"""Exact regression pins for the Table-1 barrier cycle counts.

``test_simulator.py`` checks the paper-facing claims with bands (the AMO
baselines are calibrated, not cycle-exact vs the paper); THIS file pins the
simulator's own outputs EXACTLY, so an IR or event-engine refactor cannot
silently drift the numbers the repo reports as Table 1.  Three layers are
pinned:

  * the FractalSync columns (analytic H-tree latency — paper-exact);
  * the AMO barrier replays (Naive star / XY two-level / H-tree AMO) — the
    ``HierarchicalAMOBarrier`` protocol over IR gather-tree topologies;
  * the contended-NoC replay (``schedule_on_noc``) of the three barrier
    *programs* — the generic backend every IR schedule shares.

If a change here is INTENTIONAL (e.g. recalibrated ``SimParams``), re-run
the snapshot commands in each table's comment and update the constants —
that diff is the reviewable record of the drift.
"""

import pytest

from repro.core import schedule_ir as IR
from repro.core.simulator import (DEFAULT_PARAMS, PAPER_TABLE1, NaiveBarrier,
                                  XYBarrier, schedule_on_noc, simulate_config,
                                  tree_amo_barrier)
from repro.core.tree import FractalTree

MESHES = {"Neighbor": (1, 2), "2x2": (2, 2), "4x4": (4, 4),
          "8x8": (8, 8), "16x16": (16, 16)}

# snapshot: simulate_config(name) under DEFAULT_PARAMS
#   {name: (fsync, fsync_p, naive, xy)}
PINNED = {
    "Neighbor": (4, 4, 75, 75),
    "2x2": (6, 6, 135, 192),
    "4x4": (10, 10, 573, 359),
    "8x8": (14, 18, 2350, 734),
    "16x16": (18, 34, 9381, 1683),
}

# snapshot: tree_amo_barrier(shape).run() under DEFAULT_PARAMS
PINNED_TREE_AMO = {
    "Neighbor": 75, "2x2": 192, "4x4": 498, "8x8": 937, "16x16": 1438,
}

# snapshot: schedule_on_noc(BARRIER_BUILDERS[s]((k, k))).overhead
PINNED_NOC = {
    "fractal": {"2x2": 28, "4x4": 78, "8x8": 144, "16x16": 242},
    "naive": {"2x2": 44, "4x4": 132, "8x8": 452, "16x16": 1668},
    "xy": {"2x2": 70, "4x4": 114, "8x8": 202, "16x16": 378},
}


@pytest.fixture(scope="module")
def rows():
    return {name: simulate_config(name) for name in PINNED}


@pytest.mark.parametrize("name", list(PINNED))
def test_fsync_cycles_pinned(rows, name):
    fsync, fsync_p, _, _ = PINNED[name]
    assert rows[name]["fsync"] == fsync
    assert rows[name]["fsync_p"] == fsync_p


@pytest.mark.parametrize("name", list(PINNED))
def test_fsync_matches_paper_exactly(name):
    """The FS columns are parameter-free topology: paper-exact, not just
    snapshot-stable."""
    tree = FractalTree(MESHES[name])
    paper_fsync, paper_fsync_p, *_ = PAPER_TABLE1[name]
    assert tree.fsync_latency() == paper_fsync
    assert tree.fsync_latency(pipelined=True) == paper_fsync_p


@pytest.mark.parametrize("name", list(PINNED))
def test_amo_barrier_cycles_pinned(rows, name):
    _, _, naive, xy = PINNED[name]
    assert rows[name]["naive"] == naive, (
        f"{name}: NaiveBarrier drifted from pinned {naive}")
    assert rows[name]["xy"] == xy, (
        f"{name}: XYBarrier drifted from pinned {xy}")


@pytest.mark.parametrize("name", list(PINNED))
def test_tree_amo_barrier_cycles_pinned(name):
    got = tree_amo_barrier(MESHES[name]).run()
    assert got == PINNED_TREE_AMO[name]


@pytest.mark.parametrize("schedule", sorted(PINNED_NOC))
@pytest.mark.parametrize("k", (2, 4, 8, 16))
def test_noc_replay_cycles_pinned(schedule, k):
    prog = IR.BARRIER_BUILDERS[schedule]((k, k))
    got = schedule_on_noc(prog).overhead
    assert got == PINNED_NOC[schedule][f"{k}x{k}"], (
        f"{schedule} {k}x{k}: NoC replay drifted")


def test_barrier_classes_agree_with_ir_instances():
    """NaiveBarrier/XYBarrier are IR instances of the generic AMO executor:
    re-deriving them from the barrier builders must give the same cycles."""
    from repro.core.simulator import HierarchicalAMOBarrier
    for k in (2, 4, 8):
        assert NaiveBarrier(k, k).run() == HierarchicalAMOBarrier(
            IR.naive_barrier((k, k))).run()
        assert XYBarrier(k, k).run() == HierarchicalAMOBarrier(
            IR.xy_barrier((k, k))).run()


def test_pins_cover_paper_speedup_band():
    """Sanity that the pinned numbers still tell the paper's story: FSync+P
    beats the best AMO scheme by ≥15× everywhere, ≥40× at 16×16."""
    for name, (_, fsync_p, naive, xy) in PINNED.items():
        assert min(naive, xy) / fsync_p >= 15.0
    _, fp, nv, xy = PINNED["16x16"]
    assert min(nv, xy) / fp >= 40.0
