"""Serve-side soak harness: arrivals, streaming quantiles, fault injection.

The full 2000-step soak runs in CI via ``benchmarks/soak.py --smoke``;
here the pieces are tested small: bursty arrival structure, P² accuracy,
admission holds, queue gauges, and a mini fault-injected ``run_soak``
with a real engine (spike during the stall window, recovery after).
"""

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.runtime.chaos import FaultPlan
from repro.serve import (EngineConfig, P2Quantile, Request, ServeEngine,
                         SoakConfig, burst_arrivals, parse_arrival_spec,
                         poisson_arrivals, run_soak)

ARCH = "gemma2-2b-smoke"


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.key(0))


def _requests(cfg, n, arrivals, gen=(4, 12), plen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(plen,)).tolist(),
                    max_new_tokens=int(rng.integers(gen[0], gen[1] + 1)),
                    arrival_s=arrivals[i])
            for i in range(n)]


# ---------------------------------------------------------------------------
# arrival generators
# ---------------------------------------------------------------------------


def test_burst_arrivals_deterministic_and_on_off():
    a = burst_arrivals(400, rate_per_s=40.0, duty=0.25, seed=7)
    b = burst_arrivals(400, rate_per_s=40.0, duty=0.25, seed=7)
    assert a == b
    assert burst_arrivals(400, 40.0, 0.25, seed=8) != a
    assert a[0] == 0.0 and all(x <= y for x, y in zip(a, a[1:]))
    # every arrival lands in the first duty fraction of its 1 s period
    phases = np.asarray(a) % 1.0
    assert phases.max() < 0.25
    # long-run average matches the nominal rate (Poisson CLT bounds)
    mean_rate = len(a) / a[-1]
    assert 0.8 * 40.0 < mean_rate < 1.2 * 40.0


def test_burst_matches_poisson_average_but_spikier():
    burst = np.asarray(burst_arrivals(2000, 20.0, duty=0.2, seed=3))
    pois = np.asarray(poisson_arrivals(2000, 20.0, seed=3))
    # same order of total duration...
    assert 0.7 < burst[-1] / pois[-1] < 1.3
    # ...but at sub-period resolution (one on-phase per bin) the burst's
    # peak instantaneous count spikes toward 1/duty × the Poisson peak
    def peak_count(ts):
        return max(np.histogram(ts, bins=np.arange(0, ts[-1] + 0.2,
                                                   0.2))[0])
    assert peak_count(burst) > 1.5 * peak_count(pois)


def test_parse_arrival_spec_burst():
    assert parse_arrival_spec("burst:40,0.25", 50, seed=1) == \
        burst_arrivals(50, 40.0, 0.25, seed=1)
    assert parse_arrival_spec("burst:40,0.25,2.0", 50, seed=1) == \
        burst_arrivals(50, 40.0, 0.25, period_s=2.0, seed=1)
    with pytest.raises(ValueError):
        parse_arrival_spec("burst:40", 50)
    with pytest.raises(ValueError):
        burst_arrivals(10, 40.0, duty=0.0)


# ---------------------------------------------------------------------------
# P² streaming quantiles
# ---------------------------------------------------------------------------


def test_p2_exact_below_five_samples():
    q = P2Quantile(0.5)
    assert np.isnan(q.value)
    for x in (5.0, 1.0, 3.0):
        q.add(x)
    assert q.value == 3.0


def test_p2_tracks_numpy_percentile():
    rng = np.random.default_rng(0)
    xs = rng.exponential(1.0, size=20_000)
    for p, tol in ((0.5, 0.05), (0.99, 0.15)):
        q = P2Quantile(p)
        for x in xs:
            q.add(x)
        exact = float(np.percentile(xs, 100 * p))
        assert abs(q.value - exact) / exact < tol, (p, q.value, exact)


def test_p2_rejects_bad_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


# ---------------------------------------------------------------------------
# engine fault hooks
# ---------------------------------------------------------------------------


def test_hold_admission_delays_first_token(cfg, params):
    ecfg = EngineConfig(max_slots=2, max_len=32, prefill_chunk=8,
                        chunks_per_step=2, clock="step")
    eng = ServeEngine(cfg, params, ecfg)
    eng.metrics.start()
    eng.submit(_requests(cfg, 1, [0.0]))
    eng.hold_admission(3)
    with pytest.raises(ValueError):
        eng.hold_admission(-1)
    for s in range(3):
        eng.step()
        assert len(eng.table.busy()) == 0, f"admitted during hold (step {s})"
        assert len(eng.queue) == 1
    eng.step()
    assert len(eng.table.busy()) == 1       # hold expired → admitted
    # overlapping holds extend, not stack
    eng.hold_admission(2)
    eng.hold_admission(1)
    assert eng._admission_hold == 2


def test_queue_depth_gauge(cfg, params):
    ecfg = EngineConfig(max_slots=1, max_len=32, prefill_chunk=8,
                        chunks_per_step=1, clock="step")
    eng = ServeEngine(cfg, params, ecfg)
    eng.metrics.start()
    eng.submit(_requests(cfg, 4, [0.0] * 4, gen=(8, 8)))
    for _ in range(6):
        eng.step()
    assert eng.metrics.queue_peak == 3      # 1 admitted, 3 behind it
    assert eng.metrics.summary()["queue_peak"] == 3


# ---------------------------------------------------------------------------
# mini soak run (real engine, stall fault, recovery)
# ---------------------------------------------------------------------------


def test_run_soak_recovers_from_stall(cfg, params):
    ecfg = EngineConfig(max_slots=4, max_len=32, prefill_chunk=8,
                        chunks_per_step=2, kv_mode="paged", block_size=8,
                        kv_blocks=17, clock="step")
    eng = ServeEngine(cfg, params, ecfg)
    steps, rate = 400, 40.0
    n = int(rate * steps * ecfg.step_s)
    reqs = _requests(cfg, n, poisson_arrivals(n, rate, seed=1), seed=2)
    plan = FaultPlan.parse("stall:steps=150..210")
    scfg = SoakConfig(steps=steps, window=40, warmup_steps=40,
                      recovery_band=2.0, recovery_slack_s=0.01,
                      recovery_steps=200)
    res = run_soak(eng, reqs, plan, scfg)
    assert res.ok, res.failures
    assert res.fault_end_step == 210
    assert res.recovered_step is not None
    assert len(res.trend) == steps // 40
    # the stall visibly backs up the queue inside its window
    stall_rows = [r for r in res.trend if 150 < r["step"] <= 240]
    assert max(r["queue_max"] for r in stall_rows) >= 3
    assert res.summary["queue_peak"] >= 3
    # recovery check is driven by the windowed p99 series
    assert not np.isnan(res.baseline_p99_s)


def test_run_soak_requires_step_clock(cfg, params):
    eng = ServeEngine(cfg, params,
                      EngineConfig(max_slots=2, max_len=32, prefill_chunk=8,
                                   clock="wall"))
    with pytest.raises(ValueError, match="virtual step clock"):
        run_soak(eng, [], FaultPlan(), SoakConfig(steps=1))
