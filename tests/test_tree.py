"""FractalTree invariants + Table-1 FractalSync latencies (exact)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tree import FractalTree, neighbor_tree, square_tree

# paper Table 1: mesh -> (FSync, FSync+P)
FSYNC_TABLE = {
    (1, 2): (4, 4),
    (2, 2): (6, 6),
    (4, 4): (10, 10),
    (8, 8): (14, 18),
    (16, 16): (18, 34),
}


@pytest.mark.parametrize("shape,expected", sorted(FSYNC_TABLE.items()))
def test_fsync_latency_matches_paper(shape, expected):
    tree = FractalTree(shape)
    assert tree.fsync_latency() == expected[0]
    assert tree.fsync_latency(pipelined=True) == expected[1]


def test_latency_formula():
    for k in (2, 4, 8, 16, 32, 64):
        tree = square_tree(k)
        assert tree.num_levels == 2 * int(math.log2(k))
        assert tree.fsync_latency() == 2 + 2 * tree.num_levels


def test_fs_module_count_matches_paper():
    # paper §4.2: k²−1 FractalSync modules
    for k in (2, 4, 8, 16):
        assert square_tree(k).num_fs_modules == k * k - 1


def test_neighbor_tree():
    t = neighbor_tree()
    assert t.num_tiles == 2 and t.num_levels == 1
    assert t.fsync_latency() == 4


def test_pipeline_regs_sequence_16():
    t = square_tree(16)
    regs = [t.level(l).pipeline_regs for l in range(1, 9)]
    assert regs == [0, 0, 0, 0, 1, 1, 3, 3]
    seps = [t.level(l).separation for l in range(1, 9)]
    assert seps == [1, 1, 2, 2, 4, 4, 8, 8]


def test_multi_pod_tree_pod_joins_last():
    t = FractalTree((2, 16, 16))
    assert t.num_levels == 9
    assert t.levels[-1].axis == 0     # pod axis is the root level
    # innermost axis merges first
    assert t.levels[0].axis == 2


shapes_st = st.sampled_from([(2, 2), (4, 4), (8, 8), (16, 16), (1, 2),
                             (2, 4), (4, 8), (2, 16, 16)])


@settings(max_examples=30, deadline=None)
@given(shapes_st, st.integers(0, 10), st.data())
def test_partner_involution_and_domains(shape, level_raw, data):
    tree = FractalTree(shape)
    level = 1 + level_raw % tree.num_levels
    tiles = list(tree.tiles())
    tile = data.draw(st.sampled_from(tiles))
    p = tree.partner(tile, level)
    assert p != tile
    assert tree.partner(p, level) == tile            # involution
    # partner is inside the same level-domain, outside the (level-1)-domain
    assert tree.domain_key(p, level) == tree.domain_key(tile, level)
    assert tree.domain_key(p, level - 1) != tree.domain_key(tile, level - 1) \
        or level == 0


@settings(max_examples=20, deadline=None)
@given(shapes_st, st.integers(0, 10))
def test_domains_partition(shape, level_raw):
    tree = FractalTree(shape)
    level = level_raw % (tree.num_levels + 1)
    domains = tree.domains(level)
    seen = set()
    for d in domains:
        assert len(d) == tree.domain_size(level)
        for t in d:
            assert t not in seen
            seen.add(t)
    assert len(seen) == tree.num_tiles


@settings(max_examples=20, deadline=None)
@given(shapes_st)
def test_latency_monotonic_in_level(shape):
    tree = FractalTree(shape)
    lat = [tree.fsync_latency(level) for level in range(1, tree.num_levels + 1)]
    assert all(b > a for a, b in zip(lat, lat[1:]))
    latp = [tree.fsync_latency(level, pipelined=True)
            for level in range(1, tree.num_levels + 1)]
    assert all(b >= a for a, b in zip(latp, latp[1:]))
    assert all(p >= n for n, p in zip(lat, latp))


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        FractalTree((3, 3))
    with pytest.raises(ValueError):
        FractalTree((1, 1))
