"""Multi-device numerics: bucketed pipelined sync ≡ monolithic sync.

Run standalone (spawned by tests/test_superstep.py as a subprocess so the
rest of the suite keeps a single-device jax):

    PYTHONPATH=src python tests/superstep_checks.py

Covers the ISSUE's equivalence matrix on a 16-device 4×4 host mesh:
ragged pytrees, odd bucket boundaries (pad_align variations), every
schedule (incl. per-bucket "auto") and every compression codec.  The
codec-free bucketed paths must match the monolithic path EXACTLY (the
same elementwise reduction tree, just regrouped); codec paths match the
psum-mean reference within codec tolerance.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import superstep as SS  # noqa: E402
from repro.core.bsp import BSPConfig, sync_gradients  # noqa: E402

AXES, SIZES = ("a", "b"), (4, 4)
N_DEV = 16

PASS = []


def check(name, fn):
    fn()
    PASS.append(name)
    print(f"ok  {name}", flush=True)


def ragged_tree(rng):
    """Deliberately awkward leaf shapes: primes, scalars-ish, matrices."""
    return {
        "embed": jnp.asarray(rng.normal(size=(N_DEV, 97, 13))
                             .astype(np.float32)),
        "layers": [
            {"w": jnp.asarray(rng.normal(size=(N_DEV * 31,))
                              .astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(N_DEV, 7))
                              .astype(np.float32))}
            for _ in range(3)
        ],
        "head": jnp.asarray(rng.normal(size=(N_DEV * 5, 11))
                            .astype(np.float32)),
    }


def run_sync(tree, cfg):
    spec = jax.tree.map(lambda _: P(("a", "b")), tree)
    fn = jax.jit(compat.shard_map(
        lambda g: sync_gradients(g, cfg, SIZES), jax.make_mesh(SIZES, AXES),
        (spec,), spec, check_vma=False, axis_names=frozenset(AXES)))
    return fn(tree)


def psum_mean_reference(tree):
    """Per-shard mean over the 16 device shards, replicated back."""
    def ref_leaf(x):
        shards = np.asarray(x).reshape(N_DEV, -1)
        mean = shards.mean(0)
        return np.tile(mean, (N_DEV, 1)).reshape(x.shape)
    return jax.tree.map(ref_leaf, tree)


def main():
    rng = np.random.default_rng(7)
    tree = ragged_tree(rng)
    ref = psum_mean_reference(tree)

    mono = {}   # schedule -> monolithic result (bucket_mb=None)

    # --- every schedule, monolithic vs reference ---------------------------
    for schedule in ("fractal", "ring", "xy", "naive", "hierarchical",
                     "tree", "auto"):
        def do(schedule=schedule):
            cfg = BSPConfig(sync_axes=AXES, schedule=schedule)
            out = run_sync(tree, cfg)
            mono[schedule] = out
            for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
                np.testing.assert_allclose(np.asarray(got), want,
                                           rtol=2e-5, atol=2e-5)
        check(f"monolithic[{schedule}] == psum-mean", do)

    # --- bucketed vs monolithic ------------------------------------------
    # The fractal butterfly reduces every element through the SAME binary
    # tree regardless of its position in the flat buffer, so bucketing is
    # BIT-EXACT there.  Ring/xy summation order depends on an element's
    # chunk index, which bucketing shifts — f32-tolerance equality (the
    # ISSUE's bar) for those.  Odd bucket boundaries: tiny bucket targets
    # and non-default pad_align.
    for schedule in ("fractal", "ring", "xy", "naive", "hierarchical",
                     "tree", "auto"):
        for bucket_mb, pad_align in ((0.002, 128), (0.01, 8), (0.0005, 32)):
            def do(schedule=schedule, bucket_mb=bucket_mb,
                   pad_align=pad_align):
                cfg = BSPConfig(sync_axes=AXES, schedule=schedule,
                                bucket_mb=bucket_mb, pad_align=pad_align)
                eng = SS.engine_for(tree, cfg, SIZES)
                assert eng.n_buckets > 1, \
                    f"test should exercise >1 bucket, got {eng.describe()}"
                out = run_sync(tree, cfg)
                for got, want in zip(jax.tree.leaves(out),
                                     jax.tree.leaves(mono[schedule])):
                    if schedule == "fractal":
                        np.testing.assert_array_equal(np.asarray(got),
                                                      np.asarray(want))
                    else:
                        np.testing.assert_allclose(np.asarray(got),
                                                   np.asarray(want),
                                                   rtol=1e-5, atol=1e-6)
            tag = ("== monolithic exactly" if schedule == "fractal"
                   else "≈ monolithic (f32)")
            check(f"bucketed[{schedule},{bucket_mb}MB,align{pad_align}] "
                  f"{tag}", do)

    # --- overlap=False collapses to the monolithic result ------------------
    def no_overlap():
        cfg = BSPConfig(sync_axes=AXES, schedule="fractal", bucket_mb=0.002,
                        overlap=False)
        assert SS.engine_for(tree, cfg, SIZES).n_buckets == 1
        out = run_sync(tree, cfg)
        for got, want in zip(jax.tree.leaves(out),
                             jax.tree.leaves(mono["fractal"])):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    check("bucketed[overlap=False] == monolithic exactly", no_overlap)

    # --- every codec: bucketed vs reference within codec tolerance ---------
    for comp, tol in (("bf16", 2e-2), ("int8", 6e-2)):
        def do(comp=comp, tol=tol):
            cfg = BSPConfig(sync_axes=AXES, schedule="fractal",
                            compression=comp, bucket_mb=0.002)
            out = run_sync(tree, cfg)
            for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
                scale = max(np.abs(want).max(), 1e-3)
                np.testing.assert_allclose(np.asarray(got), want,
                                           atol=tol * scale)
        check(f"bucketed[fractal+{comp}] ≈ psum-mean", do)

    # --- DP bucket-boundary search (bucket_mb="auto") ----------------------
    # Boundaries move; the reduction tree does not: the fractal DP plan must
    # stay bit-identical to the monolithic sync.  A bandwidth-starved link
    # forces the DP to actually split (with the default TPU link this tiny
    # payload is latency-bound and one bucket IS optimal).
    def dp_auto():
        from repro.core.cost_model import LinkParams
        starved = LinkParams(alpha_s=1e-9, bw_Bps=1e6, name="starved")
        cfg = BSPConfig(sync_axes=AXES, schedule="fractal",
                        bucket_mb="auto", link=starved)
        eng = SS.engine_for(tree, cfg, SIZES)
        assert eng.plan is not None and eng.plan.source == "dp", \
            eng.describe()
        assert eng.n_buckets > 1, \
            f"starved link should split buckets, got {eng.describe()}"
        out = run_sync(tree, cfg)
        for got, want in zip(jax.tree.leaves(out),
                             jax.tree.leaves(mono["fractal"])):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    check("bucketed[bucket_mb=auto,fractal] == monolithic exactly", dp_auto)

    # --- per-bucket codec (bucket_codec) -----------------------------------
    def bucket_codec_forced():
        cfg = BSPConfig(sync_axes=AXES, schedule="fractal",
                        bucket_mb=0.002, bucket_codec="bf16")
        out = run_sync(tree, cfg)
        for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            scale = max(np.abs(want).max(), 1e-3)
            np.testing.assert_allclose(np.asarray(got), want,
                                       atol=2e-2 * scale)
    check("bucketed[bucket_codec=bf16] ≈ psum-mean", bucket_codec_forced)

    def bucket_codec_auto_none_is_exact():
        # tiny latency-bound buckets: the policy must skip compression,
        # making the auto-codec path bit-identical to the codec-free one
        cfg = BSPConfig(sync_axes=AXES, schedule="fractal",
                        bucket_mb=0.002, bucket_codec="auto")
        eng = SS.engine_for(tree, cfg, SIZES)
        assert all(c == "none" for c in eng.codec_names), eng.describe()
        out = run_sync(tree, cfg)
        for got, want in zip(jax.tree.leaves(out),
                             jax.tree.leaves(mono["fractal"])):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    check("bucketed[bucket_codec=auto→none] == monolithic exactly",
          bucket_codec_auto_none_is_exact)

    # --- codec'd fractal reduce-scatter (the ZeRO-1 trainer wire path) -----
    def rs_codec():
        from repro.core import collectives as C
        from repro.optim.compression import Bf16Codec
        flat = jnp.asarray(rng.normal(size=(N_DEV * N_DEV * 128,))
                           .astype(np.float32))
        spec = P(("a", "b"))
        mesh = jax.make_mesh(SIZES, AXES)

        def run_rs(codec):
            fn = jax.jit(compat.shard_map(
                lambda v: C.reduce_scatter(v, "fractal", AXES, SIZES,
                                           codec=codec),
                mesh, (spec,), spec, check_vma=False,
                axis_names=frozenset(AXES)))
            return np.asarray(fn(flat))

        exact = run_rs(None)
        coded = run_rs(Bf16Codec())
        scale = max(np.abs(exact).max(), 1e-3)
        np.testing.assert_allclose(coded, exact, atol=2e-2 * scale)
    check("reduce_scatter[fractal+bf16 wire] ≈ uncompressed", rs_codec)

    print(f"ALL OK ({len(PASS)} checks)")


if __name__ == "__main__":
    main()
