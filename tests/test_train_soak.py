"""Training soak end-to-end (8 host devices, subprocess).

Runs tests/train_soak_checks.py in a fresh interpreter so the forced
8-device host platform cannot leak into the rest of the suite: shares
bit-consistency (uneven micro-batch splits are BIT-identical to even),
then the full fault-injected soak — actuated straggler rebalance, killed
rank, re-mesh onto the surviving fsync domain, checkpoint-restore, loss
continuity.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_train_soak_end_to_end():
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "train_soak_checks.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ALL OK" in proc.stdout
    assert "BIT-IDENTICAL" in proc.stdout
