"""Deterministic mini-`hypothesis` used when the real package is absent.

The container that runs tier-1 does not ship ``hypothesis``; rather than
turning every property test into a collection error (or a skip), the
``conftest.py`` installs this stub into ``sys.modules`` so the property
tests still run — as a fixed-seed randomized sweep of ``max_examples``
draws.  Only the tiny API subset this repo uses is provided:

    given, settings, strategies.{integers, floats, booleans, just,
    sampled_from, lists, data}

This is NOT hypothesis: no shrinking, no database, no coverage-guided
generation.  Install the real package (requirements-dev.txt) for that.
"""

from __future__ import annotations

import functools
import random
import sys
import types

_SEED = 0xF5A1  # fixed: the sweep must be reproducible across runs


class _Strategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw_fn = draw_fn
        self._label = label

    def draw(self, rng: random.Random):
        return self._draw_fn(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw_fn(rng)),
                         f"{self._label}.map")

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw_fn(rng)
                if pred(v):
                    return v
            raise ValueError(f"filter on {self._label} found no value")
        return _Strategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return f"<stub {self._label}>"


class _DataStrategy(_Strategy):
    """Marker for ``st.data()``: given() passes a _DataObject instead."""

    def __init__(self):
        super().__init__(lambda rng: None, "data")


class _DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.draw(self._rng)


def integers(min_value=0, max_value=2**31 - 1):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     f"integers({min_value},{max_value})")


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     f"floats({min_value},{max_value})")


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5, "booleans")


def just(value):
    return _Strategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements):
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from of empty sequence")
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))],
                     "sampled_from")


def lists(elements: _Strategy, min_size=0, max_size=None, unique=False):
    def draw(rng):
        hi = max_size if max_size is not None else min_size + 8
        n = rng.randint(min_size, max(min_size, hi))
        out, tries = [], 0
        while len(out) < n and tries < 100 * (n + 1):
            v = elements.draw(rng)
            tries += 1
            if unique and v in out:
                continue
            out.append(v)
        return out
    return _Strategy(draw, "lists")


def data():
    return _DataStrategy()


def given(*strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(_SEED)
            n = getattr(wrapper, "_stub_max_examples", 20)
            for _ in range(n):
                pos = [_DataObject(rng) if isinstance(s, _DataStrategy)
                       else s.draw(rng) for s in strategies]
                kws = {k: (_DataObject(rng) if isinstance(s, _DataStrategy)
                           else s.draw(rng))
                       for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kws)
        # pytest must not see the wrapped signature (it would resolve the
        # property arguments as fixtures), so drop the wraps breadcrumb
        del wrapper.__wrapped__
        wrapper.hypothesis_stub = True
        return wrapper
    return decorate


def settings(max_examples=None, deadline=None, **_kw):
    def decorate(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register the stub as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "lists", "data"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
