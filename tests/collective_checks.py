"""Multi-device numerical checks for the FractalSync collective schedules.

Run standalone (spawned by tests/test_collectives.py as a subprocess so the
rest of the suite keeps a single-device jax):

    PYTHONPATH=src python tests/collective_checks.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core import schedule_ir as IR  # noqa: E402
from repro.core.bsp import BSPConfig, bsp_shard_map, sync_gradients  # noqa: E402
from repro.core.barrier import SyncDomainMesh  # noqa: E402

PASS = []


def check(name, fn):
    fn()
    PASS.append(name)
    print(f"ok  {name}", flush=True)


def sm(fn, mesh, spec):
    return jax.jit(compat.shard_map(fn, mesh, spec, spec,
                                    check_vma=False,
                                    axis_names=frozenset(mesh.axis_names)))


def main():
    rng = np.random.default_rng(0)
    mesh44 = jax.make_mesh((4, 4), ("a", "b"))
    axes, sizes = ("a", "b"), (4, 4)
    n_dev = 16
    x = jnp.asarray(rng.normal(size=(n_dev * 64, 8)).astype(np.float32))
    spec = P(("a", "b"))
    want = np.asarray(x)  # all-reduce of a sharded array == sum of shards
    shards = np.asarray(x).reshape(n_dev, -1, 8)
    total = shards.sum(0)  # per-shard expected all-reduce value

    def expect_allreduce(fn, tol=1e-5):
        out = sm(fn, mesh44, spec)(x)
        got = np.asarray(out).reshape(n_dev, -1, 8)
        for d in range(n_dev):
            np.testing.assert_allclose(got[d], total, rtol=tol, atol=tol)

    check("fractal_all_reduce == psum",
          lambda: expect_allreduce(
              lambda v: C.fractal_all_reduce(v, axes, sizes)))

    check("naive_all_reduce == psum",
          lambda: expect_allreduce(
              lambda v: C.naive_all_reduce(v, axes, sizes)))

    check("xy_all_reduce == psum",
          lambda: expect_allreduce(
              lambda v: C.xy_all_reduce(v, "b", "a", 4, 4)))

    check("ring nested == psum",
          lambda: expect_allreduce(
              lambda v: C.all_reduce(v, "ring", axes, sizes)))

    check("hierarchical == psum",
          lambda: expect_allreduce(
              lambda v: C.hierarchical_all_reduce(v, ("b",), (4,), ("a",), (4,))))

    def rs_ag():
        def f(v):
            s = C.fractal_reduce_scatter(v, axes, sizes)
            return C.fractal_all_gather(s, axes, sizes)
        expect_allreduce(f)
    check("fractal reduce_scatter∘all_gather == psum", rs_ag)

    def rs_alone():
        def f(v):
            s = C.fractal_reduce_scatter(v, axes, sizes)
            return lax.all_gather(s, axes, tiled=False).reshape(v.shape[0] // 16 * 16, *v.shape[1:]) * 0 + jnp.sum(s)  # noqa
        # simpler: verify the scattered shards jointly cover the sum
        def g(v):
            s = C.fractal_reduce_scatter(v, axes, sizes)
            return jnp.sum(s)
        out = sm(g, mesh44, P(("a", "b")))  # scalar per shard not valid out_spec
    # coverage of rs alone is implied by rs∘ag test; skip direct check

    # --- barrier tokens per level -----------------------------------------
    def barrier_levels():
        sdm = SyncDomainMesh(mesh44, ("a", "b"))
        for level in range(sdm.num_levels + 1):
            def f(v, level=level):
                tok = sdm.fsync(level)
                return v * 0 + tok
            out = sm(f, mesh44, spec)(x)
            got = np.unique(np.asarray(out))
            assert got.size == 1 and got[0] == 2 ** level, (level, got)
    check("fsync(level) token == 2^level", barrier_levels)

    # --- sync_gradients: every schedule matches psum-mean ------------------
    grads = {
        "w": jnp.asarray(rng.normal(size=(n_dev, 40, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_dev * 5,)).astype(np.float32)),
    }
    gspec = {"w": P(("a", "b")), "b": P(("a", "b"))}
    wsh = np.asarray(grads["w"]).reshape(n_dev, 1, 40, 3)
    bsh = np.asarray(grads["b"]).reshape(n_dev, 5)
    wmean, bmean = wsh.mean(0), bsh.mean(0)

    for schedule in ("fractal", "ring", "xy", "naive", "hierarchical",
                     "tree", "auto", "xla"):
        def do(schedule=schedule):
            cfg = BSPConfig(sync_axes=axes, schedule=schedule)
            f = lambda g: sync_gradients(g, cfg, sizes)
            out = jax.jit(compat.shard_map(
                f, mesh44, (gspec,), gspec,
                check_vma=False, axis_names=frozenset(("a", "b"))))(grads)
            w = np.asarray(out["w"]).reshape(n_dev, 1, 40, 3)
            b = np.asarray(out["b"]).reshape(n_dev, 5)
            for d in range(n_dev):
                np.testing.assert_allclose(w[d], wmean, rtol=2e-5, atol=2e-5)
                np.testing.assert_allclose(b[d], bmean, rtol=2e-5, atol=2e-5)
        check(f"sync_gradients[{schedule}] == mean", do)

    # --- compressed payloads ------------------------------------------------
    for comp, tol in (("bf16", 2e-2), ("int8", 6e-2)):
        def do(comp=comp, tol=tol):
            cfg = BSPConfig(sync_axes=axes, schedule="fractal", compression=comp)
            f = lambda g: sync_gradients(g, cfg, sizes)
            out = jax.jit(compat.shard_map(
                f, mesh44, (gspec,), gspec,
                check_vma=False, axis_names=frozenset(("a", "b"))))(grads)
            w = np.asarray(out["w"]).reshape(n_dev, 1, 40, 3)
            scale = np.abs(wmean).max()
            for d in range(n_dev):
                np.testing.assert_allclose(w[d], wmean, atol=tol * scale)
        check(f"sync_gradients[fractal+{comp}] ≈ mean", do)

    # --- IR lowering ≡ legacy hand-rolled lowering --------------------------
    def ir_vs_legacy():
        prog = IR.build_program("fractal", (4, 4))

        def f(v):
            a = C.ir_all_reduce(v, prog, axes)
            b = C.fractal_all_reduce(v, axes, sizes)
            return a - b
        out = np.asarray(sm(f, mesh44, spec)(x))
        np.testing.assert_allclose(out, 0.0, atol=1e-4 * np.abs(total).max())
    check("IR lowering ≡ legacy fractal lowering", ir_vs_legacy)

    # --- manual sync axes + auto model axis ---------------------------------
    def auto_model():
        mesh = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
        k = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))

        def f(kv):
            kk, vv = kv
            y = kk @ vv            # model-axis GSPMD matmul inside manual DP
            cfg = BSPConfig(sync_axes=("pod", "data"), schedule="fractal")
            return sync_gradients(y, cfg, (2, 2), mean=False)

        fn = bsp_shard_map(f, mesh,
                           in_specs=((P(("pod", "data")), P(None)),),
                           out_specs=P(("pod", "data")),
                           sync_axes=("pod", "data"))
        out = jax.jit(fn)((k, v))
        got = np.asarray(out).reshape(4, 4, 8)
        ref = (np.asarray(k) @ np.asarray(v)).reshape(4, 4, 8).sum(0)
        for d in range(4):
            np.testing.assert_allclose(got[d], ref, rtol=1e-4, atol=1e-4)
    if compat.HAS_JAX_SHARD_MAP:
        check("bsp_shard_map manual-DP + auto-model", auto_model)
    else:
        # jax 0.4.x SPMD cannot partition partial-auto shard_map bodies on
        # host platforms (PartitionId unsupported); the all-manual paths
        # above cover the schedules themselves.
        print("skip bsp_shard_map manual-DP + auto-model "
              "(legacy jax: partial-auto shard_map unsupported)", flush=True)

    print(f"ALL OK ({len(PASS)} checks)")


if __name__ == "__main__":
    main()
