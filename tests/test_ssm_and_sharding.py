"""Chunked-BPTT scan equivalence + serve/train sharding-policy invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import ssm
from repro.models.registry import get_config
from repro.models import sharding as SH
from repro.compat import abstract_mesh
from repro.launch.mesh import make_mesh


# ------------------------------------------------------- chunked scan -----


def _body(c, x):
    c = jnp.tanh(c * 0.9 + x)
    return c, c * 2.0


@pytest.mark.parametrize("T,chunk", [(16, 4), (17, 4), (4, 8), (1024, 256)])
def test_chunked_scan_matches_plain(T, chunk):
    xs = jnp.asarray(np.random.default_rng(0).normal(size=(T, 3)),
                     jnp.float32)
    c0 = jnp.zeros((3,), jnp.float32)
    c_ref, ys_ref = lax.scan(_body, c0, xs)
    c_got, ys_got = ssm.chunked_scan(_body, c0, xs, chunk=chunk)
    np.testing.assert_allclose(np.asarray(c_got), np.asarray(c_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ys_got), np.asarray(ys_ref),
                               rtol=1e-6, atol=1e-6)


def test_chunked_scan_grad_matches():
    xs = jnp.asarray(np.random.default_rng(1).normal(size=(512, 3)),
                     jnp.float32)
    c0 = jnp.zeros((3,), jnp.float32)

    def loss(fn, xs):
        _, ys = fn(_body, c0, xs)
        return jnp.sum(ys ** 2)

    g_ref = jax.grad(lambda x: loss(lax.scan, x))(xs)
    g_got = jax.grad(lambda x: loss(
        lambda b, c, x: ssm.chunked_scan(b, c, x, chunk=128), x))(xs)
    np.testing.assert_allclose(np.asarray(g_got), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_scan_tuple_carry_and_xs():
    T = 64
    xs = (jnp.ones((T, 2)), jnp.arange(T, dtype=jnp.float32))

    def body(c, x):
        a, b = x
        c = c + jnp.sum(a) + b
        return c, c

    ref = lax.scan(body, 0.0, xs)
    got = ssm.chunked_scan(body, 0.0, xs, chunk=16)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref[1]))


# ------------------------------------------------- sharding invariants ----


def _specs_for(arch, mode, mesh_shape=(4, 4), axes=("data", "model")):
    cfg = get_config(arch)
    # AbstractMesh: the policy only reads axis sizes — no devices needed
    mesh = abstract_mesh(mesh_shape, axes)
    from repro.models import transformer as T
    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.key(0))
    return cfg, mesh, pshape, SH.param_specs(cfg, pshape, mesh, mode=mode)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "qwen3-moe-235b-a22b",
                                  "jamba-v0.1-52b"])
def test_specs_divisibility(arch):
    """Every assigned axis must divide its dim (pjit would reject)."""
    for mode in ("train", "serve"):
        cfg, mesh, pshape, specs = _specs_for(arch, mode)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree.leaves(pshape)
        assert len(flat_s) == len(flat_p)
        for spec, leaf in zip(flat_s, flat_p):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes_t = (ax,) if isinstance(ax, str) else ax
                size = 1
                for a in axes_t:
                    size *= mesh.shape[a]
                assert dim % size == 0, (arch, mode, spec, leaf.shape)


def test_serve_mode_never_fsdp_shards_dense_weights():
    """Serving must not re-gather dense weights per token: no 'data' axis on
    non-expert tensors."""
    cfg, mesh, pshape, specs = _specs_for("deepseek-v3-671b", "serve")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        is_expert = any(k in ("w_gate", "w_up", "w_down") for k in keys) \
            and "shared" not in keys and "segments" in keys \
            and "attn" not in keys
        axes_used = set()
        for ax in tuple(spec):
            if isinstance(ax, str):
                axes_used.add(ax)
            elif ax:
                axes_used.update(ax)
        if not is_expert and "mlp" not in keys:
            # dense/attention tensors: data axis must not appear
            if "data" in axes_used:
                # only experts may span the data axis in serve mode
                assert is_expert, (keys, spec)


def test_serve_mode_expert_sharding_covers_all_axes_when_divisible():
    cfg, mesh, pshape, specs = _specs_for("deepseek-v3-671b", "serve",
                                          (16, 16), ("data", "model"))
    # deepseek: 256 experts on 256 chips → full EP over both axes
    found = False
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if "ffn" in keys and "w_gate" in keys and "shared" not in keys:
            first = tuple(spec)[1]   # [stack, E, D, F] → E axis entry
            if first and set(first if not isinstance(first, str)
                             else (first,)) == {"model", "data"}:
                found = True
    assert found


def test_cache_specs_batch1_unsharded():
    cfg = get_config("jamba-v0.1-52b")
    mesh = abstract_mesh((4, 4), ("data", "model"))
    from repro.models import transformer as T
    cshape = jax.eval_shape(lambda: T.init_cache(cfg, 1, 256))
    specs = SH.cache_specs(cfg, cshape, mesh)
    for spec, leaf in zip(
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(cshape)):
        entries = tuple(spec)
        if len(leaf.shape) >= 2 and leaf.shape[1] == 1:
            assert entries[1] is None     # batch-1 must not shard
