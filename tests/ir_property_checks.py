"""Randomized multi-device check: ir_all_reduce == dense reference reduction.

Run standalone (spawned by tests/test_schedule_properties.py as a subprocess
so the rest of the suite keeps a single-device jax):

    PYTHONPATH=src python tests/ir_property_checks.py

For a fixed-seed sweep of (schedule × mesh shape × payload shape) draws,
every generated Program is validated and its ``shard_map`` + ``ppermute``
lowering is compared against the dense reference: each shard of the output
must equal the sum of all input shards.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core import schedule_ir as IR  # noqa: E402

SHAPES = ((8,), (2, 4), (4, 2), (2, 2, 2))
AXIS_POOL = ("a", "b", "c")

PASS = []


def lower(prog, mesh, axes, x):
    spec = P(axes)
    fn = compat.shard_map(lambda v: C.ir_all_reduce(v, prog, axes),
                          mesh, spec, spec, check_vma=False,
                          axis_names=frozenset(axes))
    return jax.jit(fn)(x)


def main():
    rng = np.random.default_rng(0xF5A1)
    for shape in SHAPES:
        world = int(np.prod(shape))
        axes = AXIS_POOL[:len(shape)]
        mesh = jax.make_mesh(shape, axes)
        for name in IR.SCHEDULES:
            prog = IR.build_program(name, shape)     # validates
            # randomized payload: leading dim a multiple of n_chunks
            mult = int(rng.integers(1, 4))
            width = int(rng.integers(1, 5))
            lead = prog.n_chunks * mult * world
            x = jnp.asarray(
                rng.integers(-8, 9, size=(lead, width)).astype(np.float32))
            out = lower(prog, mesh, tuple(axes), x)
            got = np.asarray(out).reshape(world, -1, width)
            want = np.asarray(x).reshape(world, -1, width).sum(0)
            for d in range(world):
                np.testing.assert_allclose(
                    got[d], want, rtol=1e-5, atol=1e-5,
                    err_msg=f"{name} on {shape}, shard {d}")
            PASS.append(f"{name}/{shape}")
            print(f"ok  ir_all_reduce {name} {shape} "
                  f"payload=({lead},{width})", flush=True)
    print(f"ALL OK ({len(PASS)} cases)")


if __name__ == "__main__":
    main()
