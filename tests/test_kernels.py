"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; same call path targets TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kernels_backend

# When the installed jax's Pallas lacks the API the kernels need, the ops
# transparently dispatch to the pure-jnp references — comparing reference
# against reference proves nothing, so skip instead of 20+ hard failures.
pytestmark = pytest.mark.skipif(
    kernels_backend() != "pallas",
    reason="Pallas API unsupported by installed jax (ops fall back to ref)")

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_mla_attention)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_mla_attention_ref)
from repro.kernels.tree_reduce.ops import (coded_tree_reduce, decode_add,
                                           encode_rows, tree_reduce)
from repro.kernels.tree_reduce.ref import linear_reduce_ref, tree_reduce_ref
from repro.models.layers import gqa_attention, paged_gather
from repro.optim.compression import CODECS

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- GEMM ----

GEMM_SHAPES = [(128, 128, 128), (256, 128, 384), (200, 300, 150),
               (64, 512, 64), (1, 128, 1), (130, 257, 129)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    x = jnp.asarray(RNG.normal(size=(m, k)), dtype=dtype)
    y = jnp.asarray(RNG.normal(size=(k, n)), dtype=dtype)
    out = gemm(x, y)
    ref = gemm_ref(x, y)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_gemm_blocks():
    x = jnp.asarray(RNG.normal(size=(256, 256)), dtype=jnp.float32)
    y = jnp.asarray(RNG.normal(size=(256, 256)), dtype=jnp.float32)
    ref = gemm_ref(x, y)
    for bm, bn, bk in [(128, 128, 128), (64, 128, 256), (128, 64, 64)]:
        out = gemm(x, y, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- flash attention --

ATTN_CASES = [
    # (B, Tq, Tk, Hq, Hkv, D, causal, window, softcap)
    (2, 128, 128, 4, 4, 64, True, None, None),
    (1, 256, 256, 8, 2, 64, True, None, None),        # GQA
    (1, 256, 256, 4, 1, 128, True, 64, None),         # MQA + window
    (1, 128, 128, 2, 2, 64, True, None, 50.0),        # softcap
    (2, 200, 200, 4, 2, 32, True, None, None),        # unaligned T
    (1, 128, 128, 4, 4, 64, False, None, None),       # bidirectional
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Tq, Tk, Hq, Hkv, D, causal, window, cap = case
    if not causal and Tq % 128:
        pytest.skip("non-causal padding needs exact blocks (documented)")
    q = jnp.asarray(RNG.normal(size=(B, Tq, Hq, D)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(B, Tk, Hkv, D)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(B, Tk, Hkv, D)), dtype=dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap)
    pos = jnp.arange(Tq)
    ref = gqa_attention(q, k, v, pos_q=pos, pos_k=pos, causal=causal,
                        window=window, attn_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad():
    B, T, H, D = 1, 128, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    pos = jnp.arange(T)
    g1 = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(
        gqa_attention(q, k, v, pos_q=pos, pos_k=pos) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


def test_flash_matches_singlehead_ref():
    bh, T, D = 3, 128, 64
    q = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    out = flash_attention(q[:, :, None], k[:, :, None], v[:, :, None])
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out[:, :, 0]), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- tree reduce --

@pytest.mark.parametrize("n,d", [(2, 128), (8, 512), (13, 700), (16, 1024),
                                 (32, 64), (1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_reduce_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype=dtype)
    out = tree_reduce(x)
    ref = jnp.sum(x.astype(jnp.float32), axis=0).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_tree_reduce_bitwise_deterministic_order():
    """The kernel's sum is bitwise-equal to the H-tree-order oracle — the
    determinism property linear accumulation does not have."""
    x = jnp.asarray(RNG.normal(size=(16, 512)) * 1e3, dtype=jnp.float32)
    out = np.asarray(tree_reduce(x))
    ref_tree = np.asarray(tree_reduce_ref(x))
    assert np.array_equal(out, ref_tree)
    # and the tree order genuinely differs from linear order somewhere
    ref_lin = np.asarray(linear_reduce_ref(x))
    assert not np.array_equal(ref_tree, ref_lin) or np.allclose(ref_tree,
                                                                ref_lin)


# ------------------------------------------------------- paged attention --
#
# The fused decode kernel walks block tables directly; its oracle is the
# gather-then-attend reference (the paged_kernel="ref" lowering).  Cases pin
# ragged per-row lengths, sentinel-padded table tails, lengths that stop
# mid-block (block-edge straddles), GQA grouping, and softcap/window.


def _paged_case(dtype, seed=0, B=3, n=4, N=9, bs=4, Hkv=2, G=3, d=16, dv=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, d)), dtype=dtype)
    kp = jnp.asarray(rng.normal(size=(N, bs, Hkv, d)), dtype=dtype)
    vp = jnp.asarray(rng.normal(size=(N, bs, Hkv, dv)), dtype=dtype)
    tables = jnp.asarray(rng.integers(1, N, size=(B, n)), dtype=jnp.int32)
    # sentinel-padded tails + ragged lengths: row 0 full-ish and straddling
    # a block edge (13 % bs != 0), row 1 short with a sentinel tail, row 2
    # minimal (single cached token)
    tables = tables.at[1, 2:].set(0)
    offset = jnp.asarray([13, 6, 0], jnp.int32)
    return q, kp, vp, tables, offset


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap",
                         [(None, None), (5, None), (None, 8.0), (6, 4.0)])
def test_paged_attention_parity(dtype, window, softcap):
    q, kp, vp, tables, offset = _paged_case(dtype)
    out = paged_attention(q, kp, vp, tables, offset, window=window,
                          softcap=softcap)
    B, _, Hq, d = q.shape
    Hkv = kp.shape[2]
    qh = q[:, 0].reshape(B, Hkv, Hq // Hkv, d)
    ref = paged_attention_ref(qh, kp, vp, tables, offset + 1,
                              scale=1.0 / np.sqrt(d), window=window,
                              softcap=softcap).reshape(out.shape)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_paged_attention_matches_gather_then_gqa():
    """Against the PRODUCTION ref lowering: paged_gather materializes the
    virtual view, gqa_attention masks causally by per-row positions."""
    q, kp, vp, tables, offset = _paged_case(jnp.float32, seed=1)
    out = paged_attention(q, kp, vp, tables, offset)
    k_all = paged_gather(kp, tables)
    v_all = paged_gather(vp, tables)
    S = k_all.shape[1]
    pos_k = jnp.arange(S, dtype=jnp.int32)[None, :]
    ref = gqa_attention(q, k_all, v_all, pos_q=offset[:, None], pos_k=pos_k,
                        causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_sentinel_and_unreferenced_blocks():
    """Poisoning the sentinel block and every unreferenced pool block must
    not move the output by a single bit — the masking (and the kernel's
    block walk) never lets those values in."""
    q, kp, vp, tables, offset = _paged_case(jnp.float32, seed=2)
    out = paged_attention(q, kp, vp, tables, offset)
    live = set()
    for b in range(tables.shape[0]):
        nblk = -(-int(offset[b] + 1) // kp.shape[1])
        live |= set(np.asarray(tables[b, :nblk]).tolist())
    poison = [i for i in range(kp.shape[0]) if i not in (live - {0})]
    kp2 = kp.at[jnp.asarray(poison)].set(1e9)
    vp2 = vp.at[jnp.asarray(poison)].set(1e9)
    out2 = paged_attention(q, kp2, vp2, tables, offset)
    assert np.array_equal(np.asarray(out), np.asarray(out2))


def test_paged_attention_invariant_to_block_placement():
    """The same logical KV content scattered to different physical blocks
    (scrambled tables) must attend identically."""
    q, kp, vp, tables, offset = _paged_case(jnp.float32, seed=3)
    N, n = kp.shape[0], tables.shape[1]
    out = paged_attention(q, kp, vp, tables, offset)
    perm = np.concatenate([[0], 1 + np.random.default_rng(9).permutation(
        N - 1)]).astype(np.int32)          # sentinel block 0 stays put
    inv = np.argsort(perm).astype(np.int32)
    kp2 = kp[jnp.asarray(inv)]
    vp2 = vp[jnp.asarray(inv)]
    tables2 = jnp.asarray(perm)[tables]
    out2 = paged_attention(q, kp2, vp2, tables2, offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_paged_attention_rejects_multi_token():
    q, kp, vp, tables, offset = _paged_case(jnp.float32)
    q2 = jnp.concatenate([q, q], axis=1)
    with pytest.raises(ValueError, match="decode-only"):
        paged_attention(q2, kp, vp, tables, offset)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_mla_attention_parity(dtype):
    rng = np.random.default_rng(5)
    B, n, N, bs, H, r, dr = 3, 4, 9, 4, 4, 24, 8
    qe = jnp.asarray(rng.normal(size=(B, 1, H, r)), dtype=dtype)
    qr = jnp.asarray(rng.normal(size=(B, 1, H, dr)), dtype=dtype)
    ckv = jnp.asarray(rng.normal(size=(N, bs, r)), dtype=dtype)
    krp = jnp.asarray(rng.normal(size=(N, bs, 1, dr)), dtype=dtype)
    tables = jnp.asarray(rng.integers(1, N, size=(B, n)), dtype=jnp.int32)
    tables = tables.at[2, 1:].set(0)
    offset = jnp.asarray([13, 6, 2], jnp.int32)
    scale = 1.0 / np.sqrt(32 + dr)
    out = paged_mla_attention(qe, qr, ckv, krp, tables, offset, scale=scale)
    ref = paged_mla_attention_ref(qe[:, 0], qr[:, 0], ckv, krp[:, :, 0, :],
                                  tables, offset + 1, scale=scale)[:, None]
    assert out.shape == (B, 1, H, r)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    # sentinel poisoning is invisible through the latent pools too
    live = {int(t) for b in range(B)
            for t in np.asarray(tables[b, :-(-int(offset[b] + 1) // bs)])}
    poison = [i for i in range(N) if i not in (live - {0})]
    out2 = paged_mla_attention(qe, qr, ckv.at[jnp.asarray(poison)].set(1e9),
                               krp.at[jnp.asarray(poison)].set(1e9),
                               tables, offset, scale=scale)
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(out2, np.float32))


# ------------------------------------------------- codec-fused tree sum --


@pytest.mark.parametrize("codec", ["none", "bf16", "int8"])
@pytest.mark.parametrize("n,d", [(2, 128), (6, 384), (16, 512)])
def test_coded_tree_reduce_parity(codec, n, d):
    """Fused dequant+reduce == decode rows, then the plain tree_reduce
    (same H-tree order; int8 may differ by an FMA ulp)."""
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype=jnp.float32)
    wire = encode_rows(x, codec)
    out = coded_tree_reduce(wire, codec)
    if codec == "int8":
        rows = (wire["q"].astype(jnp.float32)
                * wire["scale"]).reshape(n, d)
    else:
        rows = wire["x"].astype(jnp.float32)
    ref = tree_reduce(rows)
    assert out.dtype == jnp.float32 and out.shape == (d,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_decode_add_fused_matches_unfused(codec):
    """The fused receive-side accumulate == keep + codec.decode(wire), and
    with default dispatch (off-TPU) it IS that expression bit for bit."""
    rng = np.random.default_rng(11)
    keep = jnp.asarray(rng.normal(size=(1024,)), dtype=jnp.float32)
    send = jnp.asarray(rng.normal(size=(1024,)), dtype=jnp.float32)
    c = CODECS[codec]
    wire = c.encode(send)
    plain = keep + c.decode(wire, keep.shape, keep.dtype)
    fused = decode_add(keep, wire, c, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(plain),
                               rtol=1e-6, atol=1e-6)
    if jax.default_backend() != "tpu":
        assert np.array_equal(np.asarray(decode_add(keep, wire, c)),
                              np.asarray(plain))
