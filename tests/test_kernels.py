"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; same call path targets TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kernels_backend

# When the installed jax's Pallas lacks the API the kernels need, the ops
# transparently dispatch to the pure-jnp references — comparing reference
# against reference proves nothing, so skip instead of 20+ hard failures.
pytestmark = pytest.mark.skipif(
    kernels_backend() != "pallas",
    reason="Pallas API unsupported by installed jax (ops fall back to ref)")

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.tree_reduce.ops import tree_reduce
from repro.kernels.tree_reduce.ref import linear_reduce_ref, tree_reduce_ref
from repro.models.layers import gqa_attention

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- GEMM ----

GEMM_SHAPES = [(128, 128, 128), (256, 128, 384), (200, 300, 150),
               (64, 512, 64), (1, 128, 1), (130, 257, 129)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    x = jnp.asarray(RNG.normal(size=(m, k)), dtype=dtype)
    y = jnp.asarray(RNG.normal(size=(k, n)), dtype=dtype)
    out = gemm(x, y)
    ref = gemm_ref(x, y)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_gemm_blocks():
    x = jnp.asarray(RNG.normal(size=(256, 256)), dtype=jnp.float32)
    y = jnp.asarray(RNG.normal(size=(256, 256)), dtype=jnp.float32)
    ref = gemm_ref(x, y)
    for bm, bn, bk in [(128, 128, 128), (64, 128, 256), (128, 64, 64)]:
        out = gemm(x, y, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------- flash attention --

ATTN_CASES = [
    # (B, Tq, Tk, Hq, Hkv, D, causal, window, softcap)
    (2, 128, 128, 4, 4, 64, True, None, None),
    (1, 256, 256, 8, 2, 64, True, None, None),        # GQA
    (1, 256, 256, 4, 1, 128, True, 64, None),         # MQA + window
    (1, 128, 128, 2, 2, 64, True, None, 50.0),        # softcap
    (2, 200, 200, 4, 2, 32, True, None, None),        # unaligned T
    (1, 128, 128, 4, 4, 64, False, None, None),       # bidirectional
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Tq, Tk, Hq, Hkv, D, causal, window, cap = case
    if not causal and Tq % 128:
        pytest.skip("non-causal padding needs exact blocks (documented)")
    q = jnp.asarray(RNG.normal(size=(B, Tq, Hq, D)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(B, Tk, Hkv, D)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(B, Tk, Hkv, D)), dtype=dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap)
    pos = jnp.arange(Tq)
    ref = gqa_attention(q, k, v, pos_q=pos, pos_k=pos, causal=causal,
                        window=window, attn_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad():
    B, T, H, D = 1, 128, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, H, D)), dtype=jnp.float32)
    pos = jnp.arange(T)
    g1 = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(
        gqa_attention(q, k, v, pos_q=pos, pos_k=pos) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)


def test_flash_matches_singlehead_ref():
    bh, T, D = 3, 128, 64
    q = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(bh, T, D)), dtype=jnp.float32)
    out = flash_attention(q[:, :, None], k[:, :, None], v[:, :, None])
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out[:, :, 0]), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- tree reduce --

@pytest.mark.parametrize("n,d", [(2, 128), (8, 512), (13, 700), (16, 1024),
                                 (32, 64), (1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_reduce_sweep(n, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype=dtype)
    out = tree_reduce(x)
    ref = jnp.sum(x.astype(jnp.float32), axis=0).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_tree_reduce_bitwise_deterministic_order():
    """The kernel's sum is bitwise-equal to the H-tree-order oracle — the
    determinism property linear accumulation does not have."""
    x = jnp.asarray(RNG.normal(size=(16, 512)) * 1e3, dtype=jnp.float32)
    out = np.asarray(tree_reduce(x))
    ref_tree = np.asarray(tree_reduce_ref(x))
    assert np.array_equal(out, ref_tree)
    # and the tree order genuinely differs from linear order somewhere
    ref_lin = np.asarray(linear_reduce_ref(x))
    assert not np.array_equal(ref_tree, ref_lin) or np.allclose(ref_tree,
                                                                ref_lin)
