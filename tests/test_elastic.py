"""Elastic re-meshing: surviving_domain → ElasticPlan → re-mesh.

The test ``runtime/elastic.py``'s docstring promises: after failures the
recovery plan picks the largest complete fsync domain, shapes a new
power-of-two mesh over the survivors, and raises gradient accumulation so
the global batch is preserved — with the trainer's ``grad_accum`` path
actually producing the same update as the unaccumulated step.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tree import FractalTree
from repro.runtime.elastic import (ElasticPlan, build_mesh_from_tiles,
                                   plan_recovery)
from repro.runtime.fault_tolerance import surviving_domain


# ---------------------------------------------------------------------------
# surviving_domain: the structural recovery choice
# ---------------------------------------------------------------------------


def test_surviving_domain_no_failures_is_whole_tree():
    tree = FractalTree((4, 4))
    level, tiles = surviving_domain(tree, failed=[])
    assert level == tree.num_levels
    assert set(tiles) == set(tree.tiles())


def test_surviving_domain_is_largest_clean_subtree():
    tree = FractalTree((4, 4))
    level, tiles = surviving_domain(tree, failed=[(0, 0)])
    # one dead corner tile: the clean half of the mesh survives (8 tiles)
    assert len(tiles) == 8
    assert (0, 0) not in tiles
    # and it IS a domain of the tree at that level
    assert tuple(tiles) in tree.domains(level)


def test_surviving_domain_single_survivor():
    tree = FractalTree((2, 2))
    alive = (1, 1)
    failed = [t for t in tree.tiles() if t != alive]
    level, tiles = surviving_domain(tree, failed)
    assert level == 0 and tiles == (alive,)


def test_surviving_domain_all_dead_raises():
    tree = FractalTree((2, 2))
    with pytest.raises(RuntimeError):
        surviving_domain(tree, failed=list(tree.tiles()))


# ---------------------------------------------------------------------------
# plan_recovery: ElasticPlan geometry + batch preservation arithmetic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,failed,want_world,want_scale", [
    ((2, 4), [(1, 1)], 4, 2),      # half the 8-mesh survives → accum ×2
    ((4, 4), [(0, 0)], 8, 2),
    ((4, 4), [(0, 0), (3, 3)], 4, 4),
    ((2, 2), [(0, 1), (1, 0), (1, 1)], 1, 4),
])
def test_plan_recovery_preserves_global_batch(shape, failed, want_world,
                                              want_scale):
    tree = FractalTree(shape)
    plan = plan_recovery(tree, failed)
    assert isinstance(plan, ElasticPlan)
    assert plan.world == want_world == len(plan.tiles)
    assert plan.grad_accum_scale == want_scale
    # the invariant the scale exists for: survivors × accumulation == the
    # old world's total micro-batch slots, so the global batch is unchanged
    assert plan.world * plan.grad_accum_scale == tree.num_tiles
    # new mesh is a power-of-two factorization of the surviving world
    rows, cols = plan.mesh_shape
    assert rows * cols == plan.world
    assert (rows & (rows - 1)) == 0 and (cols & (cols - 1)) == 0


def test_plan_recovery_mesh_shape_squareish():
    tree = FractalTree((4, 4))
    plan = plan_recovery(tree, [])
    assert plan.mesh_shape == (4, 4)
    assert plan.grad_accum_scale == 1


# ---------------------------------------------------------------------------
# re-mesh over the survivors (host devices)
# ---------------------------------------------------------------------------


def test_build_mesh_from_tiles_single_survivor():
    tree = FractalTree((2, 2))
    alive = (1, 0)
    flat = alive[0] * 2 + alive[1]
    devices = [None] * tree.num_tiles
    devices[flat] = jax.devices()[0]
    mesh = build_mesh_from_tiles(tree, (alive,), devices=devices)
    assert mesh.devices.shape == (1, 1)
    assert mesh.devices[0, 0] == jax.devices()[0]
    assert mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# grad_accum end to end: the trainer knob the plan scales
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_trainer_grad_accum_matches_unaccumulated():
    """ElasticPlan.grad_accum_scale feeds make_bsp_train_step(grad_accum=·):
    on the surviving world, accumulating K micro-batches must equal one
    step on the same K×batch — the property that preserves the global
    batch through a re-mesh."""
    from repro.core.bsp import BSPConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.optim import adamw
    from repro.runtime import trainer

    cfg = get_config("qwen2.5-3b-smoke")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                             grad_clip=0.0)
    data = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=32, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    params0 = T.init_params(cfg, jax.random.key(0))
    bsp = BSPConfig(sync_axes=("data",), schedule="fractal")

    losses = {}
    for accum in (1, 2, 4):
        step_fn, init_state = trainer.make_bsp_train_step(
            cfg, mesh, acfg, bsp, grad_accum=accum)
        state = init_state(params0)
        *state, m = step_fn(*state, batch)
        *state, m2 = step_fn(*state, batch)
        losses[accum] = (float(np.asarray(m["loss"])),
                         float(np.asarray(m2["loss"])))
    np.testing.assert_allclose(losses[1], losses[2], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(losses[1], losses[4], rtol=1e-5, atol=1e-5)


def test_trainer_rejects_bad_grad_accum():
    from repro.core.bsp import BSPConfig
    from repro.models.registry import get_config
    from repro.optim import adamw
    from repro.runtime import trainer

    cfg = get_config("qwen2.5-3b-smoke")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    with pytest.raises(ValueError):
        trainer.make_bsp_train_step(cfg, mesh, acfg,
                                    BSPConfig(sync_axes=("data",)),
                                    grad_accum=0)
