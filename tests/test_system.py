"""End-to-end system behaviour: the BSP train loop + serving stack.

These are the integration tests the paper's workflow implies: a BSP-trained
model whose synchronization runs on the FractalSync schedule must (a) learn,
(b) reproduce exactly across schedule choices, (c) restart exactly.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, devices=None, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, *args], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_bsp_schedules_agree_subprocess():
    """Tier-B fractal vs Tier-A xla on identical data: same loss trajectory
    (the explicit H-tree schedule computes the same mean gradient)."""
    out = _run([str(ROOT / "tests" / "bsp_equivalence_check.py")])
    assert "EQUIVALENT" in out


@pytest.mark.slow
def test_train_cli_runs_and_learns(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "qwen2.5-3b-smoke",
                "--steps", "8", "--batch", "4", "--seq", "64",
                "--schedule", "fractal", "--devices", "4",
                "--checkpoint-dir", str(tmp_path / "ckpt")])
    first = last = None
    for line in out.splitlines():
        if line.startswith("loss:"):
            parts = dict(p.split("=") for p in line.split()[1:])
            first, last = float(parts["first"]), float(parts["last"])
    assert first is not None and last < first


@pytest.mark.slow
def test_serve_cli_runs():
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
                "--requests", "2", "--prompt-len", "8", "--gen", "4"])
    assert "decode" in out
    assert "decoded=4" in out      # no eos configured: full wave


@pytest.mark.slow
def test_serve_cli_eos_early_exit():
    # greedy decoding is deterministic: learn a token the wave emits, then
    # re-run with it as EOS — the decode loop must stop early
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
                "--requests", "1", "--prompt-len", "8", "--gen", "6"])
    line = next(l for l in out.splitlines() if l.startswith("sample outputs"))
    eos = eval(line.split(":", 1)[1])[0][1]    # second generated token
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
                "--requests", "1", "--prompt-len", "8", "--gen", "6",
                "--eos-id", str(eos)])
    assert "early exit" in out and "decoded=2" in out
