"""End-to-end system behaviour: the BSP train loop + serving stack.

These are the integration tests the paper's workflow implies: a BSP-trained
model whose synchronization runs on the FractalSync schedule must (a) learn,
(b) reproduce exactly across schedule choices, (c) restart exactly.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, devices=None, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, *args], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_bsp_schedules_agree_subprocess():
    """Tier-B fractal vs Tier-A xla on identical data: same loss trajectory
    (the explicit H-tree schedule computes the same mean gradient)."""
    out = _run([str(ROOT / "tests" / "bsp_equivalence_check.py")])
    assert "EQUIVALENT" in out


@pytest.mark.slow
def test_train_cli_runs_and_learns(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "qwen2.5-3b-smoke",
                "--steps", "8", "--batch", "4", "--seq", "64",
                "--schedule", "fractal", "--devices", "4",
                "--checkpoint-dir", str(tmp_path / "ckpt")])
    first = last = None
    for line in out.splitlines():
        if line.startswith("loss:"):
            parts = dict(p.split("=") for p in line.split()[1:])
            first, last = float(parts["first"]), float(parts["last"])
    assert first is not None and last < first


@pytest.mark.slow
def test_serve_cli_runs_continuous():
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
                "--requests", "6", "--prompt-len", "8", "--gen", "6",
                "--gen-spread", "4", "--max-slots", "2",
                "--prefill-chunk", "8"])
    assert "mode=continuous" in out
    assert "6/6 completed" in out
    assert "occupancy" in out and "ttft" in out


@pytest.mark.slow
def test_serve_cli_wave_and_continuous_agree():
    # fold-in sampling makes scheduling invisible: both modes emit the same
    # per-request tokens (greedy, same seed)
    args = ["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
            "--requests", "4", "--prompt-len", "8", "--gen", "5",
            "--max-slots", "2", "--prefill-chunk", "8"]
    out_c = _run(args + ["--mode", "continuous"])
    out_w = _run(args + ["--mode", "wave"])
    pick = lambda o: next(l for l in o.splitlines()  # noqa: E731
                          if l.startswith("sample outputs"))
    assert pick(out_c).strip() == pick(out_w).strip()


@pytest.mark.slow
def test_serve_cli_eos_frees_slots_early():
    # greedy decoding is deterministic: learn an emitted token, then re-run
    # with it as EOS — requests must complete early (fewer tokens out)
    base = ["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
            "--requests", "2", "--prompt-len", "8", "--gen", "6",
            "--max-slots", "2", "--prefill-chunk", "8"]
    out = _run(base)
    line = next(l for l in out.splitlines() if l.startswith("sample outputs"))
    eos = eval(line.split(":", 1)[1])[0][1]    # second generated token
    out = _run(base + ["--eos-id", str(eos)])
    line = next(l for l in out.splitlines() if l.startswith("sample outputs"))
    first = eval(line.split(":", 1)[1])[0]
    assert first[-1] == eos and len(first) < 6


@pytest.mark.slow
def test_serve_cli_sharded_slots():
    # the satellite CI path: continuous mode with the slot batch sharded
    # over 8 host devices
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma2-2b-smoke",
                "--requests", "8", "--prompt-len", "8", "--gen", "4",
                "--max-slots", "8", "--prefill-chunk", "8",
                "--devices", "8"])
    assert "devices=8" in out and "8/8 completed" in out
