"""FaultPlan DSL + StepClock: parsing, queries, determinism."""

import pytest

from repro.runtime.chaos import FaultEvent, FaultPlan, StepClock
from repro.runtime.fault_tolerance import HostMonitor

SPEC = ("kill:rank=2,step=300;"
        "slow:rank=3,factor=2.5,steps=100..140;"
        "drop_hb:host=1,steps=50..60;"
        "dup_hb:host=0,step=75;"
        "stall:steps=200..220;"
        "blocks:frac=0.5,steps=150..200")


def test_parse_and_roundtrip():
    plan = FaultPlan.parse(SPEC)
    assert len(plan.events) == 6
    assert FaultPlan.parse(plan.spec()).spec() == plan.spec()
    assert FaultPlan.parse("").spec() == ""
    assert not FaultPlan() and bool(plan)


def test_queries():
    plan = FaultPlan.parse(SPEC)
    assert plan.kills_at(300) == {2} and plan.kills_at(299) == set()
    assert plan.killed_by(299) == set()
    assert plan.killed_by(300) == plan.killed_by(10_000) == {2}
    assert plan.slow_factor(3, 100) == 2.5
    assert plan.slow_factor(3, 140) == 1.0      # half-open window
    assert plan.slow_factor(0, 100) == 1.0
    assert plan.heartbeat_dropped(1, 50) and not plan.heartbeat_dropped(1, 60)
    assert plan.heartbeat_duplicated(0, 75)
    assert not plan.heartbeat_duplicated(0, 76)
    assert plan.admission_stalled(200) and not plan.admission_stalled(220)
    assert plan.block_pressure(150) == 0.5
    assert plan.block_pressure(200) == 0.0
    assert plan.first_fault_start() == 50
    assert plan.last_fault_end() == 301
    assert (50, 60) in plan.fault_windows()


def test_slow_factor_overlap_takes_max():
    plan = FaultPlan.parse("slow:rank=0,factor=2,steps=0..10;"
                           "slow:rank=0,factor=3,steps=5..8")
    assert plan.slow_factor(0, 6) == 3.0
    assert plan.slow_factor(0, 9) == 2.0


@pytest.mark.parametrize("bad", [
    "melt:rank=1,step=3",                 # unknown kind
    "kill:rank=1",                        # no window
    "kill:rank=1,steps=5",                # steps needs A..B
    "slow:rank=1,factor=0.5,steps=1..2",  # factor must be > 1
    "slow:factor=2,steps=1..2",           # needs a rank
    "blocks:frac=1.5,steps=1..2",         # frac in (0,1]
    "stall:steps=5..5",                   # empty window
    "kill:rank 1,step=3",                 # not key=value
])
def test_bad_specs_raise(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_event_spec_roundtrip_point_vs_window():
    e = FaultEvent("stall", 7, 8)
    assert FaultPlan.parse(e.spec()).events[0] == e
    w = FaultEvent("stall", 7, 19)
    assert FaultPlan.parse(w.spec()).events[0] == w


def test_random_plans_are_seed_deterministic():
    a = FaultPlan.random(seed=11, steps=2000, ranks=8)
    b = FaultPlan.random(seed=11, steps=2000, ranks=8)
    c = FaultPlan.random(seed=12, steps=2000, ranks=8)
    assert a.spec() == b.spec()
    assert a.spec() != c.spec()
    assert a.events[0].kind == "slow"       # always a pre-kill baseline fault
    for e in a.events:
        assert 500 <= e.step < 1500         # inside [steps//4, 3·steps//4)


def test_step_clock_drives_host_monitor():
    clock = StepClock(step_s=1.0)
    mon = HostMonitor(num_hosts=2, timeout_s=3.0, clock=clock)
    mon.heartbeat(0)
    mon.heartbeat(1)
    clock.tick(3)
    assert mon.failed_hosts() == set()      # 3.0 is not > 3.0
    mon.heartbeat(0)
    clock.tick()
    assert mon.failed_hosts() == {1}
    assert clock() == clock.now() == 4.0
