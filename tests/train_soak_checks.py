"""Training-soak checks (8 host devices): shares bit-consistency + the
full fault-injected soak with actuated rebalance and elastic recovery.

Part A — the uneven-``shares=`` BSP path is BIT-IDENTICAL to the even
split on the same micro-batch set (compensated-pair accumulation makes
the global gradient partition-independent in f32), and allclose to the
legacy ``grad_accum`` scan path.

Part B — ``runtime.soak.run_train_soak``: a slow rank triggers an
actuated micro-batch rebalance; a killed rank triggers heartbeat-timeout
detection, re-mesh onto the surviving complete fsync domain,
checkpoint-restore, and a loss trajectory that replays the pre-fault
recording at the restore step before continuing to descend.

Run as a subprocess by tests/test_train_soak.py.
"""

import os
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.bsp import BSPConfig  # noqa: E402
from repro.data.pipeline import (DataConfig, SyntheticLM,  # noqa: E402
                                 reshard_for_shares)
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.registry import get_config  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import trainer  # noqa: E402
from repro.runtime.soak import (TrainSoakConfig, check_train_soak,  # noqa: E402
                                run_train_soak)


def check_shares_bit_consistency():
    cfg = get_config("qwen2.5-3b-smoke")
    mesh = make_mesh((8, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100,
                             grad_clip=0.0)
    bsp = BSPConfig(sync_axes=("data",), schedule="fractal", bucket_mb=0.25)
    params0 = T.init_params(cfg, jax.random.key(0))
    data = SyntheticLM(cfg, DataConfig(global_batch=16, seq_len=16, seed=3))
    raw = data.batch(0)              # 16 micro-batches of 1 row each

    outs = {}
    for shares in [(2,) * 8, (3, 1, 2, 2, 2, 2, 2, 2)]:
        step, init = trainer.make_bsp_train_step(cfg, mesh, acfg, bsp,
                                                 shares=shares)
        state = init(jax.tree.map(jnp.array, params0))
        b = {k: jnp.asarray(v)
             for k, v in reshard_for_shares(raw, shares).items()}
        *state, m = step(*state, b)
        outs[shares] = (jax.tree.map(np.asarray, state[0]),
                        float(m["loss"]))
        print(f"shares {shares}: loss {outs[shares][1]!r}")

    (ref_p, ref_l), (une_p, une_l) = outs.values()
    assert une_l == ref_l, (ref_l, une_l)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(une_p)):
        assert np.array_equal(a, b), "uneven shares changed the update bits"
    print("uneven shares == even shares: BIT-IDENTICAL")

    stepG, initG = trainer.make_bsp_train_step(cfg, mesh, acfg, bsp,
                                               grad_accum=2)
    stateG = initG(jax.tree.map(jnp.array, params0))
    *stateG, mG = stepG(*stateG, {k: jnp.asarray(v) for k, v in raw.items()})
    print(f"legacy grad_accum=2: loss {float(mG['loss'])!r}")
    np.testing.assert_allclose(float(mG["loss"]), ref_l,
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(stateG[0]), jax.tree.leaves(ref_p)):
        np.testing.assert_allclose(np.asarray(a), b, rtol=2e-4, atol=2e-4)
    print("shares path ~= legacy grad_accum path (allclose)")


def check_soak():
    scfg = TrainSoakConfig()
    with tempfile.TemporaryDirectory() as d:
        result = check_train_soak(run_train_soak(scfg, d), scfg)
    print("rebalance events:", result.rebalance)
    print("actuated shares :", result.actuated_shares)
    print("recovery        :", result.recovery)
    print("replay pairs    :", result.replay_pairs)
    losses = [r["loss"] for r in result.history]
    print(f"losses: first {losses[:3]} ... last {losses[-3:]}")
    assert result.ok, result.failures
    print("train soak: rebalance actuated, rank killed, re-meshed onto "
          f"level-{result.recovery['level']} domain "
          f"({result.recovery['old_world']}→{result.recovery['new_world']} "
          "ranks), checkpoint-restored, loss trajectory continuous")


def main():
    check_shares_bit_consistency()
    check_soak()
    print("ALL OK")


if __name__ == "__main__":
    main()
