"""SlotState protocol: recurrent / hybrid serving on the continuous engine.

What the per-layer backend refactor must guarantee:

  * **wave-vs-continuous token identity** for every backend mix: pure
    recurrent (xlstm), hybrid attention+mamba (jamba) on BOTH KV modes,
    and pure attention (granite) — ``serve_waves`` is the oracle;
  * **two-resource admission**: a request commits only when a recurrent
    row AND (paged) enough KV blocks are free — no over-commit, no
    deadlock, and outputs independent of pool sizes / admission order
    (the fold-in RNG keys on req_id, never on scheduling);
  * **preemption safety on hybrids**: blocks can run dry mid-decode and
    preempt; the requeued request re-prefils its recurrence from scratch
    and regenerates its tokens exactly;
  * **resource hygiene**: a drained engine returns every row and block.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve import (EngineConfig, NoFreeRows, RecurrentRows, Request,
                         ServeEngine, StatePlan, serve_waves)

JAMBA = "jamba-v0.1-52b-smoke"


@pytest.fixture(scope="module")
def jcfg():
    return get_config(JAMBA)


@pytest.fixture(scope="module")
def jparams(jcfg):
    return T.init_params(jcfg, jax.random.key(0))


def _requests(cfg, lens, gens, seed=0, arrivals=None):
    rng = np.random.default_rng(seed)
    return [Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=g,
                    arrival_s=0.0 if arrivals is None else arrivals[i])
            for i, (n, g) in enumerate(zip(lens, gens))]


def _drive(eng, reqs, cap=5000):
    """Run the engine to drain with a step bound (deadlock detector)."""
    eng.submit(reqs)
    eng.metrics.start()
    steps = 0
    while len(eng.queue) or eng.table.busy():
        if not eng.table.busy():
            nxt = eng.queue.next_arrival()
            if nxt is not None:
                eng.metrics.wait_until(nxt)
        eng.step()
        steps += 1
        assert steps < cap, f"engine failed to drain within {cap} steps"
    eng.metrics.stop()
    return {r.req_id: eng.results[r.req_id] for r in reqs}


def _assert_drained(eng):
    """Every backend resource must be back in its pool after a drain."""
    if eng.rec is not None:
        eng.rec.assert_consistent()
        assert eng.rec.num_used == 0
    if eng.allocator is not None:
        assert eng.allocator.num_used == 0


# ---------------------------------------------------------------------------
# host-side pools and plans
# ---------------------------------------------------------------------------


def test_recurrent_rows_alloc_order_and_exhaustion():
    pool = RecurrentRows(3)
    assert [pool.alloc() for _ in range(3)] == [1, 2, 3]   # deterministic
    assert pool.num_free == 0
    with pytest.raises(NoFreeRows):
        pool.alloc()
    pool.free(2)
    assert pool.num_used == 2 and pool.alloc() == 2
    pool.assert_consistent()


def test_recurrent_rows_never_hands_out_sentinel():
    pool = RecurrentRows(2)
    rows = {pool.alloc(), pool.alloc()}
    assert 0 not in rows
    with pytest.raises(ValueError):
        pool.free(0)            # sentinel row is not live, cannot be freed
    with pytest.raises(ValueError):
        pool.free(1) or pool.free(1)    # double free


def test_state_plan_resolution(jcfg):
    plan = StatePlan.resolve(jcfg, "paged")
    assert plan.has_recurrent and plan.has_kv
    assert plan.backends.count("recurrent") == 7    # 4 mamba + 3 mamba_moe
    assert plan.backends.count("paged") == 1
    assert plan.describe() == "1×paged + 7×recurrent"

    xplan = StatePlan.resolve(get_config("xlstm-1.3b-smoke"), "contiguous")
    assert xplan.has_recurrent and not xplan.has_kv and xplan.kv_mode is None

    gplan = StatePlan.resolve(get_config("granite-34b-smoke"), "contiguous")
    assert not gplan.has_recurrent and gplan.backends == ("contiguous",) * 2


# ---------------------------------------------------------------------------
# wave-vs-continuous token identity, per backend mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["xlstm-1.3b-smoke", "granite-34b-smoke"])
def test_identity_single_backend(arch):
    """Pure-recurrent (masked aligned-chunk prefill) and pure-attention
    archs match the wave oracle token for token; prompt length 9 with
    chunk 4 forces a 1-valid-token masked tail on the recurrent path."""
    cfg = get_config(arch)
    params = T.init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(max_slots=2, max_len=24, prefill_chunk=4,
                        temperature=0.8, seed=11)
    reqs = _requests(cfg, [9] * 4, [5, 3, 4, 2], seed=1)
    oracle, _ = serve_waves(cfg, params, ecfg, reqs)
    eng = ServeEngine(cfg, params, ecfg)
    out = _drive(eng, _requests(cfg, [9] * 4, [5, 3, 4, 2], seed=1))
    assert out == oracle
    _assert_drained(eng)


@pytest.mark.parametrize("kv_mode", ["contiguous", "paged"])
def test_identity_hybrid(jcfg, jparams, kv_mode):
    """Jamba mixes paged/contiguous KV and recurrent rows in ONE engine
    run and still matches the oracle exactly."""
    ecfg = EngineConfig(max_slots=2, max_len=32, prefill_chunk=4,
                        temperature=0.7, seed=5, kv_mode=kv_mode,
                        block_size=8)
    reqs = _requests(jcfg, [10] * 4, [6, 4, 5, 3], seed=3)
    oracle, _ = serve_waves(jcfg, jparams, ecfg, reqs)
    eng = ServeEngine(jcfg, jparams, ecfg)
    assert eng.plan.describe() == f"1×{kv_mode} + 7×recurrent"
    out = _drive(eng, _requests(jcfg, [10] * 4, [6, 4, 5, 3], seed=3))
    assert out == oracle
    _assert_drained(eng)
    if kv_mode == "paged":
        # the hybrid really exercised BOTH pools in one run
        assert eng.metrics.summary()["blocks_peak"] > 0
        assert eng.metrics.peak_active > 0
        # recurrent archs must never share prefix blocks (a hit would skip
        # the recurrence) — the lookup gauge stays untouched
        assert eng.metrics.prefix_lookup_tokens == 0


def test_identity_hybrid_under_preemption(jcfg, jparams):
    """A block pool too small for three growing hybrid requests forces a
    mid-decode preemption; the victim re-prefils its RECURRENT state from
    the prompt and regenerates its tokens exactly (fold-in RNG), so the
    oracle match still holds — and the discarded decode work is booked."""
    # chunks_per_step=4 lands every request in ACTIVE decode before the
    # pool dries, so the preempted victim has decode tokens to discard
    # (a victim caught mid-prefill would book zero waste)
    ecfg = EngineConfig(max_slots=3, max_len=32, prefill_chunk=4,
                        chunks_per_step=4, temperature=0.6, seed=9,
                        kv_mode="paged", block_size=8, kv_blocks=8)
    mk = lambda: _requests(jcfg, [14] * 3, [10, 10, 10], seed=7)
    oracle, _ = serve_waves(jcfg, jparams, ecfg, mk())
    eng = ServeEngine(jcfg, jparams, ecfg)
    out = _drive(eng, mk())
    s = eng.metrics.summary()
    assert s["preemptions"] > 0, "geometry was meant to force preemption"
    assert out == oracle
    _assert_drained(eng)
    # exact decode accounting: every decode-step token either reached a
    # surviving output (tokens_out minus the prefill-born first tokens) or
    # was discarded by a preemption — no modulo, no slack
    assert s["decode_steps"] > 0
    assert eng.metrics.decode_tokens == \
        (s["tokens_out"] - s["first_tokens"]) + s["wasted_decode_tokens"]
    assert s["wasted_decode_tokens"] > 0


def test_two_resource_admission_rows_scarce(jcfg, jparams):
    """rec_slots < max_slots makes recurrent rows the scarce resource:
    concurrency caps at the row pool, admission defers (never deadlocks),
    and outputs stay identical to the roomy engine."""
    roomy = EngineConfig(max_slots=3, max_len=32, prefill_chunk=4,
                         temperature=0.7, seed=5)
    tight = EngineConfig(max_slots=3, max_len=32, prefill_chunk=4,
                         temperature=0.7, seed=5, rec_slots=1)
    mk = lambda: _requests(jcfg, [8, 6, 10, 7], [5, 4, 6, 3], seed=2)
    e1 = ServeEngine(jcfg, jparams, roomy)
    out1 = _drive(e1, mk())
    e2 = ServeEngine(jcfg, jparams, tight)
    assert e2.rec.capacity == 1
    out2 = _drive(e2, mk())
    assert out1 == out2
    assert e2.metrics.peak_active <= 1      # rows, not slots, set the cap
    _assert_drained(e1)
    _assert_drained(e2)


# ---------------------------------------------------------------------------
# property: two-resource admission never over-commits, never deadlocks,
# and scheduling never leaks into outputs
# ---------------------------------------------------------------------------

_ENGINES = {}


def _engine(key):
    """One engine per pool geometry, reused across property examples so
    each compiled function is traced once (fresh req_ids per example keep
    the fold-in RNG — and the metrics records — per-request exact).
    Module-level memo instead of fixtures: the hypothesis stub's ``given``
    wrapper hides the test signature from pytest, so fixture params would
    swallow the drawn values."""
    if "cfg" not in _ENGINES:
        _ENGINES["cfg"] = get_config(JAMBA)
        _ENGINES["params"] = T.init_params(_ENGINES["cfg"],
                                           jax.random.key(0))
    if key not in _ENGINES:
        if key == "roomy-contig":
            ecfg = EngineConfig(max_slots=3, max_len=32, prefill_chunk=4,
                                temperature=0.9, seed=13)
        elif key == "tight-paged":
            ecfg = EngineConfig(max_slots=2, max_len=32, prefill_chunk=4,
                                temperature=0.9, seed=13, kv_mode="paged",
                                block_size=8, kv_blocks=7, rec_slots=1)
        else:
            raise KeyError(key)
        _ENGINES[key] = ServeEngine(_ENGINES["cfg"], _ENGINES["params"],
                                    ecfg)
    return _ENGINES[key]


_REQ_COUNTER = [1000]


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(1, 12), min_size=2, max_size=5), st.data())
def test_admission_property(plens, data):
    """For random request batches (ragged prompts, ragged budgets, jittered
    arrivals): a slot-rich contiguous engine and a row-and-block-starved
    paged engine produce IDENTICAL outputs, both drain within a bounded
    step count, and both hand every resource back."""
    cfg = _engine("roomy-contig").cfg
    gens = [data.draw(st.integers(1, 6)) for _ in plens]
    arrivals = [data.draw(st.sampled_from([0.0, 0.01, 0.03]))
                for _ in plens]
    arrivals[0] = 0.0
    base = _REQ_COUNTER[0]
    _REQ_COUNTER[0] += len(plens)

    def mk(t0):
        # arrivals ride the engine's (monotonically advancing) virtual
        # clock so the jitter still staggers admission on reused engines
        reqs = _requests(cfg, plens, gens, seed=base,
                         arrivals=[t0 + a for a in arrivals])
        for i, r in enumerate(reqs):
            r.req_id = base + i
        return reqs

    outs = []
    for key in ("roomy-contig", "tight-paged"):
        eng = _engine(key)
        outs.append(_drive(eng, mk(eng.metrics.now())))
        _assert_drained(eng)
        for i, g in enumerate(gens):
            assert len(outs[-1][base + i]) <= g
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# determinism plumbing the protocol rides on
# ---------------------------------------------------------------------------


def test_queue_heap_preserves_arrival_then_id_order():
    """The heap rewrite must keep the sorted-list contract: pops come in
    (arrival_s, req_id) order with ties broken by req_id, regardless of
    submit order — including preemption requeues landing mid-stream."""
    from repro.serve import RequestQueue
    q = RequestQueue()
    mk = lambda i, t: Request(req_id=i, prompt=[1], max_new_tokens=1,
                              arrival_s=t)
    q.submit([mk(5, 0.2), mk(1, 0.1), mk(4, 0.1), mk(2, 0.2)])
    assert q.next_arrival() == 0.1
    assert q.pop_ready(1.0).req_id == 1
    q.submit(mk(0, 0.0))                     # requeue jumps the line
    assert [q.pop_ready(1.0).req_id for _ in range(4)] == [0, 4, 2, 5]
    assert q.pop_ready(1.0) is None and len(q) == 0


def test_virtual_step_clock_is_deterministic(jcfg, jparams):
    """The default engine clock is virtual: two runs over identical
    requests report IDENTICAL TTFTs (wall clocks never could), and the
    serve loop never sleeps through arrival gaps (arrivals far in the
    virtual future drain instantly in real time)."""
    ecfg = EngineConfig(max_slots=2, max_len=32, prefill_chunk=4,
                        temperature=0.5, seed=4)
    assert ecfg.clock == "step"
    # 300s of virtual arrival gaps: a sleeping clock would blow way past
    # the suite timeout, the virtual clock jumps them instantly (compile
    # time is the only real cost here)
    mk = lambda: _requests(jcfg, [6, 6, 6], [3, 3, 3], seed=6,
                           arrivals=[0.0, 150.0, 300.0])
    e1 = ServeEngine(jcfg, jparams, ecfg)
    _drive(e1, mk())
    e2 = ServeEngine(jcfg, jparams, ecfg)
    _drive(e2, mk())
    assert e1.metrics.ttfts() == e2.metrics.ttfts()
    # the idle jump really happened: the last first-token lands past the
    # 300s virtual arrival, yet its TTFT (relative to arrival) stays tiny
    last = max(r.first_token_s for r in e1.metrics.requests.values())
    assert last >= 300.0 and e1.metrics.ttfts()[-1] < 1.0
