"""TrainLoop per-rank duration recording → StragglerTracker rebalancing.

The loop used to record every superstep under rank 0, so the tracker
could never see a straggler on >1 rank.  Now per-rank durations come from
step metrics when the runner provides them (``per_rank_step_s``), with
this host's wall clock under its own rank as the fallback.
"""

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import get_config
from repro.runtime.loop import LoopConfig, TrainLoop


def _data():
    return SyntheticLM(get_config("qwen2.5-3b-smoke"),
                       DataConfig(global_batch=2, seq_len=8, seed=0))


def _loop(step_fn, total_steps=6, **cfg_kw):
    return TrainLoop(step_fn=step_fn, state=(np.zeros(1),), data=_data(),
                     cfg=LoopConfig(total_steps=total_steps, log_every=0,
                                    **cfg_kw))


def test_per_rank_metrics_feed_straggler_tracker():
    world = 4

    def step_fn(state, batch):
        # rank 3 is 4× slower than everyone else
        per_rank = np.array([0.1, 0.1, 0.1, 0.4], np.float32)
        return state, {"loss": np.float32(1.0), "per_rank_step_s": per_rank}

    loop = _loop(step_fn)
    loop.run()
    assert sorted(loop.stragglers.durations) == list(range(world))
    assert loop.stragglers.stragglers() == {3}
    # and the proportional rebalance takes micro-batches away from rank 3
    shares = loop.stragglers.rebalanced_shares(list(range(world)), 8)
    assert sum(shares.values()) == 8
    assert shares[3] == min(shares.values()) < max(shares.values())


def test_rebalance_hint_is_surfaced():
    def step_fn(state, batch):
        per_rank = np.array([0.1, 0.5], np.float32)
        return state, {"loss": np.float32(1.0), "per_rank_step_s": per_rank}

    loop = _loop(step_fn, rebalance_microbatches=4)
    out = loop.run()
    hints = loop.rebalance_history
    assert hints, "straggler rebalance should be recorded"
    assert out["rebalance"] == hints
    assert hints[-1]["stragglers"] == [1]
    assert sum(hints[-1]["shares"].values()) == 4
    assert hints[-1]["shares"][1] < hints[-1]["shares"][0]
    # the loss history stays homogeneous: every entry indexes by "loss"
    assert all("loss" in h for h in loop.history)


def test_wall_clock_fallback_records_this_hosts_rank():
    def step_fn(state, batch):
        return state, {"loss": np.float32(2.0)}

    loop = _loop(step_fn, total_steps=3)
    loop.host_rank = 2
    loop.run()
    assert list(loop.stragglers.durations) == [2]
    assert len(loop.stragglers.durations[2]) == 3
    # a single rank can never be flagged against itself
    assert loop.stragglers.stragglers() == set()
