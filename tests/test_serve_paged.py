"""Paged-KV backend correctness: token identity with the contiguous
backend, prefix sharing, copy-on-write, preemption, EOS threading.

The invariant everything rests on: with ``block_size | max_len`` the
gathered virtual KV view has the SAME shape and the SAME values as a
contiguous cache row, and prefix hits restart prefill on the chunk grid —
so the paged backend emits token-identical outputs, across ragged prompt
lengths whose chunk boundaries straddle block edges, and across a
preempt-and-requeue cycle.  (Raw logits may differ in the last mantissa
bit: XLA fuses the gather-fed and where-fed attention graphs differently;
the primitive-level tests pin tight numeric agreement + argmax equality,
and every engine-level test asserts exact token identity.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve import (EngineConfig, Request, ServeEngine, serve_waves)
from repro.serve.blocks import SENTINEL
from repro.serve.slots import SlotTable

ARCH = "gemma2-2b-smoke"


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.key(0))


def _requests(cfg, lens, gens, seed=0, arrivals=None):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).tolist()
               for n in lens]
    return [Request(req_id=i, prompt=p, max_new_tokens=g,
                    arrival_s=0.0 if arrivals is None else arrivals[i])
            for i, (p, g) in enumerate(zip(prompts, gens))]


def _paged(**kw):
    base = dict(max_slots=2, max_len=24, prefill_chunk=4, chunks_per_step=2,
                kv_mode="paged", block_size=4, kv_blocks=0)
    base.update(kw)
    return EngineConfig(**base)


def _contig(**kw):
    base = dict(max_slots=2, max_len=24, prefill_chunk=4, chunks_per_step=2)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# model-level primitives: paged ≡ contiguous, bit for bit
# ---------------------------------------------------------------------------


def test_paged_prefill_straddling_block_edges_matches_contiguous(
        cfg, params):
    """Chunked prefill (interior + right-aligned tail) through a block
    table must write the same logits and cache bits as the contiguous row
    — block_size 4 does NOT divide plen 10, so the tail chunk [6,10)
    straddles a block edge."""
    plen, C, bs, max_len = 10, 4, 4, 16
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(1, plen)).astype(np.int32)
    chunks = [(0, prompt[:, 0:C]), (4, prompt[:, 4:8]),
              (plen - C, prompt[:, plen - C:plen])]

    ccache = T.init_cache(cfg, 1, max_len)
    for off, chunk in chunks:
        cl, ccache = T.prefill_chunk(params, cfg, jnp.asarray(chunk), ccache,
                                     jnp.asarray(off, jnp.int32))

    pcache = T.init_paged_cache(cfg, 8, bs)
    table = jnp.asarray([[2, 5, 1, SENTINEL]], jnp.int32)  # scrambled blocks
    for off, chunk in chunks:
        pl, pcache = T.prefill_chunk(params, cfg, jnp.asarray(chunk), pcache,
                                     jnp.asarray(off, jnp.int32),
                                     block_tables=table)
    cl, pl = np.asarray(cl), np.asarray(pl)
    np.testing.assert_allclose(pl, cl, rtol=2e-5, atol=2e-5)
    assert np.array_equal(cl.argmax(-1), pl.argmax(-1))

    # the gathered virtual view holds the same prompt content as the row
    for cleaf, pleaf in zip(jax.tree.leaves(ccache), jax.tree.leaves(pcache)):
        cleaf, pleaf = np.asarray(cleaf), np.asarray(pleaf)
        tbl = np.asarray(table[0])
        virt = pleaf[:, tbl].reshape(
            (pleaf.shape[0], len(tbl) * bs) + pleaf.shape[3:])
        np.testing.assert_allclose(
            virt[:, :plen].astype(np.float32),
            cleaf[:, 0, :plen].astype(np.float32), rtol=2e-5, atol=2e-5)


def test_paged_decode_matches_contiguous(cfg, params):
    """Vector-offset batched decode through block tables == contiguous —
    given the same chunk-prefill geometry on both sides (the engines
    always use matching chunk grids; that is the identity invariant).
    Tight numeric agreement + identical argmax per step."""
    B, P, bs, max_len = 3, 6, 4, 12
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, size=(B, P)).astype(np.int32)
    chunk_offs = (0, P - 4)         # chunk [2,6) straddles the block edge
    ccache = T.init_cache(cfg, B, max_len)
    for b in range(B):
        sub = T.take_slot(ccache, b)
        for off2 in chunk_offs:
            chunk = prompts[b:b + 1, off2:off2 + 4]
            _, sub = T.prefill_chunk(params, cfg, jnp.asarray(chunk), sub,
                                     jnp.asarray(off2, jnp.int32))
        ccache = T.write_slot(ccache, sub, b)
    pcache = T.init_paged_cache(cfg, 12, bs)
    tables = np.asarray([[1, 4, 7], [2, 5, 8], [3, 6, 9]], np.int32)
    for b in range(B):
        for off2 in chunk_offs:
            chunk = prompts[b:b + 1, off2:off2 + 4]
            _, pcache = T.prefill_chunk(
                params, cfg, jnp.asarray(chunk), pcache,
                jnp.asarray(off2, jnp.int32),
                block_tables=jnp.asarray(tables[b:b + 1]))
    tok = rng.integers(0, cfg.vocab_size, size=(B, 1)).astype(np.int32)
    offs = np.full((B,), P, np.int32)
    for _ in range(3):
        cl, ccache = T.decode_step(params, cfg, jnp.asarray(tok), ccache,
                                   jnp.asarray(offs))
        pl, pcache = T.decode_step(params, cfg, jnp.asarray(tok), pcache,
                                   jnp.asarray(offs),
                                   block_tables=jnp.asarray(tables))
        cl, pl = np.asarray(cl), np.asarray(pl)
        np.testing.assert_allclose(pl, cl, rtol=2e-5, atol=2e-5)
        assert np.array_equal(cl.argmax(-1), pl.argmax(-1))
        tok = cl[:, 0].argmax(-1).astype(np.int32)[:, None]
        offs = offs + 1


def test_copy_block_copies_one_block_only(cfg):
    cache = T.init_paged_cache(cfg, 6, 4)
    cache = jax.tree.map(
        lambda x: jnp.arange(x.size, dtype=x.dtype).reshape(x.shape), cache)
    out = T.copy_block(cache, jnp.asarray(2, jnp.int32),
                       jnp.asarray(4, jnp.int32))
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        a, b = np.asarray(a), np.asarray(b)
        assert np.array_equal(b[:, 4], a[:, 2])
        keep = [i for i in range(6) if i != 4]
        assert np.array_equal(b[:, keep], a[:, keep])


def test_init_paged_cache_rejects_recurrent_arch():
    with pytest.raises(ValueError, match="recurrent"):
        T.init_paged_cache(get_config("xlstm-1.3b-smoke"), 8, 4)


# ---------------------------------------------------------------------------
# engine level: token identity across backends
# ---------------------------------------------------------------------------


def test_paged_engine_token_identical_ragged(cfg, params):
    """Ragged prompt lengths (block_size divides none of them) + sampled
    temperature: the paged engine must reproduce the contiguous engine's
    outputs exactly."""
    lens, gens = [5, 9, 13, 7, 10, 3], [4, 6, 2, 5, 7, 3]
    kw = dict(max_slots=3, temperature=0.7, seed=3)
    a = ServeEngine(cfg, params, _contig(**kw)).run(_requests(cfg, lens, gens))
    eng = ServeEngine(cfg, params, _paged(**kw))
    b = eng.run(_requests(cfg, lens, gens))
    assert a == b
    eng.allocator.assert_consistent()
    assert eng.allocator.num_used == 0      # every table was freed


def test_paged_default_pool_matches_contiguous_capacity(cfg, params):
    eng = ServeEngine(cfg, params, _paged())    # kv_blocks=0 → auto
    assert eng.allocator.capacity == 2 * (24 // 4)


def test_paged_rejects_block_size_not_dividing_max_len(cfg, params):
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(cfg, params, _paged(max_len=22))


def test_paged_rejects_request_larger_than_pool(cfg, params):
    eng = ServeEngine(cfg, params, _paged(kv_blocks=4))   # 3 usable blocks
    with pytest.raises(ValueError, match="worst case"):
        eng.submit(_requests(cfg, [10], [8]))
    assert eng.metrics.requests == {} and len(eng.queue) == 0


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------------


def test_prefix_sharing_hits_and_outputs_identical(cfg, params):
    """Identical prompts admitted over time share published blocks (the
    gauge shows hits) without perturbing outputs."""
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=(12,)).tolist()
    mk = lambda: [Request(req_id=i, prompt=list(prompt), max_new_tokens=4)  # noqa: E731
                  for i in range(6)]
    eng = ServeEngine(cfg, params, _paged(kv_blocks=40))
    out = eng.run(mk())
    assert eng.metrics.prefix_hit_tokens > 0
    assert 0 < eng.metrics.prefix_hit_rate < 1
    eng.allocator.assert_consistent()
    cont = ServeEngine(cfg, params, _contig()).run(mk())
    assert out == cont


def test_cow_on_prefix_hit_tail_rewrite(cfg, params):
    """plen 12, chunk 4, block 4: a full-block prefix hit restarts prefill
    at the grid point 8, and the right-aligned tail [8,12) rewrites the
    hit's last shared block — which must be copy-on-written, leaving the
    original's bits (and the first request's recorded output) intact."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=(12,)).tolist()
    mk = lambda: [Request(req_id=i, prompt=list(prompt),  # noqa: E731
                          max_new_tokens=6) for i in range(2)]
    # one slot: strictly sequential, so request 1 hits request 0's blocks
    eng = ServeEngine(cfg, params, _paged(max_slots=1, kv_blocks=40))
    out = eng.run(mk())
    assert eng.metrics.prefix_hit_tokens == 8       # pos0 = 8 of plen 12
    eng.allocator.assert_consistent()
    cont = ServeEngine(cfg, params, _contig(max_slots=1)).run(mk())
    assert out == cont


# ---------------------------------------------------------------------------
# preemption: pool runs dry mid-decode → youngest requeued, outputs intact
# ---------------------------------------------------------------------------


def test_preempt_and_requeue_token_identical(cfg, params):
    """A pool too small for three growing requests must preempt (youngest
    first), requeue, and still emit exactly the contiguous outputs."""
    lens, gens = [8, 8, 8], [12, 10, 8]
    kw = dict(max_slots=3, max_len=32, temperature=0.6, seed=9)
    eng = ServeEngine(cfg, params, _paged(kv_blocks=11, **kw))  # 10 usable
    out = eng.run(_requests(cfg, lens, gens, seed=5))
    assert eng.metrics.preemptions >= 1
    ref = ServeEngine(cfg, params, _contig(**kw)).run(
        _requests(cfg, lens, gens, seed=5))
    assert out == ref
    eng.allocator.assert_consistent()
    assert eng.allocator.num_used == 0
    s = eng.metrics.summary()
    assert s["completed"] == 3 and s["preemptions"] == eng.metrics.preemptions
    # the discarded decode work is booked EXACTLY: every decode-step token
    # either reached a surviving output (tokens_out minus the prefill-born
    # first tokens) or landed in wasted_decode_tokens
    assert s["wasted_decode_tokens"] > 0
    assert eng.metrics.decode_tokens == \
        (s["tokens_out"] - s["first_tokens"]) + s["wasted_decode_tokens"]


def test_preempt_resets_request_record(cfg, params):
    """After a preempt-requeue cycle every request still reports exactly
    its budgeted tokens (the re-serve must not double-count)."""
    lens, gens = [8, 8, 8], [12, 10, 8]
    eng = ServeEngine(cfg, params,
                      _paged(max_slots=3, max_len=32, kv_blocks=11))
    out = eng.run(_requests(cfg, lens, gens, seed=5))
    assert eng.metrics.preemptions >= 1
    for i, g in enumerate(gens):
        assert len(out[i]) == g
        assert eng.metrics.requests[i].tokens_out == g
    # wasted accounting survives the reset: per-request tokens_out restart
    # at zero on preemption, but the decode-step tally keeps every token
    s = eng.metrics.summary()
    assert eng.metrics.decode_tokens == \
        (s["tokens_out"] - s["first_tokens"]) + s["wasted_decode_tokens"]
    assert s["wasted_decode_tokens"] > 0


# ---------------------------------------------------------------------------
# EOS threading: wave / continuous / paged terminate identically
# ---------------------------------------------------------------------------


def test_eos_consistent_across_modes(cfg, params):
    """--eos-id must cut generation at the same token in every serving
    mode (wave baseline, continuous contiguous, continuous paged)."""
    lens, gens = [6] * 3, [8] * 3
    probe = ServeEngine(cfg, params, _contig()).run(
        _requests(cfg, lens, gens, seed=5))
    eos = probe[0][1]           # greedy: request 0's second token is stable
    kw = dict(eos_id=eos)
    cont = ServeEngine(cfg, params, _contig(**kw)).run(
        _requests(cfg, lens, gens, seed=5))
    paged = ServeEngine(cfg, params, _paged(**kw)).run(
        _requests(cfg, lens, gens, seed=5))
    wave, _ = serve_waves(cfg, params, _contig(**kw),
                          _requests(cfg, lens, gens, seed=5))
    assert cont == paged == wave
    assert cont[0][-1] == eos and len(cont[0]) == 2


# ---------------------------------------------------------------------------
# host plumbing: paged slot table + gauges
# ---------------------------------------------------------------------------


def test_paged_slot_table_block_tables_padding():
    table = SlotTable(max_slots=3, max_len=16, block_size=4)
    assert table.n_max == 4
    s0 = table.slots[0]
    table.assign(s0, Request(req_id=1, prompt=[1, 2, 3], max_new_tokens=2))
    s0.blocks = [5, 7]
    bt = table.block_tables()
    assert bt.shape == (3, 4)
    assert bt[0].tolist() == [5, 7, SENTINEL, SENTINEL]
    assert (bt[1:] == SENTINEL).all()
    row = table.block_table_row(s0)
    assert row.shape == (1, 4) and row[0].tolist() == [5, 7, 0, 0]
    # masked rows write to the virtual sentinel position
    _, offsets, active, _, _ = table.decode_inputs()
    assert offsets[1] == offsets[2] == 15
    assert not active.any()


def test_release_with_live_blocks_raises():
    table = SlotTable(max_slots=1, max_len=16, block_size=4)
    s0 = table.slots[0]
    table.assign(s0, Request(req_id=1, prompt=[1, 2], max_new_tokens=2))
    s0.blocks = [3]
    with pytest.raises(RuntimeError, match="live"):
        table.release(s0)
    s0.blocks = []
    table.release(s0)


def test_paged_metrics_gauges_in_report(cfg, params):
    eng = ServeEngine(cfg, params, _paged(kv_blocks=20))
    eng.run(_requests(cfg, [6, 9], [3, 4], seed=6))
    s = eng.metrics.summary()
    assert s["blocks_total"] == 19
    assert s["blocks_peak"] > 0
    assert s["blocks_in_use"] == 0          # drained
    assert s["peak_active"] >= 1
    assert "paged" in eng.metrics.report()
    # the contiguous engine never shows the paged line
    cont = ServeEngine(cfg, params, _contig())
    cont.run(_requests(cfg, [6], [2], seed=6))
    assert "paged" not in cont.metrics.report()


# ---------------------------------------------------------------------------
# ragged multi-token paged writes (vector offset, T > 1)
# ---------------------------------------------------------------------------


def test_paged_scatter_ragged_vector_offsets_multi_token():
    """A [B] offset vector with T > 1 writes each row's span at its own
    start — identical to per-row scalar scatters, with out-of-span tail
    positions redirected to the sentinel block."""
    from repro.models.layers import paged_scatter
    B, T, n, bs, N = 3, 3, 2, 4, 8
    rng = np.random.default_rng(4)
    pool = jnp.zeros((N, bs, 2), jnp.float32)
    new = jnp.asarray(rng.normal(size=(B, T, 2)), dtype=jnp.float32)
    tables = jnp.asarray([[1, 4], [2, 5], [3, 6]], jnp.int32)
    offs = np.asarray([0, 3, 6], np.int32)   # row 1 straddles a block edge,
                                             # row 2 runs past the span
    ragged = paged_scatter(pool, new, tables, jnp.asarray(offs))
    oracle = pool
    for b in range(B):
        oracle = paged_scatter(oracle, new[b:b + 1], tables[b:b + 1],
                               jnp.asarray(offs[b]))
    assert np.array_equal(np.asarray(ragged), np.asarray(oracle))
    # in-span values landed at their virtual positions...
    from repro.models.layers import paged_gather
    view = np.asarray(paged_gather(ragged, tables))
    for b in range(B):
        for t in range(T):
            p = offs[b] + t
            if p < n * bs:
                assert np.array_equal(view[b, p], np.asarray(new[b, t]))
    # ...and row 2's overflow (positions 8) hit only the sentinel block
    untouched = [i for i in range(1, N) if i not in (3, 6)
                 and i not in (1, 4, 2, 5)]
    assert np.asarray(ragged)[untouched].sum() == 0


# ---------------------------------------------------------------------------
# fused paged-attention decode kernel: token identity with the ref lowering
# ---------------------------------------------------------------------------


def test_paged_engine_fused_kernel_token_identical(cfg, params):
    """paged_kernel="pallas" (fused block-table decode kernel, interpret
    mode on CPU) must emit exactly the tokens of paged_kernel="ref" (the
    gather-then-attend oracle) — ragged lengths, sampled temperature."""
    lens, gens = [5, 9, 13, 7], [4, 6, 2, 5]
    kw = dict(max_slots=3, temperature=0.7, seed=3)
    a = ServeEngine(cfg, params, _paged(paged_kernel="ref", **kw)).run(
        _requests(cfg, lens, gens))
    b = ServeEngine(cfg, params, _paged(paged_kernel="pallas", **kw)).run(
        _requests(cfg, lens, gens))
    assert a == b


def test_paged_engine_fused_kernel_token_identical_mla():
    """Same invariant through the MLA absorbed-decode kernel (latent
    pools, fused q_eff/W_uv absorption)."""
    mcfg = get_config("deepseek-v3-671b-smoke")
    mparams = T.init_params(mcfg, jax.random.key(0))
    lens, gens = [5, 9, 6], [3, 4, 3]
    a = ServeEngine(mcfg, mparams, _paged(paged_kernel="ref")).run(
        _requests(mcfg, lens, gens))
    b = ServeEngine(mcfg, mparams, _paged(paged_kernel="pallas")).run(
        _requests(mcfg, lens, gens))
    assert a == b


def test_paged_kernel_auto_resolves_ref_off_tpu(cfg, params):
    import jax as _jax
    eng = ServeEngine(cfg, params, _paged())          # paged_kernel="auto"
    if _jax.default_backend() != "tpu":
        assert eng.paged_kernel == "ref"
    else:
        assert eng.paged_kernel in ("pallas", "ref")


def test_paged_kernel_rejects_unknown(cfg, params):
    with pytest.raises(ValueError, match="paged_kernel"):
        ServeEngine(cfg, params, _paged(paged_kernel="cuda"))
