"""Property suite for the paged-KV block allocator.

Model-based testing: random interleavings of the allocator's whole API
(admission-style alloc+publish, free, fork, copy-on-write, prefix match)
against a shadow model of table→block references.  After every op:

  * ``assert_consistent`` — free / cached / live partition the pool, the
    prefix index points only at live-or-cached blocks, the sentinel is
    never handed out;
  * every LIVE block's refcount equals the number of table references the
    shadow model holds (so alloc/free/fork can never double-free or leak);
  * freed blocks are reusable: draining every table returns the pool to
    full capacity.

Uses real ``hypothesis`` when installed (requirements-dev.txt); the
deterministic fixed-seed stub otherwise (see ``tests/_hypothesis_stub.py``).
"""

from collections import Counter

import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.blocks import (BlockAllocator, NoFreeBlocks, SENTINEL)


# ---------------------------------------------------------------------------
# deterministic unit coverage
# ---------------------------------------------------------------------------


def test_rejects_degenerate_pools():
    with pytest.raises(ValueError):
        BlockAllocator(1, 4)            # sentinel only
    with pytest.raises(ValueError):
        BlockAllocator(4, 0)


def test_sentinel_never_allocated():
    a = BlockAllocator(5, 4)
    got = [a.alloc() for _ in range(a.capacity)]
    assert SENTINEL not in got
    assert sorted(got) == [1, 2, 3, 4]
    with pytest.raises(NoFreeBlocks):
        a.alloc()


def test_double_free_raises():
    a = BlockAllocator(4, 4)
    b = a.alloc()
    assert a.decref(b)
    with pytest.raises(RuntimeError, match="double free"):
        a.decref(b)
    a.assert_consistent()


def test_freed_blocks_are_reusable():
    a = BlockAllocator(3, 2)
    b1, b2 = a.alloc(), a.alloc()
    with pytest.raises(NoFreeBlocks):
        a.alloc()
    a.decref(b1)
    b3 = a.alloc()              # the freed block comes back
    assert b3 == b1
    a.free_blocks([b2, b3])
    assert a.num_free == a.capacity and a.num_used == 0


def test_fork_shares_and_free_unwinds():
    a = BlockAllocator(6, 2)
    blocks = [a.alloc(), a.alloc()]
    forked = a.fork(blocks)
    assert forked == blocks
    assert all(a.refcount(b) == 2 for b in blocks)
    a.free_blocks(forked)
    assert all(a.refcount(b) == 1 for b in blocks)
    a.free_blocks(blocks)
    assert a.num_used == 0 and a.num_free == a.capacity
    a.assert_consistent()


def test_cow_private_is_noop_shared_copies():
    a = BlockAllocator(6, 2)
    b = a.alloc()
    assert a.cow(b) == (b, False)           # refcount 1: already writable
    a.incref(b)
    nb, copied = a.cow(b)
    assert copied and nb != b
    assert a.refcount(b) == 1 and a.refcount(nb) == 1
    a.free_blocks([b, nb])
    a.assert_consistent()


def test_cow_pool_dry_leaves_state_intact():
    a = BlockAllocator(2, 2)                # one usable block
    b = a.alloc()
    a.incref(b)
    with pytest.raises(NoFreeBlocks):
        a.cow(b)
    assert a.refcount(b) == 2               # nothing half-done
    a.assert_consistent()


def test_publish_match_and_retention():
    a = BlockAllocator(8, 2)
    prompt = [1, 2, 3, 4, 5]                # 2 full blocks + a tail token
    keys = a.prefix_keys(prompt)
    assert keys == [(1, 2), (1, 2, 3, 4)]
    blocks = [a.alloc() for _ in range(3)]
    for b, k in zip(blocks, keys):
        assert a.publish(b, k)
    # concurrent identical prompt: shares the two published blocks
    m = a.match_prefix(prompt)
    assert m == blocks[:2]
    assert all(a.refcount(b) == 2 for b in m)
    a.free_blocks(m)
    # retention: freeing the ORIGINAL keeps published blocks cached and
    # revivable — a later identical prompt still hits
    a.free_blocks(blocks)
    assert a.num_used == 0 and a.num_cached == 2
    assert a.num_free == a.capacity         # cached blocks are allocatable
    m2 = a.match_prefix(prompt)
    assert m2 == blocks[:2] and all(a.refcount(b) == 1 for b in m2)
    a.free_blocks(m2)
    a.assert_consistent()


def test_publish_first_writer_wins():
    a = BlockAllocator(6, 2)
    b1, b2 = a.alloc(), a.alloc()
    assert a.publish(b1, (7, 8))
    assert not a.publish(b2, (7, 8))        # key taken: b2 stays private
    a.free_blocks([b1, b2])
    assert a.num_cached == 1                # only the published one retained
    a.assert_consistent()


def test_eviction_unpublishes_oldest_cached():
    a = BlockAllocator(3, 2)                # two usable blocks
    b1, b2 = a.alloc(), a.alloc()
    a.publish(b1, (1, 1))
    a.publish(b2, (2, 2))
    a.free_blocks([b1, b2])                 # both cached, b1 older
    c1 = a.alloc()                          # evicts b1 (FIFO)
    assert c1 == b1
    assert a.match_prefix([1, 1]) == []     # b1's entry is gone
    m = a.match_prefix([2, 2])              # b2 still revivable
    assert m == [b2]
    a.free_blocks([c1] + m)
    a.assert_consistent()


def test_blocks_for():
    a = BlockAllocator(4, 8)
    assert a.blocks_for(1) == 1
    assert a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2
    assert a.blocks_for(17) == 3


# ---------------------------------------------------------------------------
# property suite: random op interleavings vs a shadow reference model
# ---------------------------------------------------------------------------


def _check_refcounts(alloc, tables):
    """Every live block's refcount must equal the table references held."""
    refs = Counter(b for blocks, _ in tables for b in blocks)
    for b, n in refs.items():
        assert alloc.refcount(b) == n, f"block {b}: {alloc.refcount(b)} != {n}"
    live = alloc.num_used
    assert live == len(refs), f"{live} live blocks but {len(refs)} referenced"


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_allocator_random_ops_maintain_invariants(data):
    nb = data.draw(st.integers(min_value=3, max_value=20), label="nb")
    bs = data.draw(st.integers(min_value=1, max_value=4), label="bs")
    a = BlockAllocator(nb, bs)
    tables = []     # shadow model: (blocks, prompt) pairs we hold refs on
    n_ops = data.draw(st.integers(min_value=1, max_value=50), label="n_ops")
    for _ in range(n_ops):
        op = data.draw(st.sampled_from(
            ["admit", "free", "fork", "cow", "probe"]), label="op")
        if op == "admit":
            # admission flow: match the prefix cache, allocate the tail,
            # publish the full prompt blocks (tiny alphabet → collisions)
            plen = data.draw(st.integers(min_value=1, max_value=3 * bs))
            prompt = [data.draw(st.integers(min_value=0, max_value=2))
                      for _ in range(plen)]
            matched = a.match_prefix(prompt)
            fresh = a.blocks_for(plen) - len(matched)
            if fresh > a.num_free:
                a.free_blocks(matched)          # deferred admission
            else:
                blocks = matched + [a.alloc() for _ in range(fresh)]
                for blk, key in zip(blocks, a.prefix_keys(prompt)):
                    a.publish(blk, key)
                tables.append((blocks, prompt))
        elif op == "free" and tables:
            i = data.draw(st.integers(min_value=0, max_value=len(tables) - 1))
            blocks, _ = tables.pop(i)
            a.free_blocks(blocks)
        elif op == "fork" and tables:
            i = data.draw(st.integers(min_value=0, max_value=len(tables) - 1))
            blocks, prompt = tables[i]
            tables.append((a.fork(blocks), prompt))
        elif op == "cow" and tables:
            i = data.draw(st.integers(min_value=0, max_value=len(tables) - 1))
            blocks, prompt = tables[i]
            if blocks:
                j = data.draw(st.integers(min_value=0,
                                          max_value=len(blocks) - 1))
                try:
                    nb_, _copied = a.cow(blocks[j])
                    blocks[j] = nb_
                except NoFreeBlocks:
                    pass                        # state must stay intact
        elif op == "probe":
            # a lookup the caller abandons must be reference-neutral
            plen = data.draw(st.integers(min_value=1, max_value=2 * bs))
            prompt = [data.draw(st.integers(min_value=0, max_value=2))
                      for _ in range(plen)]
            a.free_blocks(a.match_prefix(prompt))
        a.assert_consistent()
        _check_refcounts(a, tables)

    # drain: every freed block is reusable, nothing leaks
    for blocks, _ in tables:
        a.free_blocks(blocks)
    a.assert_consistent()
    assert a.num_used == 0
    assert a.num_free == a.capacity
    got = sorted(a.alloc() for _ in range(a.capacity))
    assert got == list(range(1, nb))            # every block came back
