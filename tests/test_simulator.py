"""Table 1 reproduction: FSync exact, AMO baselines calibrated, speedups."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.simulator import (DEFAULT_PARAMS, FractalSyncSim,
                                  NaiveBarrier, PAPER_TABLE1, XYBarrier,
                                  simulate_config, table1)
from repro.core.tree import FractalTree


@pytest.fixture(scope="module")
def t1():
    return table1()


def test_fsync_columns_exact(t1):
    for name, row in t1.items():
        fsync, fsync_p, *_ = PAPER_TABLE1[name]
        assert row["fsync"] == fsync, name
        assert row["fsync_p"] == fsync_p, name


def test_amo_baselines_within_band(t1):
    # calibrated event sim: every AMO number within [0.6, 1.45]× of paper
    for name, row in t1.items():
        _, _, naive, xy, _ = PAPER_TABLE1[name]
        assert 0.6 <= row["naive"] / naive <= 1.45, (name, row["naive"], naive)
        assert 0.6 <= row["xy"] / xy <= 1.45, (name, row["xy"], xy)


def test_speedup_reproduced(t1):
    # headline claims: ≥15× everywhere, growing with mesh size, ≥35× at 16×16
    sp = {name: row["speedup"] for name, row in t1.items()}
    for name, s in sp.items():
        assert s >= 15.0, (name, s)
    assert sp["16x16"] > sp["2x2"]
    assert sp["16x16"] >= 35.0


def test_naive_beats_xy_small_then_loses(t1):
    # paper observation (iii)
    assert t1["2x2"]["naive"] < t1["2x2"]["xy"]
    assert t1["16x16"]["naive"] > t1["16x16"]["xy"]


def test_fsync_event_sim_matches_analytic():
    for shape in ((1, 2), (2, 2), (4, 4), (8, 8), (16, 16)):
        tree = FractalTree(shape)
        for pipelined in (False, True):
            sim = FractalSyncSim(tree, pipelined=pipelined)
            overhead, _ = sim.run()
            assert overhead == tree.fsync_latency(pipelined=pipelined)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([(2, 2), (4, 4), (8, 8)]), st.data())
def test_fsync_skewed_arrivals(shape, data):
    """Barrier correctness under skew: nobody wakes before the last request
    could have reached the root; overhead ≤ analytic latency."""
    tree = FractalTree(shape)
    tiles = list(tree.tiles())
    reqs = {t: data.draw(st.integers(0, 50)) for t in tiles}
    sim = FractalSyncSim(tree)
    overhead, finish = sim.run(requests=reqs)
    last = max(reqs.values())
    lat = tree.fsync_latency()
    for t, f in finish.items():
        assert f >= last + 2          # wake cannot precede slowest request
    assert overhead == lat            # Ŝ is skew-invariant by definition


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1, 2), (2, 2), (4, 4)]), st.data())
def test_amo_barrier_correctness_under_skew(shape, data):
    """No tile may pass a (correct) barrier before every tile requested."""
    rows, cols = shape
    tiles = [(r, c) for r in range(rows) for c in range(cols)]
    reqs = {t: data.draw(st.integers(0, 40)) for t in tiles}
    sim = NaiveBarrier(rows, cols, DEFAULT_PARAMS)
    sim.run(requests=dict(reqs))
    last = max(reqs.values())
    for t, f in sim.finish.items():
        assert f > last
    assert set(sim.finish) == set(tiles)


def test_fsync_partial_level_domains():
    tree = FractalTree((4, 4))
    sim = FractalSyncSim(tree)
    # sync only level 2 (groups of 4): latency = 2 + 2·2
    overhead, _ = sim.run(level=2)
    assert overhead == tree.fsync_latency(level=2) == 6


def test_amo_schemes_scale_as_paper_claims():
    """Naive superlinear, XY ~linear in k (scalability claim §4.1)."""
    n4 = NaiveBarrier(4, 4, DEFAULT_PARAMS).run()
    n8 = NaiveBarrier(8, 8, DEFAULT_PARAMS).run()
    x4 = XYBarrier(4, 4, DEFAULT_PARAMS).run()
    x8 = XYBarrier(8, 8, DEFAULT_PARAMS).run()
    assert n8 / n4 > 3.0              # ≥ linear-in-tiles growth
    assert x8 / x4 < 3.0              # sub-quadratic growth
