"""Fault tolerance: monitor, stragglers, surviving fsync domains, elastic."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tree import FractalTree
from repro.runtime.elastic import plan_recovery
from repro.runtime.fault_tolerance import (HostMonitor, StragglerTracker,
                                           surviving_domain)


def test_host_monitor_detects_timeouts():
    m = HostMonitor(num_hosts=4, timeout_s=10.0)
    for h in range(4):
        m.heartbeat(h, now=100.0)
    assert m.failed_hosts(now=105.0) == set()
    m.heartbeat(0, now=111.0)
    m.heartbeat(1, now=111.0)
    assert m.failed_hosts(now=115.0) == {2, 3}
    assert not m.healthy(now=115.0)


def test_straggler_detection_and_rebalance():
    t = StragglerTracker(window=8, threshold=1.5)
    for step in range(8):
        for rank in range(4):
            t.record(rank, 1.0 if rank != 3 else 2.5)
    assert t.stragglers() == {3}
    shares = t.rebalanced_shares([0, 1, 2, 3], total_microbatches=16)
    assert sum(shares.values()) == 16
    assert shares[3] < shares[0]
    assert min(shares.values()) >= 1


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([(4, 4), (8, 8), (2, 4)]), st.data())
def test_surviving_domain_properties(shape, data):
    tree = FractalTree(shape)
    tiles = list(tree.tiles())
    failed = set(data.draw(st.lists(st.sampled_from(tiles), min_size=0,
                                    max_size=len(tiles) - 1, unique=True)))
    level, domain = surviving_domain(tree, failed)
    assert not failed.intersection(domain)
    assert len(domain) == tree.domain_size(level)        # complete subtree
    # maximality: no fully-clean domain exists at level+1
    if level < tree.num_levels:
        for d in tree.domains(level + 1):
            assert failed.intersection(d)


def test_plan_recovery_scales_accumulation():
    tree = FractalTree((4, 4))
    plan = plan_recovery(tree, failed=[(0, 0)])
    assert plan.world == 8
    assert plan.grad_accum_scale == 2          # keep the global batch
    assert np.prod(plan.mesh_shape) == plan.world


def test_no_survivors_raises():
    tree = FractalTree((1, 2))
    with pytest.raises(RuntimeError):
        surviving_domain(tree, failed=list(tree.tiles()))
