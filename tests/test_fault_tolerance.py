"""Fault tolerance: monitor, stragglers, surviving fsync domains, elastic."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tree import FractalTree
from repro.runtime.elastic import plan_recovery
from repro.runtime.fault_tolerance import (HostMonitor, StragglerTracker,
                                           surviving_domain)


def test_host_monitor_detects_timeouts():
    m = HostMonitor(num_hosts=4, timeout_s=10.0)
    for h in range(4):
        m.heartbeat(h, now=100.0)
    assert m.failed_hosts(now=105.0) == set()
    m.heartbeat(0, now=111.0)
    m.heartbeat(1, now=111.0)
    assert m.failed_hosts(now=115.0) == {2, 3}
    assert not m.healthy(now=115.0)


def test_straggler_detection_and_rebalance():
    t = StragglerTracker(window=8, threshold=1.5)
    for step in range(8):
        for rank in range(4):
            t.record(rank, 1.0 if rank != 3 else 2.5)
    assert t.stragglers() == {3}
    shares = t.rebalanced_shares([0, 1, 2, 3], total_microbatches=16)
    assert sum(shares.values()) == 16
    assert shares[3] < shares[0]
    assert min(shares.values()) >= 1


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([(4, 4), (8, 8), (2, 4)]), st.data())
def test_surviving_domain_properties(shape, data):
    tree = FractalTree(shape)
    tiles = list(tree.tiles())
    failed = set(data.draw(st.lists(st.sampled_from(tiles), min_size=0,
                                    max_size=len(tiles) - 1, unique=True)))
    level, domain = surviving_domain(tree, failed)
    assert not failed.intersection(domain)
    assert len(domain) == tree.domain_size(level)        # complete subtree
    # maximality: no fully-clean domain exists at level+1
    if level < tree.num_levels:
        for d in tree.domains(level + 1):
            assert failed.intersection(d)


def test_plan_recovery_scales_accumulation():
    tree = FractalTree((4, 4))
    plan = plan_recovery(tree, failed=[(0, 0)])
    assert plan.world == 8
    assert plan.grad_accum_scale == 2          # keep the global batch
    assert np.prod(plan.mesh_shape) == plan.world


def test_no_survivors_raises():
    tree = FractalTree((1, 2))
    with pytest.raises(RuntimeError):
        surviving_domain(tree, failed=list(tree.tiles()))


# ---------------------------------------------------------------------------
# rebalanced_shares: regression + property suite
# ---------------------------------------------------------------------------


def test_rebalance_fewer_microbatches_than_ranks_raises():
    """Regression: total < len(ranks) used to spin forever in the drift
    loop (every share clamped at 1 with the sum still above the target)."""
    t = StragglerTracker()
    with pytest.raises(ValueError, match="micro-batches"):
        t.rebalanced_shares([0, 1, 2, 3], total_microbatches=3)
    with pytest.raises(ValueError, match="at least one rank"):
        t.rebalanced_shares([], total_microbatches=4)
    # the boundary case terminates: one micro-batch per rank
    assert t.rebalanced_shares([0, 1, 2], 3) == {0: 1, 1: 1, 2: 1}


@settings(max_examples=60, deadline=None)
@given(
    durations=st.lists(st.floats(min_value=0.05, max_value=50.0),
                       min_size=1, max_size=12),
    extra=st.integers(min_value=0, max_value=40),
)
def test_rebalanced_shares_properties(durations, extra):
    """∀ measured speeds: every share ≥ 1, the sum is exactly the total,
    strictly faster ranks never get fewer micro-batches, and the drift
    loop terminates (the call returns at all)."""
    t = StragglerTracker(window=4)
    for rank, d in enumerate(durations):
        for _ in range(3):
            t.record(rank, d)
    ranks = list(range(len(durations)))
    total = len(ranks) + extra
    shares = t.rebalanced_shares(ranks, total)
    assert set(shares) == set(ranks)
    assert all(s >= 1 for s in shares.values())
    assert sum(shares.values()) == total
    for a in ranks:
        for b in ranks:
            if durations[a] < durations[b]:       # a strictly faster
                assert shares[a] >= shares[b], (
                    f"faster rank {a} ({durations[a]}s) got {shares[a]} < "
                    f"slower rank {b} ({durations[b]}s) with {shares[b]}")


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([(2, 2), (2, 4), (4, 4), (8, 8)]), st.data(),
       st.sampled_from([1, 2, 4]))
def test_plan_recovery_properties(shape, data, accum_per_rank):
    """∀ failure sets: survivors form a complete fsync subtree and
    grad_accum_scale × surviving world covers the old world's work
    (global batch preserved whenever old_world divides evenly)."""
    tree = FractalTree(shape)
    tiles = list(tree.tiles())
    failed = set(data.draw(st.lists(st.sampled_from(tiles), min_size=1,
                                    max_size=len(tiles) - 1, unique=True)))
    plan = plan_recovery(tree, failed)
    assert tuple(plan.tiles) in [tuple(d) for d in tree.domains(plan.level)]
    assert not failed.intersection(plan.tiles)
    assert plan.world == tree.domain_size(plan.level)
    assert np.prod(plan.mesh_shape) == plan.world
    # both worlds are powers of two, so the scale is exact
    assert plan.grad_accum_scale * plan.world == tree.num_tiles
    old_batch = tree.num_tiles * accum_per_rank
    assert plan.world * (accum_per_rank * plan.grad_accum_scale) == old_batch
