"""Data pipeline: determinism, host sharding, prefetch."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models.registry import get_config

CFG = get_config("qwen2.5-3b-smoke")


def test_deterministic_across_instances():
    a = SyntheticLM(CFG, DataConfig(global_batch=4, seq_len=32, seed=7))
    b = SyntheticLM(CFG, DataConfig(global_batch=4, seq_len=32, seed=7))
    for step in (0, 1, 5):
        ba, bb = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_labels_are_shifted_tokens():
    d = SyntheticLM(CFG, DataConfig(global_batch=2, seq_len=16))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_different_steps_differ():
    d = SyntheticLM(CFG, DataConfig(global_batch=2, seq_len=64))
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])


def test_host_sharding_sizes():
    d0 = SyntheticLM(CFG, DataConfig(global_batch=8, seq_len=8, num_hosts=4,
                                     host_id=0))
    assert d0.local_batch == 2
    with pytest.raises(ValueError):
        SyntheticLM(CFG, DataConfig(global_batch=7, seq_len=8, num_hosts=4))


def test_vocab_range_and_zipf_shape():
    d = SyntheticLM(CFG, DataConfig(global_batch=4, seq_len=256))
    toks = d.batch(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < CFG.vocab_size
    # Zipfian: low ids much more frequent than high ids
    low = (toks < CFG.vocab_size // 10).mean()
    assert low > 0.3


def test_frontend_stub_for_vlm():
    cfg = get_config("paligemma-3b-smoke")
    d = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=8))
    b = d.batch(0)
    assert b["frontend"].shape == (2, cfg.frontend_tokens, cfg.frontend_dim)
    norms = np.linalg.norm(b["frontend"], axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)


def test_prefetcher_order_and_restart():
    d = SyntheticLM(CFG, DataConfig(global_batch=2, seq_len=8))
    pf = Prefetcher(d, start_step=3)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], d.batch(3)["tokens"])
    finally:
        pf.close()
