"""Property-based tests for the Schedule IR builders.

AccelSync-style coverage verification: synchronization schedules must hold
for *randomized* device counts and payload shapes, not just the happy-path
meshes the paper tables use.  Uses real ``hypothesis`` when installed
(requirements-dev.txt); the deterministic fixed-seed stub otherwise.

Two layers:

  * in-process: every generated Program passes ``validate``, and a dense
    numpy *executor* of the step graph (reduce=+=, copy=overwrite, BSP
    staging within a step) ends with every rank holding the exact integer
    sum of all contributions — the concrete counterpart of the validator's
    contribution-set abstract interpretation;
  * multi-device: ``ir_all_reduce`` (the shard_map+ppermute lowering) is
    compared against the dense reference reduction on an 8-device host
    mesh in a subprocess (``ir_property_checks.py``), so the rest of the
    suite keeps a single-device jax.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedule_ir as IR

ROOT = Path(__file__).resolve().parents[1]

POW2_SHAPES = [(2,), (4,), (8,), (16,), (32,), (2, 2), (2, 4), (4, 2),
               (4, 4), (8, 2), (2, 8), (8, 8), (2, 2, 2), (4, 2, 2)]
ANY_SHAPES = POW2_SHAPES + [(3,), (6,), (3, 2), (5,), (2, 3), (12,)]


def execute_dense(prog: IR.Program, payload: np.ndarray) -> np.ndarray:
    """Run a Program concretely: ``payload`` is [world, n_chunks, ...]; all
    sends in a step stage before any receive lands (BSP step semantics,
    matching ``validate``)."""
    state = payload.copy()
    for step in prog.steps:
        staged = [(t, state[t.src][list(t.chunks)].copy())
                  for t in step.transfers]
        for t, data in staged:
            idx = list(t.chunks)
            if t.reduce:
                state[t.dst][idx] += data
            else:
                state[t.dst][idx] = data
    return state


def _payload(rng, world: int, n_chunks: int, extra) -> np.ndarray:
    return rng.integers(-7, 8, size=(world, n_chunks, *extra)).astype(np.int64)


# ---------------------------------------------------------------------------
# every builder × randomized shapes: validator + dense execution
# ---------------------------------------------------------------------------


# one generated property test per schedule (a factory rather than
# pytest.mark.parametrize: @given-wrapped functions — stub or real — do not
# expose the parametrized argument in their signature)
def _shapes_for(schedule):
    return POW2_SHAPES if schedule in ("fractal", "hierarchical", "tree") \
        else ANY_SHAPES


def _make_reduce_property(schedule):
    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def prop(data):
        shape = data.draw(st.sampled_from(_shapes_for(schedule)))
        prog = IR.BUILDERS[schedule](shape)
        IR.validate(prog)
        world = prog.world
        # randomized payload element shape (the "payload shapes" axis)
        extra = data.draw(st.sampled_from([(), (1,), (3,), (2, 2)]))
        rng = np.random.default_rng(world * 7 + len(extra))
        payload = _payload(rng, world, prog.n_chunks, extra)
        out = execute_dense(prog, payload)
        want = payload.sum(axis=0)
        for r in range(world):
            np.testing.assert_array_equal(
                out[r], want, err_msg=f"{schedule} on {shape}, rank {r}")
    return prop


def _make_stats_property(schedule):
    @settings(max_examples=15, deadline=None)
    @given(data=st.data())
    def prop(data):
        shape = data.draw(st.sampled_from(_shapes_for(schedule)))
        prog = IR.BUILDERS[schedule](shape)
        stats = IR.validate(prog)
        assert stats["steps"] == prog.num_steps
        assert stats["messages"] == sum(len(s.transfers) for s in prog.steps)
        # nobody ships more than the serial-funnel worst case: (N−1)·V
        assert stats["max_frac_sent"] <= prog.world - 1 + 1e-9
    return prop


for _s in IR.SCHEDULES:
    globals()[f"test_{_s}_validates_and_reduces_exactly"] = \
        _make_reduce_property(_s)
    globals()[f"test_{_s}_validator_stats_match_structure"] = \
        _make_stats_property(_s)
del _s


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_barrier_builders_validate(data):
    name = data.draw(st.sampled_from(sorted(IR.BARRIER_BUILDERS)))
    shape = data.draw(st.sampled_from(
        POW2_SHAPES if name in ("fractal", "tree") else ANY_SHAPES))
    prog = IR.BARRIER_BUILDERS[name](shape)
    assert prog.kind == IR.BARRIER
    IR.validate(prog)


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_partial_level_barriers_cover_their_domains(data):
    """fsync(level) on a sub-root level: validation of the FULL world must
    fail (it is not a global barrier), but every 2^level-sized domain must
    internally know all members — checked via the dense executor."""
    shape = data.draw(st.sampled_from([(4,), (8,), (4, 4), (2, 2, 2)]))
    L = IR._check_pow2(shape)
    level = data.draw(st.integers(1, L))
    prog = IR.butterfly_barrier(shape, level)
    world = prog.world
    payload = np.zeros((world, 1), np.int64)
    payload[:, 0] = 1 << np.arange(world)     # rank bitmask as "knowledge"
    out = execute_dense(prog, payload)
    bits = IR.tree_bit_positions(shape)[:level]
    for r in range(world):
        domain = [c for c in range(world)
                  if all((c >> p) & 1 == (r >> p) & 1
                         for p in range(world.bit_length() - 1)
                         if p not in bits)]
        want = sum(1 << c for c in domain)
        assert out[r, 0] == want, (shape, level, r)


# ---------------------------------------------------------------------------
# the validator rejects broken schedules (mutation coverage)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", IR.SCHEDULES)
def test_validator_rejects_truncated_program(schedule):
    prog = IR.BUILDERS[schedule]((4, 4))
    if not prog.steps:
        pytest.skip("empty program")
    cut = IR.Program(prog.name, prog.shape, prog.n_chunks, prog.steps[:-1],
                     prog.kind)
    with pytest.raises(IR.ScheduleError):
        IR.validate(cut)


def test_validator_rejects_double_count():
    # send the same chunk to the same destination twice via two steps
    t1 = IR.Step((IR.Transfer(1, 0, (0,), reduce=True),))
    prog = IR.Program("bad", (2,), 1, (t1, t1))
    with pytest.raises(IR.ScheduleError, match="double-counted"):
        IR.validate(prog)


def test_validator_rejects_fan_in_for_all_reduce():
    step = IR.Step((IR.Transfer(1, 0, (0,), reduce=True),
                    IR.Transfer(2, 0, (1,), reduce=True)))
    prog = IR.Program("bad", (4,), 4, (step,))
    with pytest.raises(IR.ScheduleError, match="receives twice"):
        IR.validate(prog)


def test_validator_rejects_nonuniform_step_sizes():
    step = IR.Step((IR.Transfer(0, 1, (0, 1), reduce=True),
                    IR.Transfer(2, 3, (2,), reduce=True)))
    prog = IR.Program("bad", (4,), 4, (step,))
    with pytest.raises(IR.ScheduleError, match="nonuniform"):
        IR.validate(prog)


def test_executor_detects_what_validator_detects():
    """A schedule the validator rejects for double-counting really does
    compute a wrong sum when executed densely."""
    t1 = IR.Step((IR.Transfer(1, 0, (0,), reduce=True),))
    prog = IR.Program("bad", (2,), 1, (t1, t1))
    payload = np.asarray([[[1]], [[10]]], np.int64)
    out = execute_dense(prog, payload)
    assert out[0, 0, 0] == 21 != payload.sum(axis=0)[0, 0]   # 10 counted twice


# ---------------------------------------------------------------------------
# multi-device: ir_all_reduce vs dense reference (subprocess, 8 host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ir_lowering_matches_dense_reference_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "ir_property_checks.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ALL OK" in proc.stdout
