"""Tier-B (explicit fractal BSP, ZeRO-1) vs Tier-A (GSPMD/XLA) equivalence.

Same model, same data, 3 steps on 8 devices: loss trajectories must agree to
float tolerance — the H-tree schedule computes the same mean gradient as
XLA's all-reduce, and the ZeRO-1 flat update must match the pytree AdamW.
Also runs the BUCKETED pipelined superstep (tiny bucket_mb, per-bucket
autotuned schedules, grad accumulation) against the same trajectory: the
SuperstepEngine must be numerically equivalent to the monolithic path.
Run as a subprocess by tests/test_system.py.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.bsp import BSPConfig  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticLM  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.registry import get_config  # noqa: E402
from repro.models.sharding import named  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import trainer  # noqa: E402


def main():
    cfg = get_config("qwen2.5-3b-smoke")
    mesh = make_mesh((8, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100,
                             grad_clip=0.0)   # clip is per-shard in Tier B
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=32, seed=3))
    params0 = T.init_params(cfg, jax.random.key(0))

    def batches(n):
        return [{k: jnp.asarray(v) for k, v in data.batch(s).items()}
                for s in range(n)]

    # ---- Tier A (xla) ----
    stepA, (pspec, ospec, bspec) = trainer.make_gspmd_train_step(cfg, mesh,
                                                                 acfg)
    # device_put may zero-copy the local shard; copy first so Tier A's
    # donation cannot delete params0's buffers out from under Tier B
    pA = jax.device_put(jax.tree.map(jnp.array, params0), named(mesh, pspec))
    oA = adamw.init(pA, acfg)
    lossesA = []
    for b in batches(3):
        pA, oA, m = stepA(pA, oA, b)
        lossesA.append(float(np.asarray(m["loss"])))

    # ---- Tier B (fractal explicit) ----
    bsp = BSPConfig(sync_axes=("data",), schedule="fractal")
    stepB, init_state = trainer.make_bsp_train_step(cfg, mesh, acfg, bsp)
    state = init_state(params0)
    lossesB = []
    for b in batches(3):
        *state, m = stepB(*state, b)
        lossesB.append(float(np.asarray(m["loss"])))

    # ---- Tier B, bucketed pipelined superstep (SuperstepEngine) ----
    bspC = BSPConfig(sync_axes=("data",), schedule="auto", bucket_mb=0.25)
    stepC, init_stateC = trainer.make_bsp_train_step(cfg, mesh, acfg, bspC)
    stateC = init_stateC(params0)
    lossesC = []
    for b in batches(3):
        *stateC, m = stepC(*stateC, b)
        lossesC.append(float(np.asarray(m["loss"])))

    # ---- gradient accumulation: accum=2 on 2×batch == monolithic on 2×batch
    data16 = SyntheticLM(cfg, DataConfig(global_batch=16, seq_len=32, seed=7))
    batches16 = [{k: jnp.asarray(v) for k, v in data16.batch(s).items()}
                 for s in range(2)]
    bspD = BSPConfig(sync_axes=("data",), schedule="fractal", bucket_mb=0.25)
    stepD, init_stateD = trainer.make_bsp_train_step(cfg, mesh, acfg, bspD,
                                                     grad_accum=2)
    stateD = init_stateD(params0)
    lossesD = []
    for b in batches16:
        *stateD, m = stepD(*stateD, b)
        lossesD.append(float(np.asarray(m["loss"])))
    bspE = BSPConfig(sync_axes=("data",), schedule="fractal")
    stepE, init_stateE = trainer.make_bsp_train_step(cfg, mesh, acfg, bspE)
    stateE = init_stateE(params0)
    lossesE = []
    for b in batches16:
        *stateE, m = stepE(*stateE, b)
        lossesE.append(float(np.asarray(m["loss"])))

    print("xla       :", lossesA)
    print("fractal   :", lossesB)
    print("bucketed  :", lossesC)
    print("bucket+ga2:", lossesD)
    print("mono 2xB  :", lossesE)
    np.testing.assert_allclose(lossesA, lossesB, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(lossesB, lossesC, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(lossesE, lossesD, rtol=2e-4, atol=2e-4)
    print("EQUIVALENT")


if __name__ == "__main__":
    main()
