"""Optimizer + compression codec tests (unit + hypothesis property)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import adamw
from repro.optim.compression import (Bf16Codec, Int8Codec,
                                     error_feedback_step, quantization_error)


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, grad_clip=0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)
    assert max(lrs) <= 1.0 + 1e-6


def test_bf16_moments_memory():
    cfg32 = adamw.AdamWConfig(state_dtype=jnp.float32)
    cfg16 = adamw.AdamWConfig(state_dtype=jnp.bfloat16)
    assert adamw.optimizer_bytes_per_param(cfg16) < \
        adamw.optimizer_bytes_per_param(cfg32)
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    st16 = adamw.init(params, cfg16)
    assert st16.mu["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------ codecs -----

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 7), st.floats(0.1, 100.0))
def test_int8_codec_error_bound(blocks, scale):
    rng = np.random.default_rng(blocks)
    x = jnp.asarray(rng.normal(size=(blocks * 128,)).astype(np.float32)
                    * scale)
    codec = Int8Codec()
    err = quantization_error(x, codec)
    # per-block max error ≤ blockmax/127/2 … ≤ blockmax/127 with rounding
    xb = np.asarray(x).reshape(blocks, 128)
    bound = np.repeat(np.abs(xb).max(1) / 127.0, 128) * 0.5 + 1e-7
    assert (np.abs(np.asarray(err)) <= bound + 1e-6).all()


def test_bf16_codec_roundtrip():
    x = jnp.asarray(np.linspace(-3, 3, 256, dtype=np.float32))
    codec = Bf16Codec()
    err = quantization_error(x, codec)
    assert float(jnp.max(jnp.abs(err))) < 0.02
    assert codec.encode(x)["x"].dtype == jnp.bfloat16


def test_error_feedback_unbiased_over_time():
    """EF: the running sum of transmitted values tracks the running sum of
    true gradients (residual stays bounded)."""
    rng = np.random.default_rng(0)
    codec = Int8Codec()
    residual = jnp.zeros((256,), jnp.float32)
    true_sum = np.zeros(256)
    sent_sum = np.zeros(256)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        corrected, residual = error_feedback_step(g, residual, codec)
        sent = corrected - residual        # what the wire actually carries
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
        np.testing.assert_allclose(sent_sum + np.asarray(residual), true_sum,
                                   rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(residual)).max() < 0.2
