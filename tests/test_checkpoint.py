"""Checkpointing: atomic, async, keep-K, corrupt fallback, exact resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.optim import adamw


def _state(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.asarray(3.5)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(10, st, blocking=True)
    restored, meta = mgr.restore(st)
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, keep_every=10)
    for s in (1, 2, 3, 10, 11, 12):
        mgr.save(s, _state(), blocking=True)
    steps = mgr.steps()
    assert 10 in steps                  # keep_every ladder survives
    assert steps[-2:] == [11, 12]       # sliding window
    assert 1 not in steps and 2 not in steps


def test_corrupt_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    st = _state()
    mgr.save(1, st, blocking=True)
    mgr.save(2, jax.tree.map(lambda x: x + 1, st), blocking=True)
    # corrupt the newest file
    p = tmp_path / "step_2.ckpt"
    p.write_bytes(p.read_bytes()[:50])
    restored, meta = mgr.restore(st)
    assert meta["step"] == 1            # fell back to the good one


def test_no_partial_files_after_crashy_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    (tmp_path / "step_9.tmp-12345").write_bytes(b"partial garbage")
    mgr.save(9, _state(), blocking=True)
    restored, meta = mgr.restore(_state())
    assert meta["step"] == 9


@pytest.mark.slow
def test_exact_training_resume(tmp_path):
    """train 4 steps straight == train 2, restore, train 2 more (bitwise)."""
    cfg = get_config("qwen2.5-3b-smoke")
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    data = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=16))

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, cfg, batch)
        params, opt, _ = adamw.apply_updates(params, grads, opt, acfg)
        return params, opt, loss

    def run(n_steps, state, start=0):
        params, opt = state
        for s in range(start, n_steps):
            b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            params, opt, loss = step_fn(params, opt, b)
        return params, opt

    params0 = T.init_params(cfg, jax.random.key(1))
    opt0 = adamw.init(params0, acfg)

    pA, oA = run(4, (params0, opt0))

    mgr = CheckpointManager(str(tmp_path))
    p2, o2 = run(2, (params0, opt0))
    mgr.save(2, (p2, o2), blocking=True)
    (pr, orr), meta = mgr.restore((p2, o2))
    pB, oB = run(4, (pr, orr), start=2)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(oB.step) == int(oA.step)
