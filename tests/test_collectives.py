"""Multi-device collective schedule checks (subprocess: 16 host devices)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_collective_schedules_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "collective_checks.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ALL OK" in proc.stdout
