"""Continuous-batching engine correctness.

The three properties slot reuse stands on:

  * **prefill + N decode ≡ full forward** for ragged prompt lengths served
    from one batched cache with per-slot (vector) offsets;
  * **slot isolation**: resetting / re-admitting one slot leaves every
    other slot's logits BIT-identical (same-shape batched calls, rows are
    independent);
  * **RNG discipline**: token t of request r is sampled with
    ``fold_in(fold_in(seed_key, r), t)`` — deterministic per request,
    independent of admission order; the wave-era first-token-from-unsplit-
    key bug stays fixed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve import (EngineConfig, Request, ServeEngine, serve_waves)

ARCH = "gemma2-2b-smoke"


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.key(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,)).tolist() for n in lens]


def _requests(cfg, lens, gens, seed=0, arrivals=None):
    prompts = _prompts(cfg, lens, seed)
    return [Request(req_id=i, prompt=p, max_new_tokens=g,
                    arrival_s=0.0 if arrivals is None else arrivals[i])
            for i, (p, g) in enumerate(zip(prompts, gens))]


# ---------------------------------------------------------------------------
# architecture gating
# ---------------------------------------------------------------------------


def test_engine_serves_recurrent_arch():
    """Recurrent archs serve on the CONTINUOUS path (the SlotState row
    backend) — the historical ValueError is gone.  Full wave-vs-continuous
    token-identity coverage lives in test_serve_slot_state.py."""
    xcfg = get_config("xlstm-1.3b-smoke")
    xparams = T.init_params(xcfg, jax.random.key(0))
    ecfg = EngineConfig(max_slots=2, max_len=16, prefill_chunk=4)
    eng = ServeEngine(xcfg, xparams, ecfg)
    assert eng.plan.has_recurrent and not eng.plan.has_kv
    out = eng.run(_requests(xcfg, [4, 4], [3, 2]))
    assert sorted(out) == [0, 1]
    assert [len(out[0]), len(out[1])] == [3, 2]
    assert eng.metrics.summary()["completed"] == 2


def test_engine_rejects_frontend_arch():
    with pytest.raises(ValueError, match="frontend"):
        ServeEngine(get_config("paligemma-3b-smoke"), None, EngineConfig())


def test_wave_baseline_still_serves_recurrent_arch():
    """The wave loop batch-prefills without chunk padding, keeping
    recurrent caches exact by construction — it is the token-identity
    oracle the continuous recurrent path is checked against."""
    xcfg = get_config("xlstm-1.3b-smoke")
    xparams = T.init_params(xcfg, jax.random.key(0))
    ecfg = EngineConfig(max_slots=2, max_len=16)
    out, m = serve_waves(xcfg, xparams, ecfg,
                         _requests(xcfg, [4, 4], [3, 2]))
    assert sorted(out) == [0, 1]
    assert [len(out[0]), len(out[1])] == [3, 2]
    assert m.summary()["completed"] == 2


def test_prefill_chunk_rejects_blocked_attention_lengths(cfg, params):
    """Offset prefill must stay below the blocked-attention threshold whose
    static key extents assume positions start at 0."""
    from repro.models.layers import QUERY_CHUNK_THRESHOLD
    Tlen = QUERY_CHUNK_THRESHOLD
    cache = T.init_cache(cfg, 1, Tlen + 8)
    tokens = jnp.zeros((1, Tlen), jnp.int32)
    with pytest.raises(ValueError, match="blocked-attention"):
        T.prefill_chunk(params, cfg, tokens, cache,
                        jnp.asarray(0, jnp.int32))


def test_engine_rejects_oversize_request(cfg, params):
    eng = ServeEngine(cfg, params, EngineConfig(max_slots=1, max_len=8))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(_requests(cfg, [6], [4]))


def test_engine_submit_validates_whole_batch_first(cfg, params):
    """A bad request in a batch must not leave phantom metrics records or
    queued batchmates behind."""
    eng = ServeEngine(cfg, params, EngineConfig(max_slots=1, max_len=8))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(_requests(cfg, [3, 6], [4, 4]))   # second is oversize
    assert eng.metrics.requests == {}
    assert len(eng.queue) == 0


# ---------------------------------------------------------------------------
# prefill + decode ≡ forward, over ragged prompt lengths (vector offsets)
# ---------------------------------------------------------------------------


def test_prefill_then_decode_matches_forward_ragged(cfg, params):
    """Three slots at prompt lengths 5/9/12 share one batched cache; each
    is chunk-prefilled (C=4 exercises interior + right-aligned tail
    chunks), then all decode IN ONE CALL with per-slot vector offsets.
    Every step's logits must match the slot's own full-sequence forward."""
    lens, total, C, max_len = [5, 9, 12], 16, 4, 24
    rng = np.random.default_rng(1)
    seqs = [rng.integers(0, cfg.vocab_size, size=(total,)).astype(np.int32)
            for _ in lens]
    full = [np.asarray(T.forward(params, cfg, jnp.asarray(s)[None]))
            for s in seqs]

    cache = T.init_cache(cfg, len(lens), max_len)
    for i, L in enumerate(lens):
        sub = T.take_slot(cache, i)
        start = 0
        while start < L:
            if L <= C:
                chunk, off = np.zeros((1, C), np.int32), 0
                chunk[0, :L] = seqs[i][:L]
                start = L
            elif L - start > C:
                chunk, off = seqs[i][None, start:start + C], start
                start += C
            else:                       # right-aligned tail
                chunk, off = seqs[i][None, L - C:L], L - C
                start = L
            _, sub = T.prefill_chunk(params, cfg, jnp.asarray(chunk), sub,
                                     jnp.asarray(off, jnp.int32))
        cache = T.write_slot(cache, sub, i)

    offsets = np.asarray(lens, np.int32)
    got, want = [], []
    while (offsets < total).any():
        # feed each slot ITS OWN next token; finished slots re-feed their
        # last token at a frozen offset (masked by comparison selection)
        tok = np.asarray([seqs[i][min(offsets[i], total - 1)]
                          for i in range(len(lens))], np.int32)[:, None]
        logits, cache = T.decode_step(params, cfg, jnp.asarray(tok), cache,
                                      jnp.asarray(offsets))
        for i in range(len(lens)):
            if offsets[i] < total:
                got.append(np.asarray(logits[i, 0]))
                want.append(full[i][0, offsets[i]])
        offsets = np.minimum(offsets + 1, total)
    got, want = np.stack(got), np.stack(want)
    scale = np.abs(want).max() + 1e-6
    np.testing.assert_allclose(got, want, atol=2e-3 * scale, rtol=2e-2)


def test_chunked_prefill_matches_full_prefill(cfg, params):
    """Chunked (interior + right-aligned tail) admission == one-shot
    prefill: same cache contents, same last-position logits."""
    L, C, max_len = 10, 4, 16
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=(1, L)).astype(np.int32)

    ref_cache = T.init_cache(cfg, 1, max_len)
    ref_logits, ref_cache, _ = T.prefill(params, cfg, jnp.asarray(prompt),
                                         ref_cache, None)

    cache = T.init_cache(cfg, 1, max_len)
    for off in (0, 4, L - C):           # 0..3, 4..7, right-aligned 6..9
        chunk = prompt[:, off:off + C]
        logits, cache = T.prefill_chunk(params, cfg, jnp.asarray(chunk),
                                        cache, jnp.asarray(off, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(ref_cache)):
        # positions [0, L) hold the prompt in both (beyond L is scratch)
        np.testing.assert_allclose(np.asarray(a)[:, :, :L],
                                   np.asarray(b)[:, :, :L],
                                   rtol=2e-5, atol=2e-5)


def test_vector_offset_matches_scalar_offset(cfg, params):
    """A uniform offset vector must reproduce the scalar-offset decode
    bit-for-bit (same shapes, same math)."""
    B, P, max_len = 3, 6, 12
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, size=(B, P)).astype(np.int32)
    cache = T.init_cache(cfg, B, max_len)
    _, cache, off = T.prefill(params, cfg, jnp.asarray(prompts), cache, None)
    tok = rng.integers(0, cfg.vocab_size, size=(B, 1)).astype(np.int32)
    l_scalar, c_scalar = T.decode_step(params, cfg, jnp.asarray(tok), cache,
                                       off)
    l_vec, c_vec = T.decode_step(params, cfg, jnp.asarray(tok), cache,
                                 jnp.full((B,), int(off), jnp.int32))
    assert np.array_equal(np.asarray(l_scalar), np.asarray(l_vec))
    for a, b in zip(jax.tree.leaves(c_scalar), jax.tree.leaves(c_vec)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# per-slot cache surgery: isolation is bit-exact
# ---------------------------------------------------------------------------


def test_reset_slot_zeroes_only_that_slot(cfg):
    cache = T.init_cache(cfg, 3, 8)
    cache = jax.tree.map(lambda x: jnp.ones_like(x), cache)
    cache = T.reset_slot(cache, 1)
    for leaf in jax.tree.leaves(cache):
        x = np.asarray(leaf)
        assert (x[:, 1] == 0).all()
        assert (x[:, 0] == 1).all() and (x[:, 2] == 1).all()


def test_take_write_slot_roundtrip(cfg):
    cache = T.init_cache(cfg, 3, 8)
    cache = jax.tree.map(
        lambda x: jnp.arange(x.size, dtype=x.dtype).reshape(x.shape), cache)
    back = T.write_slot(cache, T.take_slot(cache, 2), 2)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_slot_reset_leaves_other_slots_logits_bit_identical(cfg, params):
    """THE slot-reuse correctness property: run 4 slots for a few decode
    steps; in a parallel universe slot 2 is reset and re-admitted with a
    different request.  Slots 0/1/3 must produce BIT-identical logits in
    both universes."""
    S, P, max_len, steps = 4, 6, 20, 4
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab_size, size=(S, P)).astype(np.int32)
    cache = T.init_cache(cfg, S, max_len)
    logits0, cache, off = T.prefill(params, cfg, jnp.asarray(prompts),
                                    cache, None)
    tok0 = np.asarray(jnp.argmax(logits0[:, -1], -1), np.int32)

    def decode_run(cache, first_tok, offsets):
        outs, tok = [], np.asarray(first_tok, np.int32)[:, None]
        offs = np.asarray(offsets, np.int32)
        for _ in range(steps):
            logits, cache = T.decode_step(params, cfg, jnp.asarray(tok),
                                          cache, jnp.asarray(offs))
            outs.append(np.asarray(logits[:, 0]))
            tok = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)[:, None]
            offs = offs + 1
        return np.stack(outs)

    # universe A: all four slots keep decoding their original requests
    outs_a = decode_run(cache, tok0, [P] * S)

    # universe B: slot 2 is reset and re-admitted with a NEW prompt (len 3,
    # chunk-prefilled), then everyone decodes together at ragged offsets
    new_prompt = rng.integers(0, cfg.vocab_size, size=(1, 3)).astype(np.int32)
    cache_b = T.reset_slot(cache, 2)
    sub = T.take_slot(cache_b, 2)
    nl, sub = T.prefill_chunk(params, cfg, jnp.asarray(new_prompt), sub,
                              jnp.asarray(0, jnp.int32))
    cache_b = T.write_slot(cache_b, sub, 2)
    tok_b = tok0.copy()
    tok_b[2] = int(jnp.argmax(nl[0, new_prompt.shape[1] - 1]))
    outs_b = decode_run(cache_b, tok_b, [P, P, 3, P])

    keep = [0, 1, 3]
    assert np.array_equal(outs_a[:, keep], outs_b[:, keep]), (
        "resetting slot 2 perturbed other slots' logits")
    # and slot 2 itself genuinely changed (the reset did something)
    assert not np.array_equal(outs_a[:, 2], outs_b[:, 2])


# ---------------------------------------------------------------------------
# engine end-to-end: budgets, EOS slot reuse, metrics accounting
# ---------------------------------------------------------------------------


def _ecfg(**kw):
    base = dict(max_slots=2, max_len=24, prefill_chunk=4, chunks_per_step=2)
    base.update(kw)
    return EngineConfig(**base)


def test_engine_serves_more_requests_than_slots(cfg, params):
    gens = [3, 5, 2, 4, 6, 1]
    reqs = _requests(cfg, [5, 7, 3, 6, 4, 5], gens)
    eng = ServeEngine(cfg, params, _ecfg())
    out = eng.run(reqs)
    assert sorted(out) == list(range(6))
    for i, g in enumerate(gens):
        assert len(out[i]) == g, f"request {i} budget {g}, got {len(out[i])}"
    s = eng.metrics.summary()
    assert s["completed"] == 6
    assert s["tokens_out"] == sum(gens)


def test_engine_eos_frees_slot_and_output_ends_at_eos(cfg, params):
    reqs = _requests(cfg, [6, 6, 6], [8, 8, 8], seed=5)
    eng = ServeEngine(cfg, params, _ecfg())
    out = eng.run(reqs)
    eos = out[0][1]           # greedy: request 0's second token is stable
    reqs2 = _requests(cfg, [6, 6, 6], [8, 8, 8], seed=5)
    eng2 = ServeEngine(cfg, params, _ecfg(eos_id=eos))
    out2 = eng2.run(reqs2)
    assert out2[0][-1] == eos and len(out2[0]) == 2
    for i in (1, 2):          # others unaffected unless they hit eos too
        assert len(out2[i]) <= 8


def test_engine_metrics_account_every_token(cfg, params):
    lens, gens, C = [5, 9, 4, 7], [4, 2, 5, 3], 4
    reqs = _requests(cfg, lens, gens)
    eng = ServeEngine(cfg, params, _ecfg(prefill_chunk=C))
    out = eng.run(reqs)
    s = eng.metrics.summary()
    assert s["tokens_out"] == sum(len(v) for v in out.values()) == sum(gens)
    assert s["prefill_tokens"] == sum(lens)
    assert s["prefill_chunks"] == sum(-(-n // C) for n in lens)
    assert 0 < s["occupancy"] <= 1
    assert len(eng.metrics.ttfts()) == len(reqs)


def test_engine_continuous_beats_wave_on_ragged_budgets(cfg, params):
    lens = [6] * 10
    gens = [2, 12, 3, 11, 2, 10, 4, 12, 2, 9]    # heavy raggedness
    ecfg = _ecfg(max_slots=2, prefill_chunk=6)
    eng = ServeEngine(cfg, params, ecfg)
    cont_out = eng.run(_requests(cfg, lens, gens))
    wave_out, wave_m = serve_waves(cfg, params, ecfg,
                                   _requests(cfg, lens, gens))
    assert cont_out == wave_out
    assert eng.metrics.occupancy > wave_m.occupancy
    assert eng.metrics.decode_steps < wave_m.decode_steps


# ---------------------------------------------------------------------------
# RNG discipline: fold_in(fold_in(key, req), token) — deterministic serving
# ---------------------------------------------------------------------------


def test_first_token_follows_fold_in_discipline(cfg, params):
    """Regression for the wave-era bug (first token sampled from the
    UNSPLIT top-level key): the engine's first token for request r must be
    exactly categorical(fold_in(fold_in(key(seed), r), 0), logits/T)."""
    temp, seed = 0.8, 11
    reqs = _requests(cfg, [6], [1], seed=6)
    eng = ServeEngine(cfg, params,
                      _ecfg(max_slots=1, temperature=temp, seed=seed,
                            prefill_chunk=6))
    out = eng.run(reqs)

    cache = T.init_cache(cfg, 1, 24)
    logits, _, _ = T.prefill(
        params, cfg, jnp.asarray([reqs[0].prompt], jnp.int32), cache, None)
    k = jax.random.fold_in(jax.random.fold_in(jax.random.key(seed), 0), 0)
    want = int(jax.random.categorical(k, logits[0, -1] / temp))
    assert out[0] == [want]


def test_same_seed_same_tokens(cfg, params):
    reqs = lambda: _requests(cfg, [5, 8, 6], [6, 4, 7], seed=7)  # noqa: E731
    a = ServeEngine(cfg, params, _ecfg(temperature=0.7, seed=3)).run(reqs())
    b = ServeEngine(cfg, params, _ecfg(temperature=0.7, seed=3)).run(reqs())
    assert a == b


def test_different_seed_different_tokens(cfg, params):
    reqs = lambda: _requests(cfg, [5, 8, 6], [8, 8, 8], seed=7)  # noqa: E731
    a = ServeEngine(cfg, params, _ecfg(temperature=0.9, seed=3)).run(reqs())
    b = ServeEngine(cfg, params, _ecfg(temperature=0.9, seed=4)).run(reqs())
    assert a != b


def test_sampling_independent_of_admission_order(cfg, params):
    """Same pool size, different arrival pattern → slot assignment and
    admission interleaving differ, but per-request tokens must not."""
    lens, gens = [5, 6, 7, 4], [5, 3, 6, 4]
    a = ServeEngine(cfg, params, _ecfg(temperature=0.7)).run(
        _requests(cfg, lens, gens, seed=8))
    staggered = _requests(cfg, lens, gens, seed=8,
                          arrivals=[0.0, 0.0, 0.05, 0.1])
    b = ServeEngine(cfg, params, _ecfg(temperature=0.7)).run(staggered)
    assert a == b


def test_wave_and_continuous_token_identical_greedy(cfg, params):
    lens, gens = [6] * 5, [3, 6, 2, 5, 4]
    ecfg = _ecfg(max_slots=2, prefill_chunk=6)
    cont = ServeEngine(cfg, params, ecfg).run(_requests(cfg, lens, gens))
    wave, _ = serve_waves(cfg, params, ecfg, _requests(cfg, lens, gens))
    assert cont == wave
