"""Architecture configs: published-size bands, segments, shape assignment."""

import pytest

from repro.configs.base import SHAPES, cell_applicable
from repro.models.registry import ARCH_IDS, all_configs, count_params, get_config

# (total target, active target) in billions; tolerance band in the test.
PUBLISHED = {
    "deepseek-v3-671b": (671, 37),
    "qwen3-moe-235b-a22b": (235, 22),
    "qwen2.5-3b": (3.1, None),
    "granite-34b": (34, None),
    "phi4-mini-3.8b": (3.8, None),
    "gemma2-2b": (2.6, None),
    "paligemma-3b": (2.5, None),      # backbone only; SigLIP tower stubbed
    "musicgen-medium": (1.5, None),
    "xlstm-1.3b": (2.0, None),        # brief dims ≠ nominal 1.3B; DESIGN.md §5
    "jamba-v0.1-52b": (52, 12),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_in_band(arch):
    cfg = get_config(arch)
    total, active = PUBLISHED[arch]
    n = count_params(cfg)
    assert abs(n / (total * 1e9) - 1) < 0.12, f"{arch}: {n/1e9:.2f}B"
    if active:
        a = count_params(cfg, active_only=True)
        assert abs(a / (active * 1e9) - 1) < 0.12, f"{arch}: {a/1e9:.2f}B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_segments_tile_pattern(arch):
    cfg = get_config(arch)
    rebuilt = []
    for unit, reps in cfg.segments():
        rebuilt.extend(list(unit) * reps)
    assert tuple(rebuilt) == cfg.layer_pattern
    assert len(rebuilt) == cfg.num_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_is_tiny_same_family(arch):
    cfg = get_config(arch)
    red = cfg.reduced()
    assert red.family == cfg.family
    assert red.d_model <= 256 and red.vocab_size <= 512
    assert count_params(red) < 5e6
    assert (red.moe is None) == (cfg.moe is None)
    assert (red.ssm is None) == (cfg.ssm is None)


def test_long500k_assignment():
    subq = {a for a in ARCH_IDS if get_config(a).sub_quadratic}
    assert subq == {"xlstm-1.3b", "jamba-v0.1-52b"}
    long = [s for s in SHAPES if s.name == "long_500k"][0]
    for a in ARCH_IDS:
        ok, why = cell_applicable(get_config(a), long)
        assert ok == (a in subq), (a, why)
        if not ok:
            assert "full-attention" in why


def test_40_cells_defined():
    cells = [(a, s.name) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40


def test_jamba_pattern_matches_hf_offsets():
    cfg = get_config("jamba-v0.1-52b")
    for i, kind in enumerate(cfg.layer_pattern):
        assert ("attn" in kind) == (i % 8 == 4)          # attn_layer_offset=4
        assert ("moe" in kind) == (i % 2 == 1)           # expert period 2


def test_gemma2_alternates_local_global():
    cfg = get_config("gemma2-2b")
    assert cfg.layer_pattern[::2] == ("local",) * 13
    assert cfg.layer_pattern[1::2] == ("global",) * 13
