"""HLO analyzer: trip-count multipliers, collective wire bytes, dot flops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.launch import hlo_analysis as H

SYNTHETIC = """
HloModule test

%body (arg: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %arg = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%arg), index=1
  %w = f32[128,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %d = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ip, %d)
}

%cond (arg: (s32[], f32[128,128])) -> pred[] {
  %arg = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[128,128]) -> f32[128,128] {
  %p = f32[128,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%zero, %p)
  %w = (s32[], f32[128,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[512,128]{1,0} all-gather(%p), replica_groups={{0,256},{1,257}}, dimensions={0}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_trip_count_and_collectives():
    st = H.analyze_hlo(SYNTHETIC)
    # dot: 2·128·128·128 flops × 7 iterations
    assert st.flops == pytest.approx(2 * 128**3 * 7)
    # all-reduce in loop: 2·(128·128·4)B·(3/4) × 7 ; all-gather: result×(1/2)
    ar = 2 * (128 * 128 * 4) * (3 / 4) * 7
    ag = (512 * 128 * 4) * (1 / 2)
    assert st.by_kind["all-reduce"] == pytest.approx(ar)
    assert st.by_kind["all-gather"] == pytest.approx(ag)
    # the all-gather group {0,256} crosses the pod boundary → dcn tier
    assert st.wire_bytes["dcn"] == pytest.approx(ag)
    assert st.wire_bytes["ici"] == pytest.approx(ar)


def test_real_compiled_scan_flops():
    """End-to-end: analyzer recovers trip-count-multiplied dot flops that
    cost_analysis misses (the probe that motivated all this)."""
    def f(x, w):
        def body(h, wi):
            return h @ wi, ()
        h, _ = lax.scan(body, x, w)
        return h
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w8 = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, w8).compile()
    st = H.analyze_hlo(compiled.as_text())
    assert st.flops == pytest.approx(8 * 2 * 256**3, rel=0.01)


def test_iota_replica_groups_parse():
    groups = H._parse_groups("replica_groups=[2,4]<=[8]")
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
    groups = H._parse_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_roofline_dominant_term():
    st = H.HloStats(flops=197e12, hbm_bytes=819e9 * 2)
    st.wire_bytes["ici"] = 50e9 * 0.5
    terms = H.roofline_terms(st)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(2.0)
    assert terms["collective_s"] == pytest.approx(0.5)
    assert terms["dominant"] == "memory_s"
