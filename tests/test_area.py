"""Area model vs paper §4.2 claims."""

from repro.core.area import (FS_MODULE_AREA_MM2, ROUTER_AREA_MM2,
                             TILE_AREA_MM2, fs_tile_overhead, system_area)


def test_tile_overhead_below_paper_bound():
    # paper: FractalSync adds < 0.01% to the tile; the synthesized delta is
    # in fact slightly NEGATIVE (−0.013%, synthesis noise per the paper)
    assert max(0.0, fs_tile_overhead()) < 1e-4
    assert abs(fs_tile_overhead()) < 2e-4


def test_k16_shares_match_paper():
    a = system_area(16)
    assert abs(a.noc_share - 0.017) < 2e-3
    assert abs(a.fs_share - 7e-5) < 2e-5
    assert a.noc_share + a.fs_share < 0.02       # >98% compute+comm


def test_fs_share_bounded_as_system_scales():
    # the scalability claim: sync-network share does not grow with k
    shares = [system_area(k).fs_share for k in (4, 8, 16, 32, 64, 128)]
    assert all(s <= 7.1e-5 for s in shares)
    assert shares[-1] >= shares[0] * 0.9         # converges, doesn't blow up


def test_component_areas_positive_and_sane():
    assert 0 < FS_MODULE_AREA_MM2 < 1e-3         # a tiny FSM
    assert 0 < ROUTER_AREA_MM2 < 0.1
    assert ROUTER_AREA_MM2 < TILE_AREA_MM2
