"""Schedule IR invariants + its three backends (cost, simulator, autotune).

The JAX-lowering backend is numerically validated against ``lax.psum`` on a
16-device host mesh in ``tests/collective_checks.py`` (subprocess, slow);
everything here is host-only and fast.
"""

import math

import pytest

from repro.core import autotune, cost_model as CM, schedule_ir as IR
from repro.core.simulator import (DEFAULT_PARAMS, HierarchicalAMOBarrier,
                                  NaiveBarrier, XYBarrier, schedule_on_noc,
                                  software_schedule_latency, tree_amo_barrier)
from repro.core.tree import FractalTree

SHAPES = [(1, 2), (2, 2), (4, 4), (2, 4), (8, 8), (16,), (2, 4, 4)]


# ------------------------------------------------------------ structure ---


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("name", IR.SCHEDULES)
def test_all_reduce_programs_validate(name, shape):
    prog = IR.build_program(name, shape)
    stats = IR.validate(prog)   # raises ScheduleError on any violation
    assert stats["steps"] == prog.num_steps
    assert prog.world == math.prod(shape)


@pytest.mark.parametrize("shape", [(4, 4), (2, 4), (16,)])
@pytest.mark.parametrize("name", ["fractal", "ring"])
def test_every_rank_sends_and_receives_once_per_step(name, shape):
    prog = IR.build_program(name, shape)
    world = prog.world
    for step in prog.steps:
        assert sorted(step.senders()) == list(range(world))
        assert sorted(step.receivers()) == list(range(world))


@pytest.mark.parametrize("shape", [(4, 4), (8, 8), (2, 4, 4)])
def test_bandwidth_optimal_payload_fractions(shape):
    """Fractal and ring each put exactly 2·V·(N−1)/N on the wire per rank."""
    n = math.prod(shape)
    want = 2 * (n - 1) / n
    for name in ("fractal", "ring"):
        fracs = IR.build_program(name, shape).per_rank_frac_sent()
        assert all(abs(f - want) < 1e-12 for f in fracs.values()), name


@pytest.mark.parametrize("shape", [(1, 2), (2, 2), (4, 4), (8, 8), (2, 4, 4)])
def test_butterfly_partner_sequence_matches_fractal_tree(shape):
    """The IR butterfly's partner at step i IS FractalTree.partner level i+1
    — the schedule is the software image of the paper's H-tree recursion."""
    prog = IR.build_program("fractal", shape)
    tree = FractalTree(shape)
    L = tree.num_levels
    rs_steps = prog.steps[:L]
    for i, step in enumerate(rs_steps):
        assert step.level == i + 1
        partner_of = {t.src: t.dst for t in step.transfers}
        for rank in range(prog.world):
            coords = IR.rank_coords(shape, rank)
            want = IR.coords_rank(shape, tree.partner(coords, i + 1))
            assert partner_of[rank] == want, (shape, i, rank)
    # and the all-gather phase mirrors it in reverse
    for i, step in enumerate(prog.steps[L:]):
        assert step.level == L - i


def test_validator_rejects_double_count():
    # rank 1 sends its contribution to rank 0 twice → double-counted sum
    t = IR.Transfer(1, 0, (0,), reduce=True)
    bad = IR.Program("bad", (2,), 1,
                     (IR.Step((t,)), IR.Step((t,))))
    with pytest.raises(IR.ScheduleError, match="double-counted"):
        IR.validate(bad)


def test_validator_rejects_incomplete():
    bad = IR.Program("bad", (2, 2), 4,
                     (IR.Step((IR.Transfer(1, 0, (0,), reduce=True),)),))
    with pytest.raises(IR.ScheduleError, match="incomplete"):
        IR.validate(bad)


def test_validator_rejects_double_send_per_step():
    bad = IR.Program("bad", (4,), 4, (IR.Step((
        IR.Transfer(0, 1, (0,), reduce=True),
        IR.Transfer(0, 2, (1,), reduce=True))),))
    with pytest.raises(IR.ScheduleError, match="sends twice"):
        IR.validate(bad)


def test_unknown_schedule_raises():
    with pytest.raises(IR.ScheduleError, match="unknown schedule"):
        IR.build_program("quantum", (4, 4))


@pytest.mark.parametrize("shape", [(4, 4), (2, 4, 4)])
def test_barrier_programs_validate(shape):
    for name, builder in IR.BARRIER_BUILDERS.items():
        prog = builder(shape)
        assert prog.kind == IR.BARRIER
        IR.validate(prog)


def test_fsync_domain_barrier_levels():
    # level ℓ butterfly barrier spans exactly 2^ℓ ranks per domain
    for level in (0, 1, 2, 3, 4):
        prog = IR.butterfly_barrier((4, 4), level=level)
        assert prog.num_steps == level


# ------------------------------------------------------- cost backend ----


@pytest.mark.parametrize("shape,n", [((4, 4), 16), ((8, 8), 64), ((16,), 16)])
@pytest.mark.parametrize("name", ["fractal", "ring", "naive", "tree"])
def test_program_cost_matches_closed_forms(name, shape, n):
    prog = IR.build_program(name, shape)
    vol = 1.6e6
    got = CM.program_cost(prog, vol, CM.MAGIA)
    want = CM.schedule_cost(name, n, vol, CM.MAGIA)
    assert got == pytest.approx(want, rel=1e-12), name


def test_program_cost_xy_matches_closed_form():
    prog = IR.build_program("xy", (4, 4))
    got = CM.program_cost(prog, 1e6, CM.MAGIA)
    assert got == pytest.approx(CM.xy_all_reduce(4, 4, 1e6, CM.MAGIA),
                                rel=1e-12)


def test_program_cost_hierarchical_tiered_links():
    prog = IR.build_program("hierarchical", (4, 4))
    got = CM.program_cost(prog, 1e6, CM.TPU_V5E_ICI, outer_link=CM.TPU_DCN)
    want = CM.hierarchical_all_reduce(4, 4, 1e6, CM.TPU_V5E_ICI, CM.TPU_DCN)
    assert got == pytest.approx(want, rel=1e-12)


def test_mesh_contention_separates_butterfly_from_ring():
    """On a mesh, the ring is cheaper per byte (hop-1 disjoint links) while
    the butterfly is cheaper per step — the crossover the autotuner uses."""
    fr = IR.build_program("fractal", (8, 8))
    rg = IR.build_program("ring", (8, 8))
    small, large = 64.0, 4e8
    assert CM.program_cost(fr, small, CM.MAGIA, mesh_contention=True) < \
        CM.program_cost(rg, small, CM.MAGIA, mesh_contention=True)
    assert CM.program_cost(rg, large, CM.MAGIA, mesh_contention=True) < \
        CM.program_cost(fr, large, CM.MAGIA, mesh_contention=True)


# -------------------------------------------------- simulator backend ----


@pytest.mark.parametrize("name", IR.SCHEDULES)
def test_noc_replay_executes_every_schedule(name):
    prog = IR.build_program(name, (4, 4))
    replay = schedule_on_noc(prog)
    assert replay.overhead > 0
    assert replay.total_msgs == sum(len(s.transfers) for s in prog.steps)
    assert len(replay.finish) == 16


def test_noc_replay_latency_ordering():
    """Log-depth schedules beat linear ones in the barrier regime."""
    lat = {s: software_schedule_latency(s, (8, 8))
           for s in ("fractal", "ring", "naive")}
    assert lat["fractal"] < lat["naive"] < lat["ring"]


def test_noc_replay_payload_scales_cost():
    prog = IR.build_program("fractal", (4, 4))
    small = schedule_on_noc(prog, payload_flits=1).overhead
    large = schedule_on_noc(prog, payload_flits=512).overhead
    assert large > small


def test_amo_barriers_are_ir_instances():
    """NaiveBarrier/XYBarrier now execute IR topologies through the generic
    hierarchical AMO executor — same protocol, IR-supplied structure."""
    nb = NaiveBarrier(4, 4)
    assert isinstance(nb, HierarchicalAMOBarrier)
    assert nb.prog.name == "naive_barrier"
    assert len(nb.levels) == 1
    xb = XYBarrier(4, 4)
    assert isinstance(xb, HierarchicalAMOBarrier)
    assert [len(lvl) for lvl in xb.levels] == [4, 1]   # 4 rows, 1 root


def test_tree_amo_barrier_between_xy_and_fsync():
    """The H-tree AMO barrier (SynCron-style) is log-depth but pays the
    software protocol per level: slower than dedicated FSync wires, and on
    small meshes the deeper tree costs more than XY's two levels."""
    t = tree_amo_barrier((8, 8)).run()
    xy = XYBarrier(8, 8, DEFAULT_PARAMS).run()
    tree = FractalTree((8, 8))
    assert t > tree.fsync_latency(pipelined=True)
    assert 0 < t < 4 * xy   # same order of magnitude, log-depth structure


# --------------------------------------------------------- autotuner -----


def test_autotune_crossover():
    assert autotune.pick_schedule((8, 8), 64.0, link=CM.MAGIA) == "fractal"
    assert autotune.pick_schedule((8, 8), 4e8, link=CM.MAGIA) == "ring"


def test_autotune_non_pow2_falls_back_to_ring_family():
    ranking = autotune.rank_schedules((12,), 1e6, link=CM.MAGIA)
    assert set(n for n, _ in ranking) <= {"ring", "xy", "naive"}


def test_autotune_measured_refinement_overrides_model():
    # model says fractal; measurements disagree → measurement wins
    fake = {"fractal": 2.0, "hierarchical": 1.0, "ring": 3.0}
    res = autotune.autotune((8, 8), 64.0, link=CM.MAGIA,
                            measure=lambda s: fake.get(s, float("inf")),
                            measure_top_k=3)
    assert res.ranking[0][0] == "fractal"
    assert res.schedule in fake and fake[res.schedule] == min(
        fake[n] for n, _ in res.ranking[:3] if n in fake)


def test_bsp_config_accepts_auto_and_tree():
    from repro.core.bsp import BSPConfig, resolve_schedule
    cfg = BSPConfig(schedule="auto")
    assert resolve_schedule(cfg, (8, 8), 64.0) in IR.SCHEDULES
    BSPConfig(schedule="tree")
    with pytest.raises(ValueError):
        BSPConfig(schedule="bogus")
