"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one train step (loss finite, grads flow), and the
prefill→decode path is *teacher-forcing consistent* with the parallel
forward pass — the strongest cheap correctness check an LM stack has.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import ARCH_IDS, get_config
from repro.optim import adamw

KEY = jax.random.key(0)


def _batch(cfg, B=2, Tlen=16, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, Tlen),
                                   dtype=np.int32))
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_tokens, cfg.frontend_dim))
            .astype(np.float32) * 0.1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch + "-smoke")
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        T.loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = adamw.global_norm(grads)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one optimizer step changes params and keeps them finite
    acfg = adamw.AdamWConfig()
    new_params, _, _ = adamw.apply_updates(params, grads,
                                           adamw.init(params, acfg), acfg)
    diff = adamw.global_norm(jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        new_params, params))
    assert float(diff) > 0
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """prefill(t[:k]) + decode(t[k:]) logits == forward(t) logits."""
    cfg = get_config(arch + "-smoke")
    params = T.init_params(cfg, KEY)
    B, Tlen, k = 2, 12, 7
    batch = _batch(cfg, B=B, Tlen=Tlen)
    tok = batch["tokens"]
    fe = batch.get("frontend")

    full_logits = T.forward(params, cfg, tok, fe)       # [B, Tf+T, V]
    off0 = cfg.frontend_tokens if cfg.frontend else 0

    cache = T.init_cache(cfg, B, Tlen + off0 + 2)
    lg, cache, offset = T.prefill(params, cfg, tok[:, :k], cache, fe)
    got = [np.asarray(lg[:, 0])]
    want = [np.asarray(full_logits[:, off0 + k - 1])]
    for i in range(k, Tlen):
        lg, cache = T.decode_step(params, cfg, tok[:, i:i + 1], cache,
                                  jnp.asarray(i + off0, jnp.int32))
        got.append(np.asarray(lg[:, 0]))
        want.append(np.asarray(full_logits[:, off0 + i]))
    got, want = np.stack(got), np.stack(want)
    scale = np.abs(want).max() + 1e-6
    np.testing.assert_allclose(got, want, atol=2e-3 * scale, rtol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-2b", "xlstm-1.3b",
                                  "jamba-v0.1-52b"])
def test_causality(arch):
    """Perturbing future tokens must not change past logits."""
    cfg = get_config(arch + "-smoke")
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg, B=1, Tlen=10)
    tok = batch["tokens"]
    cut = 6
    l1 = T.forward(params, cfg, tok)
    tok2 = tok.at[:, cut:].set((tok[:, cut:] + 7) % cfg.vocab_size)
    l2 = T.forward(params, cfg, tok2)
    np.testing.assert_allclose(np.asarray(l1[:, :cut]),
                               np.asarray(l2[:, :cut]), rtol=1e-4, atol=1e-4)


def test_prefix_lm_bidirectional():
    """PaliGemma: image-prefix tokens may attend forward within the prefix."""
    cfg = get_config("paligemma-3b-smoke")
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg, B=1, Tlen=8)
    fe = batch["frontend"]
    l1 = T.forward(params, cfg, batch["tokens"], fe)
    fe2 = fe.at[:, -1].set(fe[:, -1] + 0.5)   # change LAST prefix embedding
    l2 = T.forward(params, cfg, batch["tokens"], fe2)
    # earlier prefix positions see the change (bidirectional prefix)
    delta = np.abs(np.asarray(l1[:, 0]) - np.asarray(l2[:, 0])).max()
    assert delta > 0


def test_gemma2_softcaps_bound_logits():
    cfg = get_config("gemma2-2b-smoke")
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg, B=1, Tlen=8)
    logits = T.forward(params, cfg, batch["tokens"])
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_mtp_adds_loss_term():
    cfg = get_config("deepseek-v3-671b-smoke")
    assert cfg.mtp_depth == 1
    params = T.init_params(cfg, KEY)
    assert "mtp" in params
    loss, metrics = T.loss_fn(params, cfg, _batch(cfg))
    assert "mtp" in metrics and np.isfinite(float(metrics["mtp"]))
