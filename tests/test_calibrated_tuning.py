"""Measured-cost autotuning: calibration fit, DP bucket search, codec policy.

Host-side tests (single device) for the PR-5 tuning pipeline:

  * ``calibrate.fit_from_samples`` recovers known LinkParams exactly from
    synthetic timings (the model is linear in (α, hop, β) by construction);
  * the DP bucket partition is OPTIMAL — equal to brute-force enumeration
    of every boundary set for ≤10 random leaves, and never worse than the
    greedy packer, under the same ``overlap_step_cost``-shaped objective;
  * the per-bucket codec policy skips compression on latency-bound buckets
    and compresses bandwidth-bound ones;
  * payload-band memoization returns consistent rankings and actually
    caches;
  * the measured-refinement budget is respected and measured timings
    override the analytic picks.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune, calibrate, cost_model as CM
from repro.core import schedule_ir as IR, superstep as SS
from repro.core.bsp import BSPConfig
from repro.core.cost_model import LinkParams


# ---------------------------------------------------------------------------
# LinkParams hop term + banded pricing
# ---------------------------------------------------------------------------


def test_hop_default_reproduces_hops_times_alpha():
    prog = IR.build_program("fractal", (4, 4))
    legacy = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="l")
    explicit = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="e", hop_s=1e-6)
    a = CM.program_cost(prog, 1e6, legacy, mesh_contention=True)
    b = CM.program_cost(prog, 1e6, explicit, mesh_contention=True)
    assert a == pytest.approx(b)


def test_cheaper_hops_cut_mesh_cost_only():
    prog = IR.build_program("fractal", (4, 4))   # multi-hop butterfly steps
    base = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="b")
    fast_hop = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="f", hop_s=1e-8)
    assert CM.program_cost(prog, 1e4, fast_hop, mesh_contention=True) < \
        CM.program_cost(prog, 1e4, base, mesh_contention=True)
    # without mesh routing there are no hops to price
    assert CM.program_cost(prog, 1e4, fast_hop) == \
        pytest.approx(CM.program_cost(prog, 1e4, base))


def test_program_cost_banded_matches_band_center():
    prog = IR.build_program("ring", (4, 4))
    link = CM.TPU_V5E_ICI
    vol = 123_456.0
    band = CM.payload_band(vol)
    want = CM.program_cost(prog, CM.band_payload(band), link,
                           mesh_contention=True)
    got = CM.program_cost_banded(prog, vol, link, mesh_contention=True)
    assert got == pytest.approx(want)
    # band centers are within a quarter octave of the true payload
    assert CM.band_payload(band) / vol == pytest.approx(1.0, abs=0.1)


def test_rank_schedules_memoized_per_band():
    autotune._rank_banded.cache_clear()
    r1 = autotune.rank_schedules((4, 4), 1.00e6)
    r2 = autotune.rank_schedules((4, 4), 1.02e6)   # same quarter-octave band
    assert r1 == r2
    info = autotune._rank_banded.cache_info()
    assert info.hits >= 1 and info.misses == 1


def test_step_features_linearize_program_cost():
    link = LinkParams(alpha_s=3e-6, bw_Bps=40e9, name="x", hop_s=7e-7)
    for name in ("fractal", "ring", "tree", "naive"):
        prog = IR.build_program(name, (8,))
        n_steps, hops, load = CM.step_features(prog, mesh_contention=True)
        vol = 2e5
        want = CM.program_cost(prog, vol, link, mesh_contention=True)
        got = (n_steps * link.alpha_s + hops * link.hop
               + load * vol / link.bw_Bps)
        assert got == pytest.approx(want), name


# ---------------------------------------------------------------------------
# calibration: least-squares recovery of known link parameters
# ---------------------------------------------------------------------------


def _synthetic_samples(link, shape=(8,), mesh_contention=True):
    out = []
    for schedule in calibrate.FIT_SCHEDULES:
        for elems in (1 << 10, 1 << 14, 1 << 18):
            prog = IR.build_program(schedule, shape)
            vol = elems * 4.0
            secs = CM.program_cost(prog, vol, link,
                                   mesh_contention=mesh_contention)
            out.append(calibrate.LinkSample(schedule=schedule, shape=shape,
                                            payload_bytes=vol, seconds=secs))
    return out


def test_fit_recovers_synthetic_link_params():
    true = LinkParams(alpha_s=2e-6, bw_Bps=80e9, name="true", hop_s=5e-7)
    fit = calibrate.fit_from_samples(_synthetic_samples(true))
    assert fit.link.alpha_s == pytest.approx(true.alpha_s, rel=1e-3)
    assert fit.link.bw_Bps == pytest.approx(true.bw_Bps, rel=1e-3)
    assert fit.link.hop == pytest.approx(true.hop, rel=1e-3)
    assert fit.residual < 1e-6


def test_fit_feeds_the_tuner():
    # a fitted fat-pipe link must flip large-payload picks toward the
    # latency-optimal butterfly relative to a thin-pipe fit
    fat = calibrate.fit_from_samples(_synthetic_samples(
        LinkParams(alpha_s=1e-5, bw_Bps=1e13, name="fat"))).link
    thin = calibrate.fit_from_samples(_synthetic_samples(
        LinkParams(alpha_s=1e-9, bw_Bps=1e8, name="thin"))).link
    vol = 4e7
    assert autotune.pick_schedule((8,), vol, link=fat) == "fractal"
    assert autotune.pick_schedule((8,), vol, link=thin) == "ring"


def test_fit_link_params_guards_device_count():
    with pytest.raises(ValueError):
        calibrate.fit_link_params(min_devices=8)   # 1 host device only


def test_fit_from_samples_rejects_empty():
    with pytest.raises(ValueError):
        calibrate.fit_from_samples([])


# ---------------------------------------------------------------------------
# DP bucket-boundary search: optimality vs brute force and greedy
# ---------------------------------------------------------------------------


def _buckets_from_groups(groups, leaf_sizes, pad_unit):
    buckets, offset = [], 0
    for bi, ids in enumerate(groups):
        raw = sum(leaf_sizes[i] for i in ids)
        length = ((raw + pad_unit - 1) // pad_unit) * pad_unit
        buckets.append(SS.Bucket(index=bi, leaf_ids=tuple(ids), raw=raw,
                                 offset=offset, length=length))
        offset += length
    return tuple(buckets)


def _brute_force_objective(leaf_sizes, order, pad_unit, itemsize, cost_fn,
                           backward_s):
    """Minimum objective over ALL 2^(n-1) contiguous boundary sets."""
    n = len(order)
    best = math.inf
    for mask in range(1 << (n - 1)):
        groups, cur = [], [order[0]]
        for k in range(1, n):
            if (mask >> (k - 1)) & 1:
                groups.append(cur)
                cur = []
            cur.append(order[k])
        groups.append(cur)
        buckets = _buckets_from_groups(groups, leaf_sizes, pad_unit)
        obj = SS.partition_objective(buckets, cost_fn, itemsize, backward_s)
        best = min(best, obj)
    return best


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 40_000), min_size=1, max_size=10),
       st.floats(1e-7, 1e-4), st.floats(1e8, 1e11), st.floats(0.0, 2.0))
def test_dp_partition_matches_brute_force(sizes, alpha, bw, bwd_scale):
    order = tuple(reversed(range(len(sizes))))
    pad_unit, itemsize = 512, 4

    def cost_fn(payload_bytes):
        return alpha + payload_bytes / bw

    total_b = sum(sizes) * itemsize
    backward_s = bwd_scale * cost_fn(total_b)
    dp = SS.dp_partition(sizes, order, pad_unit, itemsize, cost_fn,
                         backward_s)
    dp_obj = SS.partition_objective(dp, cost_fn, itemsize, backward_s)
    brute = _brute_force_objective(sizes, order, pad_unit, itemsize,
                                   cost_fn, backward_s)
    assert dp_obj == pytest.approx(brute, rel=1e-9), \
        "DP must equal exhaustive boundary enumeration"
    # every leaf exactly once, reverse order, contiguous segments
    seen = [i for b in dp for i in b.leaf_ids]
    assert seen == list(order)
    for a, b in zip(dp, dp[1:]):
        assert b.offset == a.offset + a.length


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 500_000), min_size=1, max_size=24),
       st.floats(1e-7, 1e-4), st.floats(1e8, 1e11),
       st.sampled_from([0.0005, 0.01, 0.5, 64.0]))
def test_dp_never_worse_than_greedy(sizes, alpha, bw, greedy_mb):
    order = tuple(reversed(range(len(sizes))))
    pad_unit, itemsize = 128, 4

    def cost_fn(payload_bytes):
        return alpha + payload_bytes / bw

    backward_s = cost_fn(sum(sizes) * itemsize)
    elems = max(1, int(greedy_mb * 1e6 / itemsize))
    greedy = SS.partition_buckets(sizes, order, elems, pad_unit)
    greedy_obj = SS.partition_objective(greedy, cost_fn, itemsize,
                                        backward_s)
    dp = SS.dp_partition(sizes, order, pad_unit, itemsize, cost_fn,
                         backward_s, upper_bound=greedy_obj)
    dp_obj = SS.partition_objective(dp, cost_fn, itemsize, backward_s)
    assert dp_obj <= greedy_obj * (1 + 1e-12)


def test_search_bucket_partition_prefers_dp_and_reports_source():
    sizes = [60_000] * 12
    order = tuple(reversed(range(len(sizes))))

    def cost_fn(payload_bytes):
        return 1e-5 + payload_bytes / 1e9

    plan = SS.search_bucket_partition(sizes, order, 128, 4, cost_fn)
    assert plan.source == "dp"
    for mb in SS.GREEDY_FALLBACK_MBS:
        elems = max(1, int(mb * 1e6 / 4))
        g = SS.partition_buckets(sizes, order, elems, 128)
        g_obj = SS.partition_objective(g, cost_fn, 4, plan.backward_s)
        assert plan.objective_s <= g_obj * (1 + 1e-12)


# ---------------------------------------------------------------------------
# engine integration: bucket_mb="auto", per-bucket codecs, refinement
# ---------------------------------------------------------------------------


def _specs(sizes):
    return tuple(SS.LeafSpec(shape=(s,), dtype="float32") for s in sizes)


def test_engine_auto_buckets_cover_leaves():
    specs = _specs([40_000, 3, 70_000, 128, 9_999, 5_000_000, 17])
    cfg = BSPConfig(schedule="auto", bucket_mb="auto")
    eng = SS.SuperstepEngine(specs, cfg, (4,))
    seen = sorted(i for b in eng.buckets for i in b.leaf_ids)
    assert seen == list(range(len(specs)))
    assert eng.plan is not None
    assert "[" + eng.plan.source + "]" in eng.describe()
    assert eng.total_padded == sum(b.length for b in eng.buckets)


def test_engine_auto_respects_overlap_switch():
    specs = _specs([10_000] * 8)
    cfg = BSPConfig(schedule="fractal", bucket_mb="auto", overlap=False)
    eng = SS.SuperstepEngine(specs, cfg, (4,))
    assert eng.n_buckets == 1 and eng.plan is None


def test_bsp_config_validates_new_fields():
    BSPConfig(bucket_mb="auto")
    BSPConfig(bucket_codec="auto")
    BSPConfig(bucket_codec="bf16", link=CM.TPU_V5E_ICI)
    with pytest.raises(ValueError):
        BSPConfig(bucket_mb="autos")
    with pytest.raises(ValueError):
        BSPConfig(bucket_codec="zstd")


def test_codec_policy_small_skips_large_compresses():
    pols = autotune.pick_bucket_policies((4, 4), [256.0, 4e8])
    assert pols[0].codec == "none", "latency-bound bucket must not compress"
    assert pols[0].schedule == "fractal"
    assert pols[1].schedule == "fractal" and pols[1].codec in ("bf16", "int8")
    # same shape under the zero1 pricing: policy survives the publish term
    z = autotune.pick_bucket_policies((4, 4), [256.0, 4e8],
                                      zero1_publish=True)
    assert z[0].codec == "none" and z[1].codec != "none"


def test_rank_policies_sorted_and_codecs_fractal_only():
    pols = autotune.rank_policies((4, 4), 1e7)
    costs = [p.predicted_s for p in pols]
    assert costs == sorted(costs)
    for p in pols:
        if p.codec != "none":
            assert p.schedule == "fractal"


def test_engine_auto_codec_tags_bucket_meta():
    specs = _specs([100_000_000, 64])
    cfg = BSPConfig(schedule="auto", bucket_mb=1.0, bucket_codec="auto")
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    assert eng.n_buckets == 2
    assert eng.codec_names[0] == "none"      # tiny reverse-order head
    assert eng.codec_names[1] != "none"      # the 400MB leaf compresses
    progs = eng.programs()
    assert progs[0].bucket.codec is None
    assert progs[1].bucket.codec == eng.codec_names[1]


def test_engine_uniform_codec_when_bucket_codec_unset():
    specs = _specs([100_000_000, 64])
    cfg = BSPConfig(schedule="fractal", bucket_mb=1.0, compression="bf16")
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    assert all(c == "bf16" for c in eng.codec_names)


def test_pick_bucket_schedules_measured_budget():
    shape = (4, 4)
    buckets = [1e3, 1e8]
    analytic = autotune.pick_bucket_schedules(shape, buckets)
    calls = []

    def measure(name, payload):
        calls.append((name, payload))
        return 1e-9 if name == analytic[1] else 1.0

    # budget 0 → no measurement at all
    assert autotune.pick_bucket_schedules(
        shape, buckets, measure=measure, measure_budget=0) == analytic
    assert calls == []
    # budget 2 → only the priciest bucket (the 1e8 one) gets refined
    got = autotune.pick_bucket_schedules(shape, buckets, measure=measure,
                                         measure_budget=2, measure_top_k=2)
    assert len(calls) == 2
    assert all(p == buckets[1] for _, p in calls)
    assert got[1] == analytic[1]


def test_budget_exhaustion_cannot_drop_untimed_incumbent():
    shape = (4, 4)
    buckets = [4e8]
    ranking = [n for n, _ in autotune.rank_schedules(shape, buckets[0])]
    incumbent = ranking[1]               # baseline = analytic runner-up
    calls = []

    def measure(name, payload):
        calls.append(name)
        return 1e-9                      # every challenger "measures fast"

    got = autotune.pick_bucket_schedules(shape, buckets, measure=measure,
                                         measure_budget=1, measure_top_k=2,
                                         baseline=[incumbent])
    assert calls == [incumbent], \
        "the incumbent must be timed before any challenger"
    assert got[0] == incumbent


def test_zero1_codec_overhead_halved():
    # the publish all-gather half is uncompressed, so the quant launches
    # charge only the reduce-scatter half: a payload whose saving beats
    # L·alpha but not 2L·alpha must still compress under zero1 pricing
    link = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="l")
    prog = IR.build_program("fractal", (4, 4))
    pols = {p.codec: p.predicted_s
            for p in autotune.rank_policies((4, 4), 1e7, link=link,
                                            zero1_publish=True)
            if p.schedule == "fractal"}
    full = CM.program_cost_banded(prog, 1e7, link, mesh_contention=True)
    wire = CM.program_cost_banded(prog, 1e7 * 0.5, link,
                                  mesh_contention=True)
    want = 0.5 * full + 0.5 * wire + \
        0.5 * autotune.codec_step_alphas()["bf16"] * link.alpha_s \
        * prog.num_steps
    assert pols["bf16"] == pytest.approx(want)


def test_measured_refinement_overrides_analytic_pick():
    shape = (4, 4)
    buckets = [4e8]
    analytic = autotune.pick_bucket_schedules(shape, buckets)
    runner_up = [n for n, _ in autotune.rank_schedules(shape, buckets[0])
                 if n != analytic[0]][0]

    def measure(name, payload):
        return 1e-9 if name == runner_up else 1.0

    got = autotune.pick_bucket_schedules(shape, buckets, measure=measure,
                                         measure_budget=4, measure_top_k=3)
    assert got[0] == runner_up


def test_engine_refined_applies_measured_picks_and_drops_codecs():
    specs = _specs([100_000_000])
    cfg = BSPConfig(schedule="auto", bucket_mb=None, bucket_codec="auto")
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    assert eng.codec_names[0] != "none"
    ref = eng.refined(lambda s, b: 1e-9 if s == "naive" else 1.0,
                      measure_budget=8, measure_top_k=6)
    assert ref.schedules == ("naive",)
    assert ref.codec_names == ("none",)      # codecs ride fractal only
    # the original engine is untouched (refined returns a copy)
    assert eng.schedules != ("naive",)


def test_engine_refined_keeps_policy_picks_unless_outmeasured():
    specs = _specs([100_000_000] * 4)
    cfg = BSPConfig(schedule="auto", bucket_mb=64.0, bucket_codec="auto")
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    assert all(c != "none" for c in eng.codec_names)
    # a single measurement that CONFIRMS the incumbent must change nothing
    # — least of all the codec-aware picks of the unmeasured buckets
    ref = eng.refined(lambda s, b: 1e-9 if s == eng.schedules[0] else 1.0,
                      measure_budget=1, measure_top_k=1)
    assert ref.schedules == eng.schedules
    assert ref.codec_names == eng.codec_names


def test_forced_bucket_codec_normalized_to_fractal_buckets():
    specs = _specs([100_000_000])
    eng = SS.SuperstepEngine(
        specs, BSPConfig(schedule="ring", bucket_codec="bf16"), (4, 4))
    assert eng.codec_names == ("none",), \
        "no wire-codec path outside fractal — a forced codec must not " \
        "silently pretend otherwise"
    # the legacy uniform `compression` keeps its historical EF semantics
    leg = SS.SuperstepEngine(
        specs, BSPConfig(schedule="ring", compression="bf16"), (4, 4))
    assert leg.codec_names == ("bf16",)


def test_engine_refined_respects_forced_schedule():
    specs = _specs([100_000_000])
    eng = SS.SuperstepEngine(
        specs, BSPConfig(schedule="fractal", bucket_mb=None), (4, 4))
    ref = eng.refined(lambda s, b: 1e-9 if s == "naive" else 1.0,
                      measure_budget=8, measure_top_k=6)
    assert ref.schedules == ("fractal",), \
        "refinement must not override an explicitly forced schedule"
    xla = SS.SuperstepEngine(
        specs, BSPConfig(schedule="xla", bucket_mb=None), (4, 4))
    assert xla.refined(lambda s, b: 0.0, measure_budget=8).schedules == \
        ("xla",)


def test_timeline_charges_codec_launch_overhead():
    # tiny payload: the β saving is negligible, the quant/dequant launches
    # are not — a forced codec must predict strictly slower than none
    specs = _specs([400])
    plain = SS.SuperstepEngine(
        specs, BSPConfig(schedule="fractal"), (4, 4))
    coded = SS.SuperstepEngine(
        specs, BSPConfig(schedule="fractal", bucket_codec="bf16"), (4, 4))
    assert coded.timeline(0.0).overlapped_s > \
        plain.timeline(0.0).overlapped_s


def test_engine_for_caches_calibrated_configs():
    import jax.numpy as jnp
    link = LinkParams(alpha_s=1e-6, bw_Bps=42e9, name="fit")
    cfg = BSPConfig(schedule="auto", bucket_mb="auto", link=link)
    tree = {"w": jnp.zeros((2048,))}
    e1 = SS.engine_for(tree, cfg, (4,))
    e2 = SS.engine_for(tree, cfg, (4,))
    assert e1 is e2 and e1.link is link


def test_timeline_prices_with_engine_link():
    specs = _specs([1_000_000] * 4)
    slow = LinkParams(alpha_s=1e-6, bw_Bps=1e9, name="slow")
    fast = LinkParams(alpha_s=1e-6, bw_Bps=1e12, name="fast")
    e_slow = SS.SuperstepEngine(
        specs, BSPConfig(schedule="fractal", bucket_mb=1.0, link=slow), (4,))
    e_fast = SS.SuperstepEngine(
        specs, BSPConfig(schedule="fractal", bucket_mb=1.0, link=fast), (4,))
    assert e_slow.timeline(1e-3).overlapped_s > \
        e_fast.timeline(1e-3).overlapped_s
