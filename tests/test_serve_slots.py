"""Unit tests for the serve subsystem's host-side plumbing:
slot table lifecycle, request queue + arrival processes, metrics."""

import numpy as np
import pytest

from repro.serve.metrics import ServeMetrics
from repro.serve.queue import (Request, RequestQueue, parse_arrival_spec,
                               poisson_arrivals, trace_arrivals)
from repro.serve.slots import ACTIVE, FREE, PREFILL, SlotTable


def _req(i, plen=4, gen=3, arrival=0.0):
    return Request(req_id=i, prompt=list(range(1, plen + 1)),
                   max_new_tokens=gen, arrival_s=arrival)


# ---------------------------------------------------------------------------
# Request / RequestQueue
# ---------------------------------------------------------------------------


def test_request_rejects_empty_prompt():
    with pytest.raises(ValueError, match="empty prompt"):
        Request(req_id=0, prompt=[], max_new_tokens=1)


def test_request_rejects_zero_budget():
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(req_id=0, prompt=[1], max_new_tokens=0)


def test_queue_orders_by_arrival_then_id():
    q = RequestQueue()
    q.submit([_req(2, arrival=1.0), _req(0, arrival=0.5),
              _req(1, arrival=0.5)])
    assert q.pop_ready(2.0).req_id == 0
    assert q.pop_ready(2.0).req_id == 1
    assert q.pop_ready(2.0).req_id == 2
    assert q.pop_ready(2.0) is None


def test_queue_gates_on_arrival_time():
    q = RequestQueue()
    q.submit(_req(0, arrival=5.0))
    assert q.pop_ready(4.9) is None
    assert len(q) == 1
    assert q.next_arrival() == 5.0
    assert q.pop_ready(5.0).req_id == 0
    assert q.next_arrival() is None


def test_poisson_arrivals_shape_and_monotonicity():
    times = poisson_arrivals(32, rate_per_s=10.0, seed=3)
    assert len(times) == 32
    assert times[0] == 0.0
    assert all(b >= a for a, b in zip(times, times[1:]))
    # mean gap ≈ 1/rate (loose: 32 samples)
    gaps = np.diff(times)
    assert 0.02 < gaps.mean() < 0.5


def test_poisson_rejects_bad_rate():
    with pytest.raises(ValueError):
        poisson_arrivals(4, rate_per_s=0.0)


def test_poisson_zero_requests_is_empty():
    assert poisson_arrivals(0, rate_per_s=5.0) == ()


def test_trace_arrivals_from_string_and_file(tmp_path):
    assert trace_arrivals("0, 0.5,2") == (0.0, 0.5, 2.0)
    p = tmp_path / "trace.txt"
    p.write_text("0\n1.5\n1.5\n3\n")
    assert trace_arrivals(str(p)) == (0.0, 1.5, 1.5, 3.0)


def test_trace_arrivals_rejects_decreasing():
    with pytest.raises(ValueError, match="non-decreasing"):
        trace_arrivals("1,0.5")


def test_parse_arrival_spec():
    assert parse_arrival_spec("immediate", 3) == (0.0, 0.0, 0.0)
    assert len(parse_arrival_spec("poisson:100", 5, seed=1)) == 5
    assert parse_arrival_spec("trace:0,1,2", 2) == (0.0, 1.0)
    with pytest.raises(ValueError, match="unknown arrival"):
        parse_arrival_spec("bursty", 2)
    with pytest.raises(ValueError, match="trace has"):
        parse_arrival_spec("trace:0,1", 5)


# ---------------------------------------------------------------------------
# SlotTable
# ---------------------------------------------------------------------------


def test_slot_lifecycle():
    table = SlotTable(max_slots=2, max_len=16)
    assert len(table.free()) == 2 and table.n_active == 0
    slot = table.free()[0]
    table.assign(slot, _req(7, plen=4, gen=3))
    assert slot.state == PREFILL and table.prefilling() == [slot]
    table.activate(slot, first_token=42)
    assert slot.state == ACTIVE and slot.length == 4
    assert slot.output == [42] and slot.generated == 1
    req = table.release(slot)
    assert req.req_id == 7 and slot.state == FREE


def test_slot_assign_rejects_busy_and_oversize():
    table = SlotTable(max_slots=1, max_len=8)
    slot = table.slots[0]
    with pytest.raises(ValueError, match="cache positions"):
        table.assign(slot, _req(0, plen=6, gen=4))    # 10 > 8
    table.assign(slot, _req(0, plen=4, gen=3))
    with pytest.raises(RuntimeError, match="not free"):
        table.assign(slot, _req(1))
    table.activate(slot, 1)
    with pytest.raises(RuntimeError, match="not prefilling"):
        table.activate(slot, 1)            # activating twice must fail


def test_slot_release_free_raises():
    table = SlotTable(max_slots=1, max_len=8)
    with pytest.raises(RuntimeError, match="already free"):
        table.release(table.slots[0])


def test_decode_inputs_masking_and_sentinel():
    table = SlotTable(max_slots=3, max_len=32)
    s0, s1, s2 = table.slots
    table.assign(s0, _req(5, plen=4, gen=4))
    table.activate(s0, first_token=9)
    table.assign(s1, _req(6, plen=3, gen=2))          # stays PREFILL
    tokens, offsets, active, req_ids, tok_idx = table.decode_inputs()
    assert tokens.shape == (3, 1) and tokens[0, 0] == 9 and tokens[2, 0] == 0
    assert offsets[0] == 4                      # active slot: its length
    assert offsets[1] == offsets[2] == 31       # masked rows: sentinel
    assert active.tolist() == [True, False, False]
    assert req_ids[0] == 5 and tok_idx[0] == 1  # next sampled = token 1


def test_slot_table_rejects_empty_pool():
    with pytest.raises(ValueError):
        SlotTable(max_slots=0, max_len=8)


# ---------------------------------------------------------------------------
# ServeMetrics
# ---------------------------------------------------------------------------


def test_metrics_occupancy_and_tokens_per_step():
    m = ServeMetrics(max_slots=4)
    m.start()
    m.on_submit(0, 0.0, 8)
    m.on_decode_step(4)
    m.on_decode_step(2)
    assert m.decode_steps == 2
    assert m.occupancy == pytest.approx(6 / 8)
    assert m.tokens_per_step == pytest.approx(3.0)


def test_metrics_ttft_and_summary():
    m = ServeMetrics(max_slots=2)
    m.start()
    for i in range(3):
        m.on_submit(i, 0.0, 4)
        m.on_admit(i)
        m.on_first_token(i)
        m.on_finish(i)
    m.stop()
    s = m.summary()
    assert s["requests"] == 3 and s["completed"] == 3
    assert s["tokens_out"] == 3            # one (first) token each
    assert len(m.ttfts()) == 3
    assert s["ttft_p50_s"] >= 0 and s["ttft_p95_s"] >= s["ttft_p50_s"]
    assert "occupancy" in m.report()


def test_metrics_empty_edge_cases():
    m = ServeMetrics(max_slots=4)
    assert m.occupancy == 0.0 and m.tokens_per_step == 0.0
    assert m.ttfts() == []
    assert np.isnan(m.summary()["ttft_p50_s"])
