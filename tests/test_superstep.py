"""SuperstepEngine unit + property tests (host-side; single device).

Bucket partitioning invariants, flat-layout round-trips, per-bucket
autotuning, the overlap-aware cost model, and the pipelined NoC replay.
Multi-device numerics (bucketed sync ≡ monolithic sync on a 16-device
mesh) live in ``tests/superstep_checks.py`` (subprocess, marked slow).
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import cost_model as CM, schedule_ir as IR
from repro.core import superstep as SS
from repro.core.bsp import BSPConfig
from repro.core.simulator import pipelined_on_noc, schedule_on_noc

leaf_sizes_st = st.lists(st.integers(1, 5000), min_size=1, max_size=24)


# ---------------------------------------------------------------------------
# bucket partition invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(leaf_sizes_st, st.sampled_from([None, 1000, 4000, 10**7]),
       st.sampled_from([128, 512]))
def test_partition_covers_all_leaves_in_reverse_order(sizes, bound, unit):
    order = tuple(reversed(range(len(sizes))))
    buckets = SS.partition_buckets(sizes, order, bound, unit)
    seen = [i for b in buckets for i in b.leaf_ids]
    assert seen == list(order), "reverse-layer order, every leaf exactly once"
    for b in buckets:
        assert b.raw == sum(sizes[i] for i in b.leaf_ids)
        assert b.length % unit == 0 and b.length >= b.raw
        assert b.length - b.raw < unit, "minimal padding"
    offs = [b.offset for b in buckets]
    assert offs == sorted(offs) and offs[0] == 0
    for a, b in zip(buckets, buckets[1:]):
        assert b.offset == a.offset + a.length, "segments are contiguous"


@settings(max_examples=40, deadline=None)
@given(leaf_sizes_st, st.integers(1, 20000))
def test_partition_respects_size_bound(sizes, bound):
    order = tuple(reversed(range(len(sizes))))
    buckets = SS.partition_buckets(sizes, order, bound, 1)
    for b in buckets:
        # a bucket only exceeds the bound when a single leaf does
        assert b.raw <= bound or len(b.leaf_ids) == 1 or \
            b.raw - sizes[b.leaf_ids[-1]] <= bound


def test_partition_none_bound_is_single_bucket():
    buckets = SS.partition_buckets([5, 6, 7], (2, 1, 0), None, 4)
    assert len(buckets) == 1 and buckets[0].leaf_ids == (2, 1, 0)


# ---------------------------------------------------------------------------
# engine plan + flat-layout round trip (world=1: no collectives needed)
# ---------------------------------------------------------------------------


def _engine(specs, **cfg_kw):
    cfg = BSPConfig(schedule=cfg_kw.pop("schedule", "fractal"), **cfg_kw)
    return SS.SuperstepEngine(specs, cfg, (1,))


@settings(max_examples=25, deadline=None)
@given(leaf_sizes_st, st.sampled_from([None, 0.001, 0.01]))
def test_pack_unpack_roundtrip_ragged(sizes, bucket_mb):
    rng = np.random.default_rng(42)
    leaves = [jnp.asarray(rng.normal(size=(s,)).astype(np.float32))
              for s in sizes]
    specs = SS.leaf_specs_of(leaves)
    eng = _engine(specs, bucket_mb=bucket_mb, pad_align=8)
    parts = eng.pack(leaves)
    assert [p.shape[0] for p in parts] == [b.length for b in eng.buckets]
    out = eng.unpack(parts, leaves)
    for a, b in zip(leaves, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unpack_restores_shapes_and_dtypes():
    leaves = [jnp.ones((3, 5), jnp.bfloat16), jnp.zeros((7,), jnp.float32)]
    eng = _engine(SS.leaf_specs_of(leaves), bucket_mb=None, pad_align=4)
    out = eng.unpack(eng.pack(leaves), leaves)
    assert out[0].shape == (3, 5) and out[0].dtype == jnp.bfloat16
    assert out[1].shape == (7,) and out[1].dtype == jnp.float32


def test_overlap_false_collapses_to_single_bucket():
    specs = tuple(SS.LeafSpec((1000,), "float32") for _ in range(8))
    cfg = BSPConfig(schedule="fractal", bucket_mb=0.001, overlap=False)
    eng = SS.SuperstepEngine(specs, cfg, (2, 2))
    assert eng.n_buckets == 1
    cfg_on = BSPConfig(schedule="fractal", bucket_mb=0.001, overlap=True)
    assert SS.SuperstepEngine(specs, cfg_on, (2, 2)).n_buckets > 1


def test_engine_programs_carry_bucket_metadata():
    specs = tuple(SS.LeafSpec((4000,), "float32") for _ in range(6))
    cfg = BSPConfig(schedule="auto", bucket_mb=0.02)
    eng = SS.SuperstepEngine(specs, cfg, (2, 2))
    progs = eng.programs()
    assert len(progs) == eng.n_buckets > 1
    for i, (p, b) in enumerate(zip(progs, eng.buckets)):
        assert p.bucket == b.meta(eng.n_buckets)
        assert p.bucket.index == i
        assert p.name in IR.SCHEDULES
    # bucket metadata survives describe() and _replace_name
    assert "bucket 0/" in progs[0].describe()
    assert progs[0]._replace_name("x").bucket == progs[0].bucket


def test_shard_offsets_partition_the_rank_shard():
    specs = tuple(SS.LeafSpec((3000,), "float32") for _ in range(5))
    cfg = BSPConfig(schedule="fractal", bucket_mb=0.01)
    eng = SS.SuperstepEngine(specs, cfg, (4,))
    offs = eng.shard_offsets()
    lens = [eng.shard_len(b) for b in eng.buckets]
    assert offs[0] == 0
    assert all(offs[i + 1] == offs[i] + lens[i] for i in range(len(lens) - 1))
    assert offs[-1] + lens[-1] == eng.total_padded // 4


def test_auto_schedule_is_picked_per_bucket():
    # one tiny + one huge bucket on a 4×4 mesh must split fractal/ring,
    # matching the schedule_matrix crossover
    specs = (SS.LeafSpec((10_000_000,), "float32"),
             SS.LeafSpec((32,), "float32"))
    cfg = BSPConfig(schedule="auto", bucket_mb=1.0)
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    assert eng.n_buckets == 2
    # bucket 0 is the reverse-order head: the tiny leaf
    assert eng.schedules[0] == "fractal"
    assert eng.schedules[1] == "ring"


def test_engine_cache_reuses_plan():
    cfg = BSPConfig(schedule="fractal", bucket_mb=0.1)
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((7, 3))}
    e1 = SS.engine_for(tree, cfg, (2, 2))
    e2 = SS.engine_for({"a": jnp.ones((100,)), "b": jnp.ones((7, 3))},
                       cfg, (2, 2))
    assert e1 is e2


def test_world_one_sync_is_identity():
    cfg = BSPConfig(schedule="fractal")
    tree = {"w": jnp.arange(8.0)}
    eng = SS.engine_for(tree, cfg, (1,))
    out = eng.sync(tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))


# ---------------------------------------------------------------------------
# overlap-aware cost model
# ---------------------------------------------------------------------------


def _progs(n, shape=(4, 4), name="fractal"):
    return [IR.build_program(name, shape) for _ in range(n)]


def test_overlap_never_beats_physics_and_never_loses_to_serial():
    progs = _progs(4)
    vols = [1e6, 2e6, 4e6, 8e6]
    ready = [1e-4, 2e-4, 3e-4, 4e-4]
    tl = CM.overlap_step_cost(progs, vols, ready, CM.TPU_V5E_ICI)
    assert tl.overlapped_s <= tl.serial_s
    # lower bounds: last ready time, and total fabric occupancy
    assert tl.overlapped_s >= max(ready)
    assert tl.overlapped_s >= sum(tl.comm_cost_s)
    for r, s, e, c in zip(tl.ready_s, tl.comm_start_s, tl.comm_end_s,
                          tl.comm_cost_s):
        assert s >= r and e == pytest.approx(s + c)


def test_overlap_equals_serial_when_nothing_ready_early():
    progs = _progs(3)
    vols = [1e6] * 3
    ready = [5e-3] * 3   # everything ready at backward end: no overlap
    tl = CM.overlap_step_cost(progs, vols, ready, CM.TPU_V5E_ICI)
    assert tl.overlapped_s == pytest.approx(tl.serial_s)
    assert tl.overlap_gain == pytest.approx(0.0)


def test_overlap_strictly_wins_with_early_buckets():
    progs = _progs(2)
    vols = [8e6, 8e6]
    ready = [0.0, 1e-3]          # bucket 0 ready immediately
    tl = CM.overlap_step_cost(progs, vols, ready, CM.TPU_V5E_ICI)
    assert tl.overlapped_s < tl.serial_s
    assert tl.overlap_gain > 0


def test_engine_timeline_monotone_ready_and_matches_program_costs():
    specs = tuple(SS.LeafSpec((50_000,), "float32") for _ in range(10))
    cfg = BSPConfig(schedule="fractal", bucket_mb=0.4)
    eng = SS.SuperstepEngine(specs, cfg, (4, 4))
    tl = eng.timeline(backward_s=1e-3)
    assert list(tl.ready_s) == sorted(tl.ready_s)
    assert tl.ready_s[-1] == pytest.approx(1e-3)
    assert len(tl.comm_cost_s) == eng.n_buckets


# ---------------------------------------------------------------------------
# pipelined NoC replay
# ---------------------------------------------------------------------------


def test_pipelined_single_program_matches_schedule_on_noc():
    for name in ("fractal", "ring", "xy", "naive"):
        prog = IR.build_program(name, (2, 4))
        a = schedule_on_noc(prog, payload_flits=32)
        b = pipelined_on_noc([prog], payload_flits=[32], ready=[0])
        assert a.overhead == b.overhead, name
        assert a.total_msgs == b.total_msgs


def test_pipelined_ready_gating_delays_later_buckets():
    prog = IR.build_program("fractal", (4, 4))
    solo = schedule_on_noc(prog, payload_flits=16).overhead
    gap = 10 * solo
    pipe = pipelined_on_noc([prog, prog], payload_flits=[16, 16],
                            ready=[0, gap])
    # far-apart ready times: no contention between buckets, second one
    # simply starts at its gate
    assert pipe.program_finish[0] <= gap
    assert pipe.program_finish[1] >= gap
    assert pipe.overhead >= gap


def test_pipelined_overlap_beats_serial_sum():
    progs = [IR.build_program("fractal", (4, 4)) for _ in range(3)]
    flits = [64, 64, 64]
    serial = sum(schedule_on_noc(p, payload_flits=f).overhead
                 for p, f in zip(progs, flits))
    ready = [serial // 3, 2 * serial // 3, serial]
    pipe = pipelined_on_noc(progs, payload_flits=flits, ready=ready)
    assert pipe.overhead < max(ready) + serial
    assert len(pipe.program_finish) == 3
    assert list(pipe.program_finish) == sorted(pipe.program_finish)


def test_pipelined_shape_mismatch_rejected():
    a = IR.build_program("fractal", (2, 2))
    b = IR.build_program("fractal", (4, 4))
    with pytest.raises(ValueError):
        pipelined_on_noc([a, b])
    with pytest.raises(ValueError):
        pipelined_on_noc([])


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_bucket_mb_must_be_positive():
    with pytest.raises(ValueError):
        BSPConfig(bucket_mb=0.0)
    with pytest.raises(ValueError):
        BSPConfig(bucket_mb=-1.0)


# ---------------------------------------------------------------------------
# multi-device numerics (16 host devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bucketed_numerics_subprocess():
    """Bucketed pipelined sync ≡ monolithic sync: ragged pytrees, odd
    bucket boundaries, every schedule and codec (see superstep_checks)."""
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "superstep_checks.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ALL OK" in proc.stdout
