import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    # The tier-1 container has no hypothesis; run the property tests as a
    # deterministic fixed-seed sweep instead of failing collection.
    from _hypothesis_stub import install as _install_hypothesis_stub
    _install_hypothesis_stub()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration test")


def pytest_addoption(parser):
    parser.addoption("--skip-slow", action="store_true", default=False,
                     help="skip tests marked slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--skip-slow"):
        skip = pytest.mark.skip(reason="--skip-slow")
        for item in items:
            if "slow" in item.keywords:
                item.add_marker(skip)
