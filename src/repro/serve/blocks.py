"""Host-side block allocator for the paged KV cache.

The contiguous backend reserves one ``max_len`` cache row per slot, so HBM
caps concurrency at ``pool_positions / max_len`` even when most requests
use a fraction of that.  Paged serving decouples the two: the device holds
one pooled tensor of ``num_blocks`` fixed-size blocks per cache leaf, and
each request maps its *virtual* positions onto physical blocks through a
block table.  This module is the host half of that design — pure Python
bookkeeping, no jax:

  * **free list** — physical blocks are allocated/freed in O(1); block 0 is
    reserved as the SENTINEL: masked decode rows and padded prefill writes
    land there, and block-table padding points at it, so garbage never
    touches a live block.
  * **refcounts + prefix sharing** — fully-written *prompt* blocks are
    published to a content index keyed by the token prefix they encode
    (the exact token tuple, so no hash-collision risk).  A new request
    whose prompt starts with the same tokens maps those positions onto the
    published blocks and only prefills the tail.  Published blocks whose
    last reference drops are RETAINED (moved to an evictable cached pool,
    FIFO-evicted only when the free list runs dry), so a later identical
    prompt still hits even after the original request finished.
  * **copy-on-write** — writes must only touch refcount-1 blocks.  When an
    engine needs to write into a shared block (e.g. the right-aligned tail
    chunk of a prefix-hit prompt re-writes the overlap), it forks the block
    first: ``cow`` hands back a private block id and the caller copies the
    device payload (``transformer.copy_block``) before writing.

The allocator never touches device memory — the engine owns the pooled
tensors and mirrors every decision here onto them.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

# single source of truth for the reserved garbage block: the device-side
# scatter redirect (paged_scatter) and the host-side table padding MUST
# agree on the same id
from repro.models.layers import PAGED_SENTINEL as SENTINEL


class NoFreeBlocks(RuntimeError):
    """The pool is exhausted — the engine preempts or defers admission."""


class BlockAllocator:
    """Refcounted fixed-size block pool with a prompt-prefix content index.

    Invariants (``assert_consistent`` checks them; the property suite in
    ``tests/test_serve_blocks.py`` hammers them under random op sequences):

      * every non-sentinel block is in exactly ONE of three states — on
        the free list, CACHED (published, refcount 0, evictable), or LIVE
        (refcount >= 1);
      * the prefix index only points at live or cached blocks, and each
        indexed block knows its own key (so eviction unpublishes exactly
        its entry); every cached block is indexed;
      * ``num_free + num_used == num_blocks - 1`` (the sentinel is
        pinned), where ``num_free`` counts allocatable blocks — truly
        free PLUS evictable cached.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the sentinel)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # pop() takes from the end: low ids first keeps tests readable
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        self._key_of: Dict[int, Tuple[int, ...]] = {}   # published blocks
        self._index: Dict[Tuple[int, ...], int] = {}    # key -> block
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # FIFO evict

    # -- capacity ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (sentinel excluded)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        """Allocatable blocks: truly free + evictable cached."""
        return len(self._free) + len(self._cached)

    @property
    def num_used(self) -> int:
        """Live (referenced) blocks."""
        return len(self._ref)

    @property
    def num_cached(self) -> int:
        """Published blocks kept alive for future prefix hits."""
        return len(self._cached)

    def blocks_for(self, n_positions: int) -> int:
        """Blocks needed to hold ``n_positions`` cache positions."""
        return -(-n_positions // self.block_size)

    # -- alloc / refcount -------------------------------------------------
    def _unpublish(self, block: int) -> None:
        key = self._key_of.pop(block, None)
        if key is not None and self._index.get(key) == block:
            del self._index[key]

    def alloc(self) -> int:
        """Hand out a fresh block: the free list first, then FIFO-evict
        from the cached pool (evicted content is unpublished before the
        block is reused)."""
        if self._free:
            blk = self._free.pop()
        elif self._cached:
            blk, _ = self._cached.popitem(last=False)   # oldest first
            self._unpublish(blk)
        else:
            raise NoFreeBlocks(f"all {self.capacity} KV blocks in use")
        self._ref[blk] = 1
        return blk

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def incref(self, block: int) -> None:
        if block not in self._ref:
            raise RuntimeError(f"incref on unallocated block {block}")
        self._ref[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block left the live
        set.  Published blocks are RETAINED in the evictable cached pool
        (still indexed — a later identical prompt revives them); private
        blocks go straight back to the free list."""
        if block not in self._ref:
            raise RuntimeError(f"double free of block {block}")
        self._ref[block] -= 1
        if self._ref[block] > 0:
            return False
        del self._ref[block]
        if block in self._key_of:
            self._cached[block] = None
        else:
            self._free.append(block)
        return True

    def free_blocks(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.decref(b)

    def fork(self, blocks: Sequence[int]) -> List[int]:
        """Share an existing table: one new reference per block."""
        for b in blocks:
            self.incref(b)
        return list(blocks)

    def cow(self, block: int) -> Tuple[int, bool]:
        """Make ``block`` writable.  refcount 1 → (block, False); shared →
        allocate a private block, drop one reference on the original, and
        return (new_block, True) — the CALLER must copy the device payload
        before writing (``transformer.copy_block``)."""
        if self.refcount(block) < 1:
            raise RuntimeError(f"cow of unallocated block {block}")
        if self._ref[block] == 1:
            return block, False
        new = self.alloc()              # may raise NoFreeBlocks: state intact
        self.decref(block)
        return new, True

    # -- prompt-prefix content index --------------------------------------
    def prefix_keys(self, prompt: Sequence[int]):
        """Content key per FULL prompt block: the exact token prefix the
        block completes.  Exact tuples, not hashes — a collision would
        silently serve the wrong prefix."""
        bs = self.block_size
        return [tuple(prompt[:(i + 1) * bs])
                for i in range(len(prompt) // bs)]

    def publish(self, block: int, key: Tuple[int, ...]) -> bool:
        """Register a fully-written prompt block under its content key.
        First writer wins: a key that is already indexed (a concurrent
        identical prompt) is left alone.  Returns True when published."""
        if block not in self._ref:
            raise RuntimeError(f"publish of unallocated block {block}")
        if key in self._index or block in self._key_of:
            return False
        self._index[key] = block
        self._key_of[block] = key
        return True

    def match_prefix(self, prompt: Sequence[int]) -> List[int]:
        """Longest run of published blocks matching the prompt's full
        blocks.  Matched blocks come back INCREF'D — the caller owns the
        references (free_blocks to abandon them).  Cached (refcount-0)
        blocks are revived out of the evictable pool."""
        out: List[int] = []
        for key in self.prefix_keys(prompt):
            blk = self._index.get(key)
            if blk is None:
                break
            if blk in self._cached:     # revive: content is still intact
                del self._cached[blk]
                self._ref[blk] = 1
            else:
                self.incref(blk)
            out.append(blk)
        return out

    # -- invariants -------------------------------------------------------
    def assert_consistent(self) -> None:
        free = set(self._free)
        live = set(self._ref)
        cached = set(self._cached)
        assert SENTINEL not in free | live | cached
        assert not (free & live) and not (free & cached) \
            and not (live & cached), "block in two states"
        assert len(free) + len(live) + len(cached) == self.capacity
        assert all(c >= 1 for c in self._ref.values())
        for key, blk in self._index.items():
            assert blk in live or blk in cached, \
                f"index points at freed block {blk}"
            assert self._key_of.get(blk) == key
        for blk in self._key_of:
            assert blk in live or blk in cached
        for blk in cached:
            assert blk in self._key_of, f"cached block {blk} unpublished"

    def __repr__(self) -> str:
        return (f"BlockAllocator(blocks={self.num_blocks}, "
                f"bs={self.block_size}, free={len(self._free)}, "
                f"cached={self.num_cached}, used={self.num_used}, "
                f"published={len(self._index)})")
