"""Serving metrics: per-request TTFT, per-step throughput, slot occupancy.

Two clocks run side by side:

  * the **step counters** — deterministic tallies (decode steps, tokens
    out, active-slot sums) that benchmarks and CI assert on;
  * the **serve clock** behind ``now()`` — either measured wall seconds
    (``clock="wall"``: human-facing tok/s and TTFT, noisy on shared CI
    machines, never asserted) or a VIRTUAL step clock (``clock="step"``,
    the engine default): time advances ``step_s`` per engine step via
    ``tick()`` and jumps forward via ``wait_until()`` instead of sleeping
    — deterministic TTFTs, and serve loops never block on arrival gaps.

``occupancy`` is the serve engine's headline number: the fraction of
slot-steps that decoded a live request.  The wave baseline burns slot-steps
on padding until the longest request in the wave drains; continuous
admission refills slots the moment EOS frees them.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator: O(1) memory,
    one pass — the soak harness runs for thousands of steps and cannot
    afford (nor needs) to sort the full latency history.  Exact below 5
    observations, piecewise-parabolic marker interpolation after.
    """

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {q}")
        self.q = q
        self.n = 0
        self._heights: List[float] = []          # 5 marker heights
        self._pos: List[float] = []              # marker positions (1-based)
        self._want: List[float] = []             # desired positions
        self._inc = (0.0, q / 2, q, (1 + q) / 2, 1.0)

    def add(self, x: float) -> None:
        self.n += 1
        if self.n <= 5:
            self._heights.append(float(x))
            self._heights.sort()
            if self.n == 5:
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0, 1 + 2 * self.q, 1 + 4 * self.q,
                              3 + 2 * self.q, 5.0]
            return
        h, pos = self._heights, self._pos
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._inc[i]
        for i in (1, 2, 3):
            d = self._want[i] - pos[i]
            if (d >= 1 and pos[i + 1] - pos[i] > 1) or \
                    (d <= -1 and pos[i - 1] - pos[i] < -1):
                d = 1.0 if d > 0 else -1.0
                # parabolic (P²) update, clamped to stay monotone
                hp = h[i] + d / (pos[i + 1] - pos[i - 1]) * (
                    (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i])
                    / (pos[i + 1] - pos[i])
                    + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1])
                    / (pos[i] - pos[i - 1]))
                if not h[i - 1] < hp < h[i + 1]:
                    hp = h[i] + d * (h[i + int(d)] - h[i]) \
                        / (pos[i + int(d)] - pos[i])
                h[i] = hp
                pos[i] += d

    @property
    def value(self) -> float:
        if self.n == 0:
            return float("nan")
        if self.n <= 5:
            xs = self._heights
            i = min(len(xs) - 1, int(round(self.q * (len(xs) - 1))))
            return xs[i]
        return self._heights[2]


@dataclass
class RequestRecord:
    req_id: int
    arrival_s: float = 0.0
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    prompt_len: int = 0
    tokens_out: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s


@dataclass
class ServeMetrics:
    max_slots: int = 1
    requests: Dict[int, RequestRecord] = field(default_factory=dict)
    decode_steps: int = 0
    active_slot_steps: int = 0       # Σ over decode steps of live slots
    decode_tokens: int = 0           # tokens produced by decode steps
    prefill_chunks: int = 0
    prefill_tokens: int = 0
    peak_active: int = 0             # max live slots in any decode step
    # paged-KV gauges (stay 0 for the contiguous backend):
    prefix_hit_tokens: int = 0       # prompt tokens served from shared blocks
    prefix_lookup_tokens: int = 0    # prompt tokens that went through lookup
    blocks_in_use: int = 0           # current allocated blocks
    blocks_peak: int = 0             # high-water mark
    blocks_total: int = 0            # pool capacity (sentinel excluded)
    preemptions: int = 0             # preempt-and-requeue events
    wasted_decode_tokens: int = 0    # decode tokens discarded by preemption
    queue_depth: int = 0             # admission backlog (gauge, per step)
    queue_peak: int = 0              # backlog high-water mark
    # event logs for windowed trend analysis (the soak harness turns them
    # on; OFF by default so long-lived engines pay nothing):
    record_events: bool = False
    ttft_events: List[Tuple[float, float]] = field(default_factory=list)
    tpot_events: List[Tuple[float, float]] = field(default_factory=list)
    clock: str = "wall"              # "wall" (measured) | "step" (virtual)
    step_s: float = 0.01             # virtual seconds per engine step
    _t0: Optional[float] = None
    _vt: float = 0.0                 # virtual clock position (step mode)
    wall_s: float = 0.0
    # streaming percentile estimators (P², O(1) memory): always on — a
    # preempted-and-reserved request contributes BOTH its ttft samples
    # (the stream sees what clients saw; the per-request record keeps
    # only the final one)
    p2_ttft_p50: P2Quantile = field(default_factory=lambda: P2Quantile(0.5))
    p2_ttft_p99: P2Quantile = field(default_factory=lambda: P2Quantile(0.99))
    p2_tpot_p99: P2Quantile = field(default_factory=lambda: P2Quantile(0.99))

    # -- clock ------------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        if self.clock == "step":
            return self._vt
        if self._t0 is None:
            self.start()
        return time.monotonic() - self._t0

    def tick(self) -> None:
        """One engine step elapsed (virtual clock; wall mode is a no-op —
        real time passed on its own)."""
        if self.clock == "step":
            self._vt += self.step_s

    def wait_until(self, t: float) -> None:
        """Idle until the serve clock reaches ``t``: the virtual clock
        jumps (deterministic, instant), the wall clock sleeps."""
        if self.clock == "step":
            self._vt = max(self._vt, t)
            return
        now = self.now()
        if t > now:
            time.sleep(t - now)

    def stop(self) -> None:
        self.wall_s = self.now()

    # -- events -----------------------------------------------------------
    def on_submit(self, req_id: int, arrival_s: float, prompt_len: int) -> None:
        self.requests[req_id] = RequestRecord(
            req_id=req_id, arrival_s=arrival_s, prompt_len=prompt_len)

    def on_admit(self, req_id: int) -> None:
        self.requests[req_id].admitted_s = self.now()

    def on_prefill_chunk(self, n_tokens: int) -> None:
        self.prefill_chunks += 1
        self.prefill_tokens += n_tokens

    def on_first_token(self, req_id: int) -> None:
        r = self.requests[req_id]
        r.first_token_s = self.now()
        r.tokens_out += 1
        ttft = r.first_token_s - r.arrival_s
        self.p2_ttft_p50.add(ttft)
        self.p2_ttft_p99.add(ttft)
        if self.record_events:
            self.ttft_events.append((r.first_token_s, ttft))

    def on_decode_step(self, n_active: int) -> None:
        self.decode_steps += 1
        self.active_slot_steps += n_active
        self.decode_tokens += n_active
        self.peak_active = max(self.peak_active, n_active)

    def on_token(self, req_id: int) -> None:
        self.requests[req_id].tokens_out += 1

    def on_finish(self, req_id: int) -> None:
        r = self.requests[req_id]
        r.finished_s = self.now()
        if r.first_token_s is not None and r.tokens_out > 1:
            tpot = (r.finished_s - r.first_token_s) / (r.tokens_out - 1)
            self.p2_tpot_p99.add(tpot)
            if self.record_events:
                self.tpot_events.append((r.finished_s, tpot))

    def on_queue_depth(self, depth: int) -> None:
        """Admission-backlog gauge, sampled once per engine step."""
        self.queue_depth = depth
        self.queue_peak = max(self.queue_peak, depth)

    def on_prefix_lookup(self, hit_tokens: int, total_tokens: int) -> None:
        """One admission's prefix-cache outcome: ``hit_tokens`` of the
        ``total_tokens``-long prompt were served from shared blocks."""
        self.prefix_hit_tokens += hit_tokens
        self.prefix_lookup_tokens += total_tokens

    def on_blocks(self, in_use: int, total: int) -> None:
        """Block-pool gauge sample (paged backend)."""
        self.blocks_in_use = in_use
        self.blocks_peak = max(self.blocks_peak, in_use)
        self.blocks_total = total

    def on_preempt(self, req_id: int) -> None:
        """A mid-flight request lost its resources and went back to the
        queue: its per-request record restarts (tokens regenerate exactly
        on re-serve — the fold-in RNG makes the retry invisible in
        outputs).  The discarded work is BOOKED, not erased: of the
        request's ``tokens_out``, all but the first (which came from the
        prefill logits) were produced by decode steps whose
        ``decode_tokens`` tally keeps counting them — they land in
        ``wasted_decode_tokens`` so throughput accounting stays exact:
        ``decode_tokens == (tokens_out - first_tokens) + wasted``."""
        self.preemptions += 1
        r = self.requests[req_id]
        if r.first_token_s is not None and r.tokens_out > 0:
            self.wasted_decode_tokens += r.tokens_out - 1
        r.admitted_s = None
        r.first_token_s = None
        r.finished_s = None
        r.tokens_out = 0

    # -- aggregates -------------------------------------------------------
    @property
    def tokens_out(self) -> int:
        return sum(r.tokens_out for r in self.requests.values())

    @property
    def first_tokens(self) -> int:
        """Requests whose (current) first token is live — first tokens come
        from prefill logits, so they are excluded from decode accounting."""
        return sum(1 for r in self.requests.values()
                   if r.first_token_s is not None)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of looked-up prompt tokens served from shared blocks."""
        if self.prefix_lookup_tokens == 0:
            return 0.0
        return self.prefix_hit_tokens / self.prefix_lookup_tokens

    @property
    def occupancy(self) -> float:
        """Fraction of decode slot-steps spent on live requests."""
        if self.decode_steps == 0:
            return 0.0
        return self.active_slot_steps / (self.decode_steps * self.max_slots)

    @property
    def tokens_per_step(self) -> float:
        """Decode tokens per decode step — the deterministic throughput
        proxy: per-step cost is shape-constant, so tok/s ∝ tokens/step."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_tokens / self.decode_steps

    def ttfts(self) -> List[float]:
        return sorted(r.ttft_s for r in self.requests.values()
                      if r.ttft_s is not None)

    def _pct(self, xs: List[float], q: float) -> float:
        if not xs:
            return float("nan")
        i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
        return xs[i]

    def summary(self) -> Dict[str, float]:
        ttfts = self.ttfts()
        wall = self.wall_s or self.now()
        return {
            "requests": len(self.requests),
            "completed": sum(1 for r in self.requests.values()
                             if r.finished_s is not None),
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "tokens_per_step": self.tokens_per_step,
            "occupancy": self.occupancy,
            "peak_active": self.peak_active,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hit_rate": self.prefix_hit_rate,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "blocks_in_use": self.blocks_in_use,
            "blocks_peak": self.blocks_peak,
            "blocks_total": self.blocks_total,
            "preemptions": self.preemptions,
            "wasted_decode_tokens": self.wasted_decode_tokens,
            "first_tokens": self.first_tokens,
            "queue_peak": self.queue_peak,
            "ttft_mean_s": (sum(ttfts) / len(ttfts)) if ttfts else float("nan"),
            "ttft_p50_s": self._pct(ttfts, 0.50),
            "ttft_p95_s": self._pct(ttfts, 0.95),
            "ttft_p99_s": self._pct(ttfts, 0.99),
            # streaming (P²) views — what a week-long soak reports when the
            # per-request table is long gone
            "ttft_p50_stream_s": self.p2_ttft_p50.value,
            "ttft_p99_stream_s": self.p2_ttft_p99.value,
            "tpot_p99_stream_s": self.p2_tpot_p99.value,
            "wall_s": wall,
            "tokens_per_s": self.tokens_out / wall if wall > 0 else 0.0,
        }

    def report(self) -> str:
        s = self.summary()
        lines = [
            f"requests : {s['completed']:.0f}/{s['requests']:.0f} completed, "
            f"{s['tokens_out']:.0f} tokens out",
            f"decode   : {s['decode_steps']:.0f} steps, "
            f"{s['tokens_per_step']:.2f} tok/step, "
            f"occupancy {s['occupancy'] * 100:.1f}%, "
            f"peak {s['peak_active']:.0f} slots",
            f"prefill  : {s['prefill_chunks']:.0f} chunks, "
            f"{s['prefill_tokens']:.0f} tokens",
        ]
        if s["blocks_total"]:
            lines.append(
                f"paged    : prefix hit-rate "
                f"{s['prefix_hit_rate'] * 100:.1f}% "
                f"({s['prefix_hit_tokens']:.0f} tokens), blocks "
                f"{s['blocks_in_use']:.0f}/{s['blocks_total']:.0f} "
                f"(peak {s['blocks_peak']:.0f}), "
                f"preemptions {s['preemptions']:.0f}")
        if s["preemptions"]:
            lines.append(
                f"preempt  : {s['wasted_decode_tokens']:.0f} decode tokens "
                "discarded (regenerated exactly on re-serve)")
        lines += [
            f"ttft     : mean {s['ttft_mean_s'] * 1e3:.1f} ms, "
            f"p50 {s['ttft_p50_s'] * 1e3:.1f} ms, "
            f"p95 {s['ttft_p95_s'] * 1e3:.1f} ms",
            f"wall     : {s['wall_s']:.2f} s, "
            f"{s['tokens_per_s']:.0f} tok/s",
        ]
        return "\n".join(lines)
