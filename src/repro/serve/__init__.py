"""Continuous-batching serve subsystem.

A fixed pool of decode slots over one shared KV cache; queued requests are
admitted into slots the moment capacity frees, with chunked prefill
interleaved between decode steps.  Two KV backends sit behind the same
engine interface: contiguous per-slot rows (slot-count admission) and
paged blocks (block-count admission, prefix sharing, preemption).

  engine.ServeEngine    the continuous-batching core (jit-stable decode)
  engine.serve_waves    the wave-at-a-time baseline (for A/B benchmarks)
  blocks.BlockAllocator paged-KV host allocator (free list, refcounts,
                        prefix index, copy-on-write)
  slots.SlotTable       host-side slot bookkeeping mirroring device state
  queue.RequestQueue    arrival-time-gated admission queue + generators
  metrics.ServeMetrics  per-request TTFT, per-step throughput, occupancy,
                        prefix hit-rate and block-pool gauges
"""

from .blocks import BlockAllocator, NoFreeBlocks, SENTINEL  # noqa: F401
from .engine import EngineConfig, ServeEngine, serve_waves  # noqa: F401
from .metrics import ServeMetrics  # noqa: F401
from .queue import (Request, RequestQueue, poisson_arrivals,  # noqa: F401
                    parse_arrival_spec, trace_arrivals)
from .slots import SlotTable  # noqa: F401
