"""Continuous-batching serve subsystem.

A fixed pool of decode slots over one shared cache; queued requests are
admitted into slots the moment capacity frees, with chunked prefill
interleaved between decode steps.  Per-layer decode state goes through the
SlotState protocol — three backends behind one engine interface, composed
per layer from the architecture config (hybrid stacks mix them):

  * contiguous KV rows   (slot-count admission)
  * paged KV blocks      (block-count admission, prefix sharing, preemption)
  * recurrent state rows (row-count admission; O(1), never grows)

  engine.ServeEngine       the continuous-batching core (jit-stable decode)
  engine.serve_waves       wave-at-a-time baseline — the token-identity
                           TEST ORACLE (and the A/B benchmark baseline)
  slot_state.StatePlan     per-layer backend resolution from an ArchConfig
  slot_state.RecurrentRows pooled recurrent-row allocator (row 0 sentinel)
  blocks.BlockAllocator    paged-KV host allocator (free list, refcounts,
                           prefix index, copy-on-write)
  slots.SlotTable          host-side slot bookkeeping mirroring device state
  queue.RequestQueue       arrival-time-gated admission heap + generators
  metrics.ServeMetrics     per-request TTFT, per-step throughput, occupancy,
                           preemption waste, block-pool gauges — on a wall
                           OR virtual step clock (deterministic timing)
  metrics.P2Quantile       O(1)-memory streaming quantile (P² algorithm)
  soak.run_soak            fault-injected sustained-load soak + SLO-recovery
                           harness (consumes a runtime.chaos.FaultPlan)
"""

from .blocks import BlockAllocator, NoFreeBlocks, SENTINEL  # noqa: F401
from .engine import EngineConfig, ServeEngine, serve_waves  # noqa: F401
from .metrics import P2Quantile, ServeMetrics  # noqa: F401
from .queue import (Request, RequestQueue, burst_arrivals,  # noqa: F401
                    poisson_arrivals, parse_arrival_spec, trace_arrivals)
from .soak import (SoakConfig, SoakResult, check_recovery,  # noqa: F401
                   run_soak)
from .slot_state import (NoFreeRows, REC_SENTINEL,  # noqa: F401
                         RecurrentRows, StatePlan)
from .slots import SlotTable  # noqa: F401
