"""Continuous-batching serve subsystem.

A fixed pool of decode slots over the shared ring KV cache; queued requests
are admitted into slots the moment EOS (or the per-request token budget)
frees them, with chunked prefill interleaved between decode steps.

  engine.ServeEngine    the continuous-batching core (jit-stable decode)
  engine.serve_waves    the wave-at-a-time baseline (for A/B benchmarks)
  slots.SlotTable       host-side slot bookkeeping mirroring device state
  queue.RequestQueue    arrival-time-gated admission queue + generators
  metrics.ServeMetrics  per-request TTFT, per-step throughput, occupancy
"""

from .engine import EngineConfig, ServeEngine, serve_waves  # noqa: F401
from .metrics import ServeMetrics  # noqa: F401
from .queue import (Request, RequestQueue, poisson_arrivals,  # noqa: F401
                    parse_arrival_spec, trace_arrivals)
from .slots import SlotTable  # noqa: F401
