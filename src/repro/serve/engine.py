"""Continuous-batching serve engine with slot-level admission.

The wave-based loop this replaces admitted B requests, decoded until the
whole wave drained, and only then admitted again — freed slots idled behind
the wave's straggler.  Here a fixed pool of ``max_slots`` decode slots runs
over one shared ring KV cache (the slot index IS the cache batch row) and a
queued request is admitted the moment EOS or the per-request budget frees a
slot:

  * **jit-stable decode**: every decode step is one compiled call over the
    full [S] slot batch — fixed slot count, per-slot cache offsets (the
    vector-``offset`` form of ``transformer.decode_step``), inactive rows
    masked by writing to the cache sentinel position the causal mask hides.
    Slot churn never recompiles anything.
  * **chunked admission prefill**: prompts stream through one compiled
    [1, prefill_chunk] function (``transformer.prefill_chunk``) into the
    admitted slot's cache row, interleaved between decode steps so ongoing
    decodes keep making progress while newcomers prefill.
  * **single RNG split discipline**: token t of request r is sampled with
    ``fold_in(fold_in(seed_key, r), t)`` — including the FIRST token (the
    wave-era loop sampled it from the unsplit top-level key).  Sampling is
    deterministic per request, independent of slot assignment, admission
    order, or pool size.
  * **mesh composition**: given a 1-axis ("data",) mesh the slot batch dim
    of the cache and every per-step input shards across devices; params are
    replicated (serve-style), activations follow ``act_sharding``.

``serve_waves`` keeps the old wave-at-a-time loop alive as the measured
baseline for ``benchmarks/serve_bench.py``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models.transformer import ATTN_KINDS, MLA_KINDS

from .metrics import ServeMetrics
from .queue import Request, RequestQueue
from .slots import SlotTable


@dataclass(frozen=True)
class EngineConfig:
    """Engine knobs (everything the serve CLI exposes lands here)."""

    max_slots: int = 8
    max_len: int = 256           # cache positions per slot (prompt + gen)
    prefill_chunk: int = 16      # admission prefill chunk length
    chunks_per_step: int = 1     # prefill chunks interleaved per decode step
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0


def _check_arch(cfg: ArchConfig, *, allow_recurrent: bool = False) -> None:
    """Slot reuse needs positional caches: a freed row is reclaimed by
    masking, not by replaying state.  Recurrent caches (mamba/xlstm) would
    advance on chunk padding and carry the evicted request's state — the
    CONTINUOUS engine rejects them loudly rather than serving wrongly; the
    wave baseline batch-prefills without chunk padding and may keep them
    (``allow_recurrent=True``).  The frontend (prefix-image) path needs
    per-request embeddings at admission: rejected in both modes (requests
    are token-only)."""
    if cfg.frontend:
        raise ValueError(
            f"{cfg.name}: frontend architectures are not servable "
            "(requests are token-only)")
    if allow_recurrent:
        return
    for unit, _reps in cfg.segments():
        for kind in unit:
            if kind not in ATTN_KINDS and kind not in MLA_KINDS:
                raise ValueError(
                    f"{cfg.name}: layer kind {kind!r} has a recurrent "
                    "cache; the continuous engine supports attention/MLA "
                    "architectures (--mode wave still serves it)")


def _make_sampler(base_key, temperature: float):
    """The single RNG split discipline both serving modes share: token t of
    request r is drawn with ``fold_in(fold_in(base_key, r), t)``.  One
    definition — the wave/continuous token-identity invariant (asserted in
    ``benchmarks/serve_bench.py``) depends on the two modes never drifting.
    """

    def sample(logits, req_ids, tok_idx):
        """logits [N,V] → tokens [N]."""
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def one(l, r, t):
            k = jax.random.fold_in(jax.random.fold_in(base_key, r), t)
            return jax.random.categorical(k, l / temperature).astype(
                jnp.int32)

        return jax.vmap(one)(logits, req_ids, tok_idx)

    return sample


class ServeEngine:
    """Fixed slot pool + shared ring KV cache + admission queue."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 mesh=None):
        _check_arch(cfg)
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh
        if ecfg.chunks_per_step < 1:
            raise ValueError("chunks_per_step must be >= 1")
        if ecfg.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        # a padded chunk must fit the cache row (a clamped dynamic-slice
        # write would silently shift over live positions)
        self._chunk = min(ecfg.prefill_chunk, ecfg.max_len)
        self.table = SlotTable(ecfg.max_slots, ecfg.max_len)
        self.queue = RequestQueue()
        self.metrics = ServeMetrics(max_slots=ecfg.max_slots)
        self.results: Dict[int, List[int]] = {}
        self._key = jax.random.key(ecfg.seed)

        self._data_spec = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            if ecfg.max_slots % mesh.devices.size:
                raise ValueError(
                    f"--max-slots {ecfg.max_slots} must divide across "
                    f"{mesh.devices.size} devices")
            self._data_spec = lambda ndim: NamedSharding(
                mesh, P("data", *([None] * (ndim - 1))))
            replicated = NamedSharding(mesh, P())
            params = jax.device_put(params, jax.tree.map(
                lambda _: replicated, params))
        self.params = params

        cache = T.init_cache(cfg, ecfg.max_slots, ecfg.max_len)
        if self._data_spec is not None:
            # cache leaves are [reps, S, ...]: slot batch dim is axis 1
            from jax.sharding import NamedSharding, PartitionSpec as P
            cache = jax.tree.map(
                lambda x: jax.device_put(x, NamedSharding(
                    mesh, P(None, "data", *([None] * (x.ndim - 2))))), cache)
        self.cache = cache

        self._decode = jax.jit(
            lambda p, tok, c, off: T.decode_step(p, cfg, tok, c, off))
        self._sample = jax.jit(_make_sampler(self._key, ecfg.temperature))
        # admission: slice the slot's row, prefill one chunk into it, write
        # it back — one compiled function per variant, traced slot index.
        # Interior chunks only feed the cache, so they skip the full-vocab
        # head projection (the dominant admission FLOPs at real vocab sizes)
        def admit(with_logits):
            def fn(p, c, tokens, slot, offset):
                sub = T.take_slot(c, slot)
                logits, sub = T.prefill_chunk(p, cfg, tokens, sub, offset,
                                              with_logits=with_logits)
                return logits, T.write_slot(c, sub, slot)
            return jax.jit(fn)
        self._admit = admit(True)
        self._admit_quiet = admit(False)
        self._reset = jax.jit(T.reset_slot)

    def _put(self, x):
        if self._data_spec is None:
            return x
        return jax.device_put(x, self._data_spec(np.ndim(x)))

    # -- request intake ---------------------------------------------------
    def submit(self, requests) -> None:
        if isinstance(requests, Request):
            requests = [requests]
        # validate the WHOLE batch before recording anything: a bad request
        # must not leave phantom metrics records for its batchmates
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > self.ecfg.max_len:
                raise ValueError(
                    f"request {r.req_id}: prompt+gen {need} exceeds "
                    f"max_len {self.ecfg.max_len}")
        for r in requests:
            self.metrics.on_submit(r.req_id, r.arrival_s, len(r.prompt))
        self.queue.submit(requests)

    # -- engine phases (one call each per step) ---------------------------
    def _admit_ready(self, now_s: float) -> None:
        for slot in self.table.free():
            req = self.queue.pop_ready(now_s)
            if req is None:
                return
            self.table.assign(slot, req)
            self.cache = self._reset(self.cache, slot.index)
            self.metrics.on_admit(req.req_id)

    def _finish(self, slot) -> None:
        req = slot.request
        self.results[req.req_id] = list(slot.output)
        self.table.release(slot)
        self.metrics.on_finish(req.req_id)

    def _complete_if_done(self, slot, token: int) -> bool:
        eos = self.ecfg.eos_id
        if (eos is not None and token == eos) \
                or slot.generated >= slot.request.max_new_tokens:
            self._finish(slot)
            return True
        return False

    def _prefill_tick(self) -> None:
        """Advance up to ``chunks_per_step`` admission prefills one chunk.

        Chunk geometry keeps every write in-bounds without padding leaking
        past the prompt: short prompts (≤ chunk) pad at the END (garbage
        positions are causally masked until overwritten by decode); a
        ragged TAIL chunk is RIGHT-ALIGNED at ``plen - chunk``, re-writing
        the overlap with bit-identical k/v (k/v at a position depend only
        on its token, its position, and the already-written prefix).
        """
        C = self._chunk
        budget = self.ecfg.chunks_per_step
        for slot in self.table.prefilling():
            if budget <= 0:
                return
            prompt = np.asarray(slot.request.prompt, np.int32)
            plen = len(prompt)
            remaining = plen - slot.prefill_pos
            chunk = np.zeros((1, C), np.int32)
            if plen <= C:                       # whole prompt, end-padded
                start, last_row = 0, plen - 1
                chunk[0, :plen] = prompt
            elif remaining > C:                 # full interior chunk
                start, last_row = slot.prefill_pos, C - 1
                chunk[0] = prompt[start:start + C]
            else:                               # right-aligned tail chunk
                start, last_row = plen - C, C - 1
                chunk[0] = prompt[start:plen]
            final = remaining <= C
            admit = self._admit if final else self._admit_quiet
            logits, self.cache = admit(
                self.params, self.cache, jnp.asarray(chunk),
                slot.index, start)
            slot.prefill_pos += remaining if remaining <= C else C
            slot.length = slot.prefill_pos
            self.metrics.on_prefill_chunk(min(remaining, C))
            budget -= 1
            if slot.prefill_pos >= plen:
                # prompt fully cached: sample the request's token 0 from the
                # logits at the REAL last prompt position of this chunk
                row = jnp.asarray(logits)[:, last_row]          # [1,V]
                tok = int(self._sample(
                    row, jnp.asarray([slot.req_id], jnp.int32),
                    jnp.asarray([0], jnp.int32))[0])
                self.table.activate(slot, tok)
                self.metrics.on_first_token(slot.req_id)
                self._complete_if_done(slot, tok)

    def _decode_tick(self) -> None:
        if self.table.n_active == 0:
            return
        tokens, offsets, active, req_ids, tok_idx = self.table.decode_inputs()
        logits, self.cache = self._decode(
            self.params, self._put(jnp.asarray(tokens)), self.cache,
            self._put(jnp.asarray(offsets)))
        toks = np.asarray(self._sample(
            logits[:, 0], self._put(jnp.asarray(req_ids)),
            self._put(jnp.asarray(tok_idx))))
        self.metrics.on_decode_step(int(active.sum()))
        for slot in self.table.active():
            tok = int(toks[slot.index])
            slot.length += 1          # pending token was cached this step
            slot.pending_token = tok
            slot.generated += 1
            slot.output.append(tok)
            self.metrics.on_token(slot.req_id)
            self._complete_if_done(slot, tok)

    def step(self) -> None:
        """One engine iteration: admissions, a prefill tick, a decode step."""
        self._admit_ready(self.metrics.now())
        self._prefill_tick()
        self._decode_tick()

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> Dict[int, List[int]]:
        """Serve until the queue and every slot drain; returns outputs."""
        if requests:
            self.submit(list(requests))
        self.metrics.start()
        while len(self.queue) or self.table.busy():
            if not self.table.busy():
                nxt = self.queue.next_arrival()
                now = self.metrics.now()
                if nxt is not None and nxt > now:
                    time.sleep(min(nxt - now, 0.01))   # open-loop idle
            self.step()
        self.metrics.stop()
        return self.results


# ---------------------------------------------------------------------------
# wave-at-a-time baseline (what PR 2 shipped) — kept for A/B benchmarks
# ---------------------------------------------------------------------------


def serve_waves(cfg: ArchConfig, params, ecfg: EngineConfig,
                requests: Sequence[Request]):
    """Admit ≤ max_slots requests per wave; decode until the wave drains.

    Freed slots idle until the whole wave finishes — the occupancy/
    throughput gap to ``ServeEngine`` on ragged output lengths is exactly
    what ``benchmarks/serve_bench.py`` measures.  Prompts within a wave
    must share one length (the wave loop batch-prefills).  Sampling uses
    the same fold-in discipline, so per-request outputs match the
    continuous engine token for token.
    """
    _check_arch(cfg, allow_recurrent=True)
    S, max_len = ecfg.max_slots, ecfg.max_len
    metrics = ServeMetrics(max_slots=S)
    results: Dict[int, List[int]] = {}

    prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c, None))
    decode = jax.jit(lambda p, t, c, o: T.decode_step(p, cfg, t, c, o))
    sample_j = jax.jit(_make_sampler(jax.random.key(ecfg.seed),
                                     ecfg.temperature))

    reqs = sorted(requests, key=lambda r: (r.arrival_s, r.req_id))
    for r in reqs:
        metrics.on_submit(r.req_id, r.arrival_s, len(r.prompt))
    metrics.start()
    for w0 in range(0, len(reqs), S):
        wave = reqs[w0:w0 + S]
        plens = {len(r.prompt) for r in wave}
        if len(plens) != 1:
            raise ValueError("wave baseline needs uniform prompt lengths "
                             f"within a wave, got {sorted(plens)}")
        P = plens.pop()
        # a wave starts only once its LAST member arrived — slots freed
        # mid-wave cannot admit (that is the baseline's pathology)
        wave_start = max(r.arrival_s for r in wave)
        now = metrics.now()
        if wave_start > now:
            time.sleep(wave_start - now)
        B = len(wave)
        cache = T.init_cache(cfg, B, max_len)
        prompts = jnp.asarray([list(r.prompt) for r in wave], jnp.int32)
        req_ids = jnp.asarray([r.req_id for r in wave], jnp.int32)
        for r in wave:
            metrics.on_admit(r.req_id)
        logits, cache, offset = prefill(params, prompts, cache)
        metrics.on_prefill_chunk(B * P)
        toks = np.asarray(sample_j(logits[:, -1], req_ids,
                                   jnp.zeros((B,), jnp.int32)))
        outs = [[int(t)] for t in toks]
        done = np.zeros((B,), bool)
        for i, r in enumerate(wave):
            metrics.on_first_token(r.req_id)
            if (ecfg.eos_id is not None and outs[i][0] == ecfg.eos_id) \
                    or r.max_new_tokens == 1:
                done[i] = True
                metrics.on_finish(r.req_id)
        gen = 1
        max_gen = max(r.max_new_tokens for r in wave)
        while not done.all() and gen < max_gen:
            tok_in = jnp.asarray(toks, jnp.int32)[:, None]
            logits, cache = decode(params, tok_in, cache,
                                   jnp.asarray(P + gen - 1, jnp.int32))
            toks = np.asarray(sample_j(
                logits[:, 0], req_ids, jnp.full((B,), gen, jnp.int32)))
            metrics.on_decode_step(int((~done).sum()))
            for i, r in enumerate(wave):
                if done[i]:
                    continue       # slot idles until the wave drains
                outs[i].append(int(toks[i]))
                metrics.on_token(r.req_id)
                if (ecfg.eos_id is not None and outs[i][-1] == ecfg.eos_id) \
                        or len(outs[i]) >= r.max_new_tokens:
                    done[i] = True
                    metrics.on_finish(r.req_id)
            gen += 1
        for i, r in enumerate(wave):
            results[r.req_id] = outs[i]
            if not done[i]:
                metrics.on_finish(r.req_id)
    metrics.stop()
    return results, metrics
