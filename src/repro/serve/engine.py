"""Continuous-batching serve engine over the SlotState protocol: per-layer
decode-state backends (contiguous KV, paged KV, recurrent rows) composed
from the architecture config.

The wave-based loop this replaces admitted B requests, decoded until the
whole wave drained, and only then admitted again — freed slots idled behind
the wave's straggler.  Here a fixed pool of ``max_slots`` decode slots runs
over one shared cache and a queued request is admitted the moment EOS or
the per-request budget frees a slot:

  * **jit-stable decode**: every decode step is one compiled call over the
    full [S] slot batch — fixed slot count, per-slot cache offsets (the
    vector-``offset`` form of ``transformer.decode_step``), inactive rows
    masked by writing to the cache sentinel position the causal mask hides
    (KV) and by gating the state advance on the sentinel row (recurrent).
    Slot churn never recompiles anything.
  * **chunked admission prefill**: prompts stream through one compiled
    [1, prefill_chunk] function (``transformer.prefill_chunk``) into the
    admitted slot's state, interleaved between decode steps so ongoing
    decodes keep making progress while newcomers prefill.
  * **single RNG split discipline**: token t of request r is sampled with
    ``fold_in(fold_in(seed_key, r), t)`` — including the FIRST token (the
    wave-era loop sampled it from the unsplit top-level key).  Sampling is
    deterministic per request, independent of slot assignment, admission
    order, pool size, state backend, or preemption.
  * **mesh composition**: given a 1-axis ("data",) mesh the slot batch dim
    of every per-step input shards across devices; params are replicated
    (serve-style), activations follow ``act_sharding``.

Per-layer state backends (``serve.slot_state.StatePlan``): attention / MLA
layers follow the engine's KV mode, recurrent layers (mamba / xLSTM)
always take the recurrent-row backend — hybrid stacks (Jamba) mix both
inside one engine run:

  * ``contiguous`` KV — one ``max_len`` cache row per slot (the slot index
    IS the cache batch row); admission is free-slot driven.  Simple, but
    HBM caps concurrency at ``pool_positions / max_len`` even when
    requests use a fraction of their reservation.
  * ``paged`` KV — one pooled tensor of ``kv_blocks`` × ``block_size``
    positions per cache leaf; each slot maps virtual positions onto
    physical blocks through a block table (``blocks.BlockAllocator`` owns
    the host bookkeeping).  Admission is free-BLOCK driven, identical
    prompt prefixes share refcounted blocks (copy-on-write when a shared
    block must be rewritten), and when the pool runs dry mid-decode the
    YOUNGEST request is preempted: its resources are freed and the request
    requeued — the fold-in RNG regenerates its tokens exactly on re-serve,
    so preemption is invisible in outputs.

    Token identity with the contiguous backend holds by construction:
    ``max_len % block_size == 0`` makes the gathered virtual KV view the
    same shape AND the same values as a contiguous row, and prefix-cache
    hits are rounded down to the prefill-chunk grid so chunk boundaries —
    hence the cached k/v content — match a from-scratch prefill (the
    paged suite and serve benchmarks assert exact token identity end to
    end).
  * ``recurrent`` rows — O(1) per-request state in a pooled
    ``[rec_slots + 1, ...]`` leaf (row 0 = sentinel).  Admission takes one
    row (a SECOND resource next to KV blocks: both must be free before
    either commits); the row never grows, so recurrent state can defer
    admission but never triggers mid-decode preemption.  Prefill chunks
    stay on the aligned ``[k·C, (k+1)·C)`` grid with the padded tail gated
    off by a validity mask — the state advances over every prompt token
    exactly once, which is what makes continuous-path outputs
    token-identical to the wave loop.  Prefix-cache sharing is disabled
    for recurrent-bearing archs: a prefix hit would skip the state
    computation the recurrence needs.

``serve_waves`` keeps the old wave-at-a-time loop alive as the TEST ORACLE
(plus the measured baseline for ``benchmarks/serve_bench.py``): it batch-
prefills whole prompts with no chunking, no masking and no slot reuse, so
any engine output can be checked against it token for token.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

from .blocks import BlockAllocator, NoFreeBlocks
from .metrics import ServeMetrics
from .queue import Request, RequestQueue
from .slot_state import RecurrentRows, StatePlan
from .slots import ACTIVE, PREFILL, SlotTable


@dataclass(frozen=True)
class EngineConfig:
    """Engine knobs (everything the serve CLI exposes lands here)."""

    max_slots: int = 8
    max_len: int = 256           # cache positions per request (prompt + gen)
    prefill_chunk: int = 16      # admission prefill chunk length
    chunks_per_step: int = 1     # prefill chunks interleaved per decode step
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0
    kv_mode: str = "contiguous"  # "contiguous" | "paged"
    slot_state: str = "auto"     # "auto" (follow kv_mode) | "contiguous" |
                                 # "paged" — KV-layer backend override;
                                 # recurrent layers always take the
                                 # recurrent-row backend
    rec_slots: int = 0           # recurrent rows (0 = match max_slots);
                                 # < max_slots makes rows the scarce
                                 # admission resource
    block_size: int = 16         # paged: positions per physical block
    kv_blocks: int = 0           # paged: pool size (0 = match contiguous
                                 # capacity: 1 + max_slots * max_len / bs)
    paged_kernel: str = "auto"   # paged decode attention lowering:
                                 # "pallas" (fused block-table kernel) |
                                 # "ref" (gather-then-attend oracle) |
                                 # "auto" (pallas on TPU, ref elsewhere)
    clock: str = "step"          # "step" (virtual, deterministic — the
                                 # loops never sleep) | "wall" (measured
                                 # seconds; idle gaps really sleep)
    step_s: float = 0.01         # virtual seconds per engine step


def _check_arch(cfg: ArchConfig) -> None:
    """Every token-only architecture serves: attention/MLA layers through a
    KV backend, recurrent layers (mamba/xlstm) through pooled state rows,
    hybrids through both at once (``slot_state.StatePlan``).  Only the
    frontend (prefix-image) path is rejected — it needs per-request
    embeddings at admission and requests are token-only."""
    if cfg.frontend:
        raise ValueError(
            f"{cfg.name}: frontend architectures are not servable "
            "(requests are token-only)")


def _make_sampler(base_key, temperature: float):
    """The single RNG split discipline both serving modes share: token t of
    request r is drawn with ``fold_in(fold_in(base_key, r), t)``.  One
    definition — the wave/continuous token-identity invariant (asserted in
    ``benchmarks/serve_bench.py``) depends on the two modes never drifting.
    """

    def sample(logits, req_ids, tok_idx):
        """logits [N,V] → tokens [N]."""
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def one(l, r, t):
            k = jax.random.fold_in(jax.random.fold_in(base_key, r), t)
            return jax.random.categorical(k, l / temperature).astype(
                jnp.int32)

        return jax.vmap(one)(logits, req_ids, tok_idx)

    return sample


class ServeEngine:
    """Fixed slot pool + per-layer SlotState backends + arrival queue."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 mesh=None):
        _check_arch(cfg)
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh
        if ecfg.chunks_per_step < 1:
            raise ValueError("chunks_per_step must be >= 1")
        if ecfg.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if ecfg.kv_mode not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_mode {ecfg.kv_mode!r}")
        if ecfg.slot_state not in ("auto", "contiguous", "paged"):
            raise ValueError(f"unknown slot_state {ecfg.slot_state!r}")
        if ecfg.paged_kernel not in ("auto", "pallas", "ref"):
            raise ValueError(f"unknown paged_kernel {ecfg.paged_kernel!r}")
        if ecfg.clock not in ("step", "wall"):
            raise ValueError(f"unknown clock {ecfg.clock!r}")
        if ecfg.rec_slots < 0:
            raise ValueError("rec_slots must be >= 0")
        kv_mode = (ecfg.kv_mode if ecfg.slot_state == "auto"
                   else ecfg.slot_state)
        self.plan = StatePlan.resolve(cfg, kv_mode)
        self.has_rec = self.plan.has_recurrent
        self.has_kv = self.plan.has_kv
        # "paged" only means something when there are positional leaves to
        # page: a pure-recurrent arch ignores the KV mode entirely
        self.paged = self.has_kv and kv_mode == "paged"
        # "auto" takes the fused kernel only where it runs natively: on TPU
        # with live Pallas dispatch.  Elsewhere it stays on the gather
        # oracle (interpret-mode kernels would crawl); explicit "pallas"
        # forces the kernel anywhere (interpret off-TPU) so parity tests
        # can pin fused-vs-ref token identity on any host.
        if ecfg.paged_kernel == "auto":
            from repro.compat import on_tpu
            from repro.kernels import kernels_backend
            self.paged_kernel = ("pallas" if on_tpu()
                                 and kernels_backend() == "pallas" else "ref")
        else:
            self.paged_kernel = ecfg.paged_kernel
        # a padded chunk must fit the cache row (a clamped dynamic-slice
        # write would silently shift over live positions)
        self._chunk = min(ecfg.prefill_chunk, ecfg.max_len)

        if self.paged:
            bs = ecfg.block_size
            if ecfg.max_len % bs:
                raise ValueError(
                    f"paged mode needs max_len ({ecfg.max_len}) divisible "
                    f"by block_size ({bs}): the gathered virtual KV view "
                    "must match the contiguous row shape bit-for-bit")
            nblocks = ecfg.kv_blocks or (
                1 + ecfg.max_slots * (ecfg.max_len // bs))
            self.allocator: Optional[BlockAllocator] = \
                BlockAllocator(nblocks, bs)
            self.table = SlotTable(ecfg.max_slots, ecfg.max_len,
                                   block_size=bs)
        else:
            self.allocator = None
            self.table = SlotTable(ecfg.max_slots, ecfg.max_len)

        # the second admission resource: one pooled state row per live
        # request on recurrent-bearing archs
        self.rec: Optional[RecurrentRows] = None
        if self.has_rec:
            self.rec = RecurrentRows(ecfg.rec_slots or ecfg.max_slots)

        self.queue = RequestQueue()
        self.metrics = ServeMetrics(max_slots=ecfg.max_slots,
                                    clock=ecfg.clock, step_s=ecfg.step_s)
        self.results: Dict[int, List[int]] = {}
        self._key = jax.random.key(ecfg.seed)
        self._admission_hold = 0     # steps left with admission stalled

        self._data_spec = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            if ecfg.max_slots % mesh.devices.size:
                raise ValueError(
                    f"--max-slots {ecfg.max_slots} must divide across "
                    f"{mesh.devices.size} devices")
            self._data_spec = lambda ndim: NamedSharding(
                mesh, P("data", *([None] * (ndim - 1))))
            replicated = NamedSharding(mesh, P())
            params = jax.device_put(params, jax.tree.map(
                lambda _: replicated, params))
        self.params = params

        if self.has_rec:
            # hybrid/recurrent cache: KV leaves sized by the KV backend's
            # geometry, recurrent leaves by the row pool (+ sentinel row 0)
            if self.paged:
                kv_batch, kv_len = self.allocator.num_blocks, ecfg.block_size
            else:
                kv_batch, kv_len = ecfg.max_slots, ecfg.max_len
            cache = T.init_hybrid_cache(cfg, kv_batch=kv_batch,
                                        kv_len=kv_len,
                                        rec_batch=self.rec.capacity + 1)
            if mesh is not None:
                # pooled recurrent rows (and paged pools) have no slot dim:
                # replicate the whole cache and let the data-sharded
                # per-step inputs drive the layout
                from jax.sharding import NamedSharding, PartitionSpec as P
                replicated = NamedSharding(mesh, P())
                cache = jax.tree.map(
                    lambda x: jax.device_put(x, replicated), cache)
        elif self.paged:
            cache = T.init_paged_cache(cfg, self.allocator.num_blocks,
                                       ecfg.block_size)
            if mesh is not None:
                # the pooled leaves have no slot dim: replicate them and
                # let the data-sharded per-step inputs drive the layout
                from jax.sharding import NamedSharding, PartitionSpec as P
                replicated = NamedSharding(mesh, P())
                cache = jax.tree.map(
                    lambda x: jax.device_put(x, replicated), cache)
        else:
            cache = T.init_cache(cfg, ecfg.max_slots, ecfg.max_len)
            if self._data_spec is not None:
                # cache leaves are [reps, S, ...]: slot batch dim is axis 1
                from jax.sharding import NamedSharding, PartitionSpec as P
                cache = jax.tree.map(
                    lambda x: jax.device_put(x, NamedSharding(
                        mesh, P(None, "data", *([None] * (x.ndim - 2))))),
                    cache)
        self.cache = cache

        # One jitted decode / admit pair serves every backend mix: unused
        # backend inputs are passed as None (an empty pytree — traced away)
        pk = self.paged_kernel
        contig_kv = self.has_kv and not self.paged
        self._decode = jax.jit(
            lambda p, tok, c, off, bt, rows, act: T.decode_step(
                p, cfg, tok, c, off, block_tables=bt, paged_kernel=pk,
                rec_rows=rows, active=act))

        # admission: contiguous KV slices the slot's row, prefills one
        # chunk into it, writes it back (paged mode addresses the pool
        # through the slot's [1, n_max] table row instead; recurrent state
        # is row-addressed in place via ``rec_row``).  Interior chunks only
        # feed the cache, so they skip the full-vocab head projection (the
        # dominant admission FLOPs at real vocab sizes)
        def admit(with_logits):
            def fn(p, c, tokens, slot, offset, table, rec_row, valid):
                sub = T.take_state(cfg, c, slot) if contig_kv else c
                logits, sub = T.prefill_chunk(
                    p, cfg, tokens, sub, offset, with_logits=with_logits,
                    block_tables=table, rec_rows=rec_row, valid=valid)
                if contig_kv:
                    return logits, T.write_state(cfg, c, sub, slot)
                return logits, sub
            return jax.jit(fn)
        self._admit = admit(True)
        self._admit_quiet = admit(False)
        self._reset = jax.jit(
            lambda c, slot, row: T.reset_slot_state(cfg, c, slot=slot,
                                                    rec_row=row))
        if self.paged:
            self._copy = jax.jit(T.copy_block)
        self._sample = jax.jit(_make_sampler(self._key, ecfg.temperature))

    def _put(self, x):
        if self._data_spec is None:
            return x
        return jax.device_put(x, self._data_spec(np.ndim(x)))

    # -- request intake ---------------------------------------------------
    def submit(self, requests) -> None:
        if isinstance(requests, Request):
            requests = [requests]
        # validate the WHOLE batch before recording anything: a bad request
        # must not leave phantom metrics records for its batchmates
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > self.ecfg.max_len:
                raise ValueError(
                    f"request {r.req_id}: prompt+gen {need} exceeds "
                    f"max_len {self.ecfg.max_len}")
            if self.paged:
                # the last decode write lands at position prompt+gen-2, so
                # a lone request must fit the pool or it would preempt
                # itself forever
                worst = (len(r.prompt) + r.max_new_tokens - 2) \
                    // self.allocator.block_size + 1
                if worst > self.allocator.capacity:
                    raise ValueError(
                        f"request {r.req_id}: worst case {worst} blocks "
                        f"exceeds the pool ({self.allocator.capacity} "
                        "usable blocks)")
        for r in requests:
            self.metrics.on_submit(r.req_id, r.arrival_s, len(r.prompt))
        self.queue.submit(requests)

    # -- backend resource plumbing ----------------------------------------
    def _record_blocks(self) -> None:
        self.metrics.on_blocks(self.allocator.num_used,
                               self.allocator.capacity)

    def _free_resources(self, slot) -> None:
        """Hand every backend resource the slot holds back to its pool."""
        if self.allocator is not None and slot.blocks:
            self.allocator.free_blocks(slot.blocks)
            slot.blocks = []
            self._record_blocks()
        if self.rec is not None and slot.rec_row:
            self.rec.free(slot.rec_row)
            slot.rec_row = 0

    def _preempt(self, victim) -> None:
        """Free the victim's resources (blocks AND recurrent row) and send
        its request back to the queue.  The fold-in RNG regenerates its
        tokens exactly on re-serve, so the only trace is the
        ``preemptions`` counter (and the wasted decode tokens, which
        ``metrics.wasted_decode_tokens`` books)."""
        req = victim.request
        self._free_resources(victim)
        self.table.release(victim)
        self.metrics.on_preempt(req.req_id)
        self.queue.submit(req)

    def _make_room(self, slot) -> bool:
        """The pool is dry: preempt the youngest busy request.  Returns
        False when the victim was ``slot`` itself (the caller must stop
        touching it)."""
        victim = self.table.youngest_busy()
        if victim is slot and len(self.table.busy()) == 1:
            # cannot happen given submit()'s worst-case validation, but
            # fail loudly rather than spin
            raise RuntimeError("KV pool too small for the only live request")
        self._preempt(victim)
        return victim is not slot

    def _alloc_block(self, slot) -> Optional[int]:
        """Allocate one block for ``slot``, preempting the youngest busy
        request while the pool is dry.  Returns None when ``slot`` itself
        was the youngest and got preempted."""
        while True:
            try:
                return self.allocator.alloc()
            except NoFreeBlocks:
                if not self._make_room(slot):
                    return None

    def _ensure_writable(self, slot, block_idx: int,
                         need_copy: bool = True) -> bool:
        """Copy-on-write: make ``slot.blocks[block_idx]`` private before a
        write (``allocator.cow`` forks the host side, ``copy_block`` clones
        the device payload — skipped when the imminent write overwrites
        the whole block anyway).  Returns False if ``slot`` was preempted
        while making room for the copy."""
        while True:
            blk = slot.blocks[block_idx]
            try:
                new, copied = self.allocator.cow(blk)
            except NoFreeBlocks:
                if not self._make_room(slot):
                    return False
                continue        # a preemption may even have unshared blk
            if copied:
                if need_copy:
                    self.cache = self._copy(self.cache, blk, new)
                slot.blocks[block_idx] = new
            return True

    def _ensure_writable_range(self, slot, lo: int, hi: int) -> bool:
        """COW every allocated block covering positions [lo, hi); blocks
        fully inside the range skip the device copy (every position is
        about to be rewritten)."""
        bs = self.allocator.block_size
        for bi in range(lo // bs, min(-(-hi // bs), len(slot.blocks))):
            full = lo <= bi * bs and (bi + 1) * bs <= hi
            if not self._ensure_writable(slot, bi, need_copy=not full):
                return False
        return True

    def _try_admit_paged(self, slot, req) -> bool:
        """Map the request's prompt onto blocks: prefix-cache hits share
        published blocks (refcounted), the tail gets fresh ones.  Fails
        (False) when the free list cannot cover the tail — the caller
        requeues the request and stops admitting this step.

        Recurrent-bearing archs skip prefix matching entirely: a prefix
        hit would skip the prompt positions the recurrent state must
        advance over, serving from a stale (zero) recurrence."""
        alloc = self.allocator
        bs = alloc.block_size
        plen = len(req.prompt)
        matched = [] if self.has_rec else alloc.match_prefix(req.prompt)
        fresh_needed = alloc.blocks_for(plen) - len(matched)
        if fresh_needed > alloc.num_free:
            alloc.free_blocks(matched)
            return False
        # prefill restarts on the chunk grid so every chunk has the same
        # shape — hence bit-identical k/v — as a from-scratch prefill; the
        # cap at the last grid point below plen guarantees the final chunk
        # still produces the first token's logits
        C = self._chunk
        pos0 = min((len(matched) * bs // C) * C, ((plen - 1) // C) * C)
        self.table.assign(slot, req)
        slot.blocks = matched + [alloc.alloc() for _ in range(fresh_needed)]
        slot.prefill_pos = pos0
        self.metrics.on_admit(req.req_id)
        if not self.has_rec:
            self.metrics.on_prefix_lookup(pos0, plen)
        self._record_blocks()
        return True

    # -- engine phases (one call each per step) ---------------------------
    def _admit_ready(self, now_s: float) -> None:
        for slot in self.table.free():
            req = self.queue.pop_ready(now_s)
            if req is None:
                return
            # TWO-RESOURCE admission: every backend must have capacity
            # before either commits (nothing to unwind on failure).
            # Recurrent rows never free mid-decode, so a deferral clears
            # only when a request finishes (or is preempted); FIFO order
            # is preserved by requeueing and admitting nobody behind the
            # blocked request.
            if self.rec is not None and self.rec.num_free == 0:
                self.queue.submit(req)
                return
            if self.paged:
                if not self._try_admit_paged(slot, req):
                    # not enough free blocks: put the request back (the
                    # queue re-sorts it into place) and keep FIFO order by
                    # not admitting anyone behind it
                    self.queue.submit(req)
                    return
            else:
                self.table.assign(slot, req)
                self.metrics.on_admit(req.req_id)
            if self.rec is not None:
                slot.rec_row = self.rec.alloc()
            # device-side hygiene: a reused contiguous slot row and/or
            # recurrent row starts zeroed (paged blocks need no reset —
            # fresh blocks are written before they are ever read)
            if self.rec is not None or not self.paged:
                slot_idx = (slot.index if self.has_kv and not self.paged
                            else None)
                row = slot.rec_row if self.rec is not None else None
                self.cache = self._reset(self.cache, slot_idx, row)

    def _finish(self, slot) -> None:
        req = slot.request
        self.results[req.req_id] = list(slot.output)
        self._free_resources(slot)
        self.table.release(slot)
        self.metrics.on_finish(req.req_id)

    def _complete_if_done(self, slot, token: int) -> bool:
        eos = self.ecfg.eos_id
        if (eos is not None and token == eos) \
                or slot.generated >= slot.request.max_new_tokens:
            self._finish(slot)
            return True
        return False

    def _prefill_tick(self) -> None:
        """Advance up to ``chunks_per_step`` admission prefills one chunk.

        Chunk geometry, KV-only archs: short prompts (≤ chunk) pad at the
        END (garbage positions are causally masked until overwritten by
        decode); a ragged TAIL chunk is RIGHT-ALIGNED at ``plen - chunk``,
        re-writing the overlap with bit-identical k/v (k/v at a position
        depend only on its token, its position, and the already-written
        prefix).

        Recurrent-bearing archs instead keep every chunk on the ALIGNED
        ``[k·C, (k+1)·C)`` grid with the final chunk end-padded and gated
        off by ``valid``: re-running an overlap would advance the
        recurrence twice over those tokens.  KV layers in the same stack
        tolerate the end padding exactly like the short-prompt case.

        Paged mode starts at the prefix-cache hit point (chunk-grid
        aligned, so the geometry — and the written bits — match the
        contiguous backend exactly); a tail chunk that dips into shared
        blocks copy-on-writes them first.
        """
        C = self._chunk
        budget = self.ecfg.chunks_per_step
        for slot in self.table.prefilling():
            if budget <= 0:
                return
            if slot.state != PREFILL:   # preempted earlier this tick
                continue
            prompt = np.asarray(slot.request.prompt, np.int32)
            plen = len(prompt)
            remaining = plen - slot.prefill_pos
            chunk = np.zeros((1, C), np.int32)
            valid = None
            if self.has_rec:                    # aligned grid, masked tail
                start = slot.prefill_pos
                n = min(C, remaining)
                last_row = n - 1
                chunk[0, :n] = prompt[start:start + n]
                valid = n
            elif plen <= C:                     # whole prompt, end-padded
                start, last_row = 0, plen - 1
                chunk[0, :plen] = prompt
            elif remaining > C:                 # full interior chunk
                start, last_row = slot.prefill_pos, C - 1
                chunk[0] = prompt[start:start + C]
            else:                               # right-aligned tail chunk
                start, last_row = plen - C, C - 1
                chunk[0] = prompt[start:plen]
            final = remaining <= C
            admit = self._admit if final else self._admit_quiet
            if self.paged:
                if not self._ensure_writable_range(slot, start, start + C):
                    continue                    # preempted mid-COW
                table = jnp.asarray(self.table.block_table_row(slot))
            else:
                table = None
            rec_row = (None if self.rec is None
                       else jnp.asarray([slot.rec_row], jnp.int32))
            logits, self.cache = admit(
                self.params, self.cache, jnp.asarray(chunk), slot.index,
                jnp.asarray(start, jnp.int32), table, rec_row,
                None if valid is None else jnp.asarray(valid, jnp.int32))
            slot.prefill_pos += min(remaining, C)
            slot.length = slot.prefill_pos
            self.metrics.on_prefill_chunk(min(remaining, C))
            budget -= 1
            if slot.prefill_pos >= plen:
                # prompt fully cached: sample the request's token 0 from the
                # logits at the REAL last prompt position of this chunk
                row = jnp.asarray(logits)[:, last_row]          # [1,V]
                tok = int(self._sample(
                    row, jnp.asarray([slot.req_id], jnp.int32),
                    jnp.asarray([0], jnp.int32))[0])
                self.table.activate(slot, tok)
                if self.paged and not self.has_rec:
                    # publish the full prompt blocks so identical prompts
                    # admitted later share them (first writer wins);
                    # recurrent archs never share — see _try_admit_paged
                    keys = self.allocator.prefix_keys(slot.request.prompt)
                    for i, key in enumerate(keys):
                        self.allocator.publish(slot.blocks[i], key)
                self.metrics.on_first_token(slot.req_id)
                self._complete_if_done(slot, tok)

    def _grow_decode_blocks(self) -> None:
        """Paged: every ACTIVE slot writes its pending token at position
        ``length`` this step — allocate the covering block when the write
        crosses into a new one, preempting the youngest request while the
        pool is dry (oldest slots grow first, so preemption pressure lands
        on the newest work).  Recurrent rows never grow: blocks are the
        only resource that can run out mid-decode."""
        bs = self.allocator.block_size
        for slot in sorted(self.table.active(), key=lambda s: s.admit_seq):
            if slot.state != ACTIVE:    # preempted by an earlier growth
                continue
            while slot.state == ACTIVE and slot.length // bs == \
                    len(slot.blocks):
                blk = self._alloc_block(slot)
                if blk is None:         # slot itself was the victim
                    break
                slot.blocks.append(blk)
        self._record_blocks()

    def _decode_tick(self) -> None:
        if self.paged:
            self._grow_decode_blocks()
        if self.table.n_active == 0:
            return
        tokens, offsets, active, req_ids, tok_idx = self.table.decode_inputs()
        bt = rows = act = None
        if self.paged:
            bt = self._put(jnp.asarray(self.table.block_tables()))
        if self.rec is not None:
            rows = self._put(jnp.asarray(self.table.rec_rows()))
            act = self._put(jnp.asarray(active))
        logits, self.cache = self._decode(
            self.params, self._put(jnp.asarray(tokens)), self.cache,
            self._put(jnp.asarray(offsets)), bt, rows, act)
        toks = np.asarray(self._sample(
            logits[:, 0], self._put(jnp.asarray(req_ids)),
            self._put(jnp.asarray(tok_idx))))
        self.metrics.on_decode_step(int(active.sum()))
        for slot in self.table.active():
            tok = int(toks[slot.index])
            slot.length += 1          # pending token was cached this step
            slot.pending_token = tok
            slot.generated += 1
            slot.output.append(tok)
            self.metrics.on_token(slot.req_id)
            self._complete_if_done(slot, tok)

    def hold_admission(self, steps: int) -> None:
        """Stall admission for the next ``steps`` engine steps (fault
        injection: a hung scheduler / admission-control brown-out).  Live
        slots keep prefilling and decoding; only NEW admissions wait, so
        the backlog — and TTFT — grows until the hold clears.  Overlapping
        holds extend, not stack."""
        if steps < 0:
            raise ValueError(f"hold steps must be >= 0, got {steps}")
        self._admission_hold = max(self._admission_hold, steps)

    def step(self) -> None:
        """One engine iteration: admissions, a prefill tick, a decode step,
        and a clock tick (virtual mode — wall time passes on its own)."""
        if self._admission_hold > 0:
            self._admission_hold -= 1
        else:
            self._admit_ready(self.metrics.now())
        self._prefill_tick()
        self._decode_tick()
        self.metrics.on_queue_depth(len(self.queue))
        self.metrics.tick()

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> Dict[int, List[int]]:
        """Serve until the queue and every slot drain; returns outputs."""
        if requests:
            self.submit(list(requests))
        self.metrics.start()
        while len(self.queue) or self.table.busy():
            if not self.table.busy():
                nxt = self.queue.next_arrival()
                if nxt is not None:
                    # open-loop idle: the virtual clock jumps to the next
                    # arrival, the wall clock actually sleeps the gap
                    self.metrics.wait_until(nxt)
            self.step()
        self.metrics.stop()
        return self.results


# ---------------------------------------------------------------------------
# wave-at-a-time baseline (what PR 2 shipped) — the token-identity TEST
# ORACLE, and the measured baseline for benchmarks/serve_bench.py
# ---------------------------------------------------------------------------


def serve_waves(cfg: ArchConfig, params, ecfg: EngineConfig,
                requests: Sequence[Request]):
    """Admit ≤ max_slots requests per wave; decode until the wave drains.

    This is the engine's TEST ORACLE: it batch-prefills whole prompts in
    one call (no chunking, no padding masks, no slot reuse, no paging), so
    its per-request outputs are the ground truth the continuous engine —
    every backend mix, including recurrent and hybrid stacks — must match
    token for token (same fold-in sampling discipline).  It doubles as the
    measured baseline whose occupancy/throughput gap on ragged output
    lengths ``benchmarks/serve_bench.py`` quantifies: freed slots idle
    until the whole wave finishes.  Prompts within a wave must share one
    length (the wave loop batch-prefills).
    """
    _check_arch(cfg)
    S, max_len = ecfg.max_slots, ecfg.max_len
    metrics = ServeMetrics(max_slots=S, clock=ecfg.clock, step_s=ecfg.step_s)
    results: Dict[int, List[int]] = {}

    prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c, None))
    decode = jax.jit(lambda p, t, c, o: T.decode_step(p, cfg, t, c, o))
    sample_j = jax.jit(_make_sampler(jax.random.key(ecfg.seed),
                                     ecfg.temperature))

    reqs = sorted(requests, key=lambda r: (r.arrival_s, r.req_id))
    for r in reqs:
        metrics.on_submit(r.req_id, r.arrival_s, len(r.prompt))
    metrics.start()
    for w0 in range(0, len(reqs), S):
        wave = reqs[w0:w0 + S]
        plens = {len(r.prompt) for r in wave}
        if len(plens) != 1:
            raise ValueError("wave baseline needs uniform prompt lengths "
                             f"within a wave, got {sorted(plens)}")
        P = plens.pop()
        # a wave starts only once its LAST member arrived — slots freed
        # mid-wave cannot admit (that is the baseline's pathology)
        wave_start = max(r.arrival_s for r in wave)
        metrics.wait_until(wave_start)
        B = len(wave)
        cache = T.init_cache(cfg, B, max_len)
        prompts = jnp.asarray([list(r.prompt) for r in wave], jnp.int32)
        req_ids = jnp.asarray([r.req_id for r in wave], jnp.int32)
        for r in wave:
            metrics.on_admit(r.req_id)
        logits, cache, offset = prefill(params, prompts, cache)
        metrics.on_prefill_chunk(B * P)
        metrics.tick()
        toks = np.asarray(sample_j(logits[:, -1], req_ids,
                                   jnp.zeros((B,), jnp.int32)))
        outs = [[int(t)] for t in toks]
        done = np.zeros((B,), bool)
        for i, r in enumerate(wave):
            metrics.on_first_token(r.req_id)
            if (ecfg.eos_id is not None and outs[i][0] == ecfg.eos_id) \
                    or r.max_new_tokens == 1:
                done[i] = True
                metrics.on_finish(r.req_id)
        gen = 1
        max_gen = max(r.max_new_tokens for r in wave)
        while not done.all() and gen < max_gen:
            tok_in = jnp.asarray(toks, jnp.int32)[:, None]
            logits, cache = decode(params, tok_in, cache,
                                   jnp.asarray(P + gen - 1, jnp.int32))
            toks = np.asarray(sample_j(
                logits[:, 0], req_ids, jnp.full((B,), gen, jnp.int32)))
            metrics.on_decode_step(int((~done).sum()))
            metrics.tick()
            for i, r in enumerate(wave):
                if done[i]:
                    continue       # slot idles until the wave drains
                outs[i].append(int(toks[i]))
                metrics.on_token(r.req_id)
                if (ecfg.eos_id is not None and outs[i][-1] == ecfg.eos_id) \
                        or len(outs[i]) >= r.max_new_tokens:
                    done[i] = True
                    metrics.on_finish(r.req_id)
            gen += 1
        for i, r in enumerate(wave):
            results[r.req_id] = outs[i]
            if not done[i]:
                metrics.on_finish(r.req_id)
    metrics.stop()
    return results, metrics
