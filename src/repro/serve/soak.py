"""Sustained-load soak + SLO harness for the serve engine.

``benchmarks/serve_bench.py`` proves scheduling/memory wins on short
closed-loop cells; this module answers the production question instead:
under hours of open-loop traffic — with faults injected — does p99 TTFT
stay inside the SLO band, and how fast does it RECOVER once a fault
window closes?

``run_soak`` drives a ``ServeEngine`` for thousands of virtual-clock
steps under any arrival process (Poisson / bursty / trace), submitting
requests only when their arrival time passes (so ``len(engine.queue)``
is the true backlog), applying a ``runtime.chaos.FaultPlan`` each step:

  * ``stall`` windows hold admission (``engine.hold_admission``) — the
    backlog and TTFT grow while live decodes keep streaming;
  * ``blocks`` windows confiscate a fraction of the paged KV pool (held
    via the engine's own allocator, released when the window closes) —
    admission defers and the youngest decodes get preempted, exactly the
    pressure path the paged backend is built to absorb.

Every ``window`` steps it snapshots a trend row (windowed p50/p99 TTFT
from the metrics event log, queue depth, preemption/prefix-hit deltas,
blocks in use); streaming P² estimators run alongside for the long-run
view.  ``check_recovery`` then asserts the SLO claim: windowed p99 TTFT
returns to ``baseline × recovery_band`` within ``recovery_steps`` after
the last fault window closes (baseline = steady-state p99 measured after
warmup, before the first fault).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.runtime.chaos import FaultPlan

from .blocks import NoFreeBlocks
from .engine import ServeEngine
from .queue import Request


@dataclass(frozen=True)
class SoakConfig:
    steps: int = 2000            # virtual-clock engine steps to drive
    window: int = 50             # trend-row cadence (steps)
    warmup_steps: int = 100      # excluded from the baseline measurement
    recovery_band: float = 1.5   # p99 must return within band × baseline
    recovery_slack_s: float = 0.0   # absolute slack added to the band
    recovery_steps: int = 500    # ... within this many steps of fault end
    slo_p99_s: Optional[float] = None   # absolute steady-state SLO (opt.)


@dataclass
class SoakResult:
    summary: Dict[str, float]
    trend: List[Dict[str, float]]
    baseline_p99_s: float
    fault_end_step: Optional[int]
    recovered_step: Optional[int]     # first healthy window end after fault
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures

    @property
    def recovery_steps_taken(self) -> Optional[int]:
        if self.recovered_step is None or self.fault_end_step is None:
            return None
        return self.recovered_step - self.fault_end_step


def _p_of(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def run_soak(engine: ServeEngine, requests: Sequence[Request],
             plan: Optional[FaultPlan] = None,
             scfg: SoakConfig = SoakConfig()) -> SoakResult:
    """Drive ``engine`` for ``scfg.steps`` steps under ``requests`` with
    ``plan``'s faults injected; returns trends + recovery verdict."""
    plan = plan or FaultPlan()
    m = engine.metrics
    if m.clock != "step":
        raise ValueError("soak runs need the virtual step clock "
                         "(EngineConfig.clock='step'): recovery windows "
                         "are counted in deterministic steps")
    m.record_events = True
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.req_id))
    pending.reverse()                       # pop() from the earliest end

    held_blocks: List[int] = []
    trend: List[Dict[str, float]] = []
    ev_ptr = 0                              # consumed ttft_events
    win_queue_max = 0
    win_preempt0 = win_tokens0 = 0
    m.start()

    for s in range(scfg.steps):
        now = m.now()
        while pending and pending[-1].arrival_s <= now:
            engine.submit(pending.pop())

        # -- fault injection ----------------------------------------------
        if plan.admission_stalled(s):
            engine.hold_admission(1)
        if engine.allocator is not None:
            target = int(plan.block_pressure(s) * engine.allocator.capacity)
            while len(held_blocks) < target:
                try:
                    held_blocks.append(engine.allocator.alloc())
                except NoFreeBlocks:
                    break                   # pool already drained: maximal
            if len(held_blocks) > target:
                engine.allocator.free_blocks(held_blocks[target:])
                del held_blocks[target:]
            engine._record_blocks()

        engine.step()
        win_queue_max = max(win_queue_max, len(engine.queue))

        # -- trend row every `window` steps -------------------------------
        if (s + 1) % scfg.window == 0 or s + 1 == scfg.steps:
            ttfts = [t for _, t in m.ttft_events[ev_ptr:]]
            ev_ptr = len(m.ttft_events)
            trend.append({
                "step": s + 1,
                "ttft_p50_s": _p_of(ttfts, 0.50),
                "ttft_p99_s": _p_of(ttfts, 0.99),
                "first_tokens": len(ttfts),
                "queue_depth": len(engine.queue),
                "queue_max": win_queue_max,
                "active": len(engine.table.busy()),
                "preemptions": m.preemptions - win_preempt0,
                "tokens_out": m.tokens_out - win_tokens0,
                "prefix_hit_rate": m.prefix_hit_rate,
                "blocks_in_use": m.blocks_in_use,
                "blocks_held": len(held_blocks),
            })
            win_queue_max = 0
            win_preempt0, win_tokens0 = m.preemptions, m.tokens_out

    if held_blocks:                         # plan ended mid-window
        engine.allocator.free_blocks(held_blocks)
        held_blocks = []
        engine._record_blocks()
    m.stop()

    # -- baseline + recovery ----------------------------------------------
    first_fault = plan.first_fault_start()
    fault_end = plan.last_fault_end()
    t_warm = scfg.warmup_steps * m.step_s
    t_fault = (first_fault * m.step_s) if first_fault is not None \
        else float("inf")
    baseline = [t for at, t in m.ttft_events if t_warm <= at < t_fault]
    baseline_p99 = _p_of(baseline, 0.99)

    recovered = None
    if fault_end is not None:
        bound = baseline_p99 * scfg.recovery_band + scfg.recovery_slack_s
        for row in trend:
            if row["step"] <= fault_end:
                continue
            healthy_quiet = (row["first_tokens"] == 0
                             and row["queue_depth"] == 0)
            if healthy_quiet or (row["first_tokens"] > 0
                                 and row["ttft_p99_s"] <= bound):
                recovered = row["step"]
                break

    result = SoakResult(summary=m.summary(), trend=trend,
                        baseline_p99_s=baseline_p99,
                        fault_end_step=fault_end, recovered_step=recovered)
    check_recovery(result, scfg)
    return result


def check_recovery(result: SoakResult, scfg: SoakConfig) -> None:
    """Populate ``result.failures`` with every violated SLO claim."""
    if result.fault_end_step is not None:
        if result.recovered_step is None:
            result.failures.append(
                f"p99 TTFT never returned to {scfg.recovery_band}× the "
                f"pre-fault baseline ({result.baseline_p99_s * 1e3:.1f} ms) "
                f"after the fault window closed at step "
                f"{result.fault_end_step}")
        elif result.recovery_steps_taken > scfg.recovery_steps:
            result.failures.append(
                f"p99 TTFT took {result.recovery_steps_taken} steps to "
                f"recover (bound: {scfg.recovery_steps}) after step "
                f"{result.fault_end_step}")
    if scfg.slo_p99_s is not None:
        base = result.baseline_p99_s
        if not base <= scfg.slo_p99_s:      # NaN baseline also fails
            result.failures.append(
                f"steady-state p99 TTFT {base * 1e3:.1f} ms violates the "
                f"{scfg.slo_p99_s * 1e3:.1f} ms SLO")
