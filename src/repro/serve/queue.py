"""Request queue + synthetic arrival processes for the serve engine.

A ``Request`` is everything admission needs: prompt tokens, a per-request
generation budget, and an arrival time on the engine's step clock.  The
queue releases requests whose arrival time has passed — the engine polls it
once per step, so arrivals gate *admission*, never the decode loop.

Arrival generators:

  * ``poisson_arrivals(n, rate, seed)`` — exponential inter-arrival gaps
    (the classic open-loop load model), in seconds of engine clock;
  * ``burst_arrivals(n, rate, duty, period, seed)`` — on-off (bursty)
    traffic: Poisson at ``rate/duty`` during the first ``duty`` fraction
    of each period, silent for the rest — queue-depth spikes at a given
    long-run average rate (the soak harness's worst case);
  * ``trace_arrivals(spec)``           — explicit timestamps, either a
    comma-separated string ("0,0.5,0.5,2") or a file with one per line;
  * ``parse_arrival_spec("poisson:8", n, seed)`` — the CLI surface
    (immediate | poisson:RATE | burst:RATE,DUTY[,PERIOD] | trace:SPEC).
"""

from __future__ import annotations

import heapq
import os
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    """One generation request.

    prompt          : token ids (host ints; the engine pads/chunks them)
    max_new_tokens  : generation budget, counting the first (prefill) token
    arrival_s       : arrival time on the engine clock (seconds)
    req_id          : unique id — also the RNG fold-in domain, so sampling
                      is deterministic per request regardless of which slot
                      or admission order serves it
    """

    req_id: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_s: float = 0.0

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError(f"request {self.req_id}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.req_id}: max_new_tokens must be >= 1")


@dataclass
class RequestQueue:
    """Arrival-ordered FIFO releasing requests whose time has come.

    A binary heap keyed ``(arrival_s, req_id)`` — the same total order the
    old sorted list kept (req_id is unique, so ``Request`` itself is never
    compared and ties stay deterministic), but submit and pop are O(log n)
    instead of the old ``list.pop(0)``'s O(n) shift, which went O(n²) per
    drain under heavy-traffic arrival bursts (preemption requeues included).
    """

    _heap: List[Tuple[float, int, Request]] = field(default_factory=list)

    def submit(self, requests) -> None:
        if isinstance(requests, Request):
            requests = [requests]
        for r in requests:
            heapq.heappush(self._heap, (r.arrival_s, r.req_id, r))

    def pop_ready(self, now_s: float) -> Optional[Request]:
        """Next request with arrival_s <= now_s, or None."""
        if self._heap and self._heap[0][0] <= now_s:
            return heapq.heappop(self._heap)[2]
        return None

    def next_arrival(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0
                     ) -> Tuple[float, ...]:
    """n arrival times with Exp(rate) inter-arrival gaps, starting at 0."""
    if rate_per_s <= 0:
        raise ValueError("poisson rate must be > 0")
    if n == 0:
        return ()
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    gaps[0] = 0.0                       # first request arrives immediately
    return tuple(np.cumsum(gaps).tolist())


def burst_arrivals(n: int, rate_per_s: float, duty: float,
                   period_s: float = 1.0, seed: int = 0
                   ) -> Tuple[float, ...]:
    """On-off bursty arrivals averaging ``rate_per_s`` requests/second.

    Each ``period_s`` window is "on" for its first ``duty`` fraction and
    silent for the rest; during the on-phase arrivals are Poisson at the
    peak rate ``rate_per_s / duty``, so the long-run average matches the
    equivalent Poisson load while the instantaneous rate spikes 1/duty×.
    Deterministic per (n, rate, duty, period, seed): a Poisson stream is
    drawn on the compressed "on-time" axis and mapped onto wall time by
    inserting the off-gaps.
    """
    if rate_per_s <= 0:
        raise ValueError("burst rate must be > 0")
    if not 0.0 < duty <= 1.0:
        raise ValueError(f"burst duty must be in (0,1], got {duty}")
    if period_s <= 0:
        raise ValueError("burst period must be > 0")
    if n == 0:
        return ()
    rng = np.random.default_rng(seed)
    peak = rate_per_s / duty
    gaps = rng.exponential(1.0 / peak, size=n)
    gaps[0] = 0.0                       # first request arrives immediately
    t_on = np.cumsum(gaps)              # time on the compressed on-axis
    on_len = duty * period_s
    k = np.floor(t_on / on_len)
    times = k * period_s + (t_on - k * on_len)
    return tuple(times.tolist())


def trace_arrivals(spec: str) -> Tuple[float, ...]:
    """Timestamps from a comma-separated string or a one-per-line file."""
    if os.path.exists(spec):
        with open(spec) as f:
            raw = [ln.strip() for ln in f if ln.strip()]
    else:
        raw = [tok.strip() for tok in spec.split(",") if tok.strip()]
    if not raw:
        raise ValueError(f"empty arrival trace {spec!r}")
    times = tuple(float(tok) for tok in raw)
    if any(b < a for a, b in zip(times, times[1:])):
        raise ValueError("arrival trace must be non-decreasing")
    return times


def parse_arrival_spec(spec: str, n: int, seed: int = 0) -> Tuple[float, ...]:
    """CLI arrival spec → n arrival times.

      "immediate"      every request present at t=0 (closed-loop batch)
      "poisson:RATE"   open-loop Poisson at RATE req/s
      "burst:RATE,DUTY[,PERIOD]"  on-off bursty traffic averaging RATE
                       req/s, on for DUTY of each PERIOD (default 1 s)
      "trace:SPEC"     explicit timestamps (string or file); must supply at
                       least n arrivals, truncated to the first n
    """
    if spec == "immediate":
        return (0.0,) * n
    if spec.startswith("poisson:"):
        return poisson_arrivals(n, float(spec.split(":", 1)[1]), seed)
    if spec.startswith("burst:"):
        parts = spec.split(":", 1)[1].split(",")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"burst spec needs RATE,DUTY[,PERIOD], got {spec!r}")
        rate, duty = float(parts[0]), float(parts[1])
        period = float(parts[2]) if len(parts) == 3 else 1.0
        return burst_arrivals(n, rate, duty, period_s=period, seed=seed)
    if spec.startswith("trace:"):
        times = trace_arrivals(spec.split(":", 1)[1])
        if len(times) < n:
            raise ValueError(
                f"trace has {len(times)} arrivals for {n} requests")
        return times[:n]
    raise ValueError(f"unknown arrival spec {spec!r} "
                     "(immediate | poisson:RATE | burst:RATE,DUTY[,PERIOD] "
                     "| trace:SPEC)")
