"""Host-side slot bookkeeping for the continuous-batching engine.

The device sees a fixed [S]-shaped batch every decode step (jit-stable);
the *meaning* of each row — which request it serves, how long its sequence
is, whether it is live — lives here, in plain numpy, mirrored into the
device inputs once per step by ``decode_inputs``.

Slot lifecycle:

    FREE ──assign──▶ PREFILL ──(last chunk, first token)──▶ ACTIVE
      ▲                │                                       │
      │                └───────────── preempt ─────────────────┤
      └──────────────── release (EOS / budget) ◀───────────────┘

Inactive rows still flow through the batched decode step (masked): their
token input is 0 and their write offset is the cache sentinel position —
one the causal mask hides until the moment a live request writes its own
token there, so garbage never leaks into any slot's attention.

Paged mode (``block_size`` set): each slot additionally carries its block
table — the list of physical blocks its virtual positions [0, max_len)
map onto — mirrored into a fixed-width [S, n_max] device array by
``block_tables()`` (unallocated entries padded with the sentinel block 0).
The block ids themselves are owned by ``blocks.BlockAllocator``; the table
only transports them.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .blocks import SENTINEL
from .queue import Request

FREE, PREFILL, ACTIVE = 0, 1, 2


@dataclass
class Slot:
    index: int
    state: int = FREE
    request: Optional[Request] = None
    length: int = 0          # tokens currently in this slot's cache row
    prefill_pos: int = 0     # prompt tokens already written (or shared)
    generated: int = 0       # tokens sampled for this request so far
    pending_token: int = 0   # next token to feed the decode step
    output: List[int] = field(default_factory=list)
    # paged mode only:
    blocks: List[int] = field(default_factory=list)   # physical block table
    # recurrent backend only: pooled state row (0 = none — row 0 is the
    # sentinel row and is never allocated to a request)
    rec_row: int = 0
    admit_seq: int = -1      # admission order (preemption picks the max)

    @property
    def req_id(self) -> int:
        return self.request.req_id if self.request is not None else -1


class SlotTable:
    """Fixed pool of S slots + the [S]-shaped device-input builders."""

    def __init__(self, max_slots: int, max_len: int,
                 block_size: Optional[int] = None):
        if max_slots < 1:
            raise ValueError("need at least one slot")
        self.max_slots = max_slots
        self.max_len = max_len
        self.block_size = block_size
        self.n_max = (-(-max_len // block_size)
                      if block_size is not None else 0)
        self._admits = 0
        self.slots = [Slot(i) for i in range(max_slots)]

    @property
    def paged(self) -> bool:
        return self.block_size is not None

    # -- queries ----------------------------------------------------------
    def free(self) -> List[Slot]:
        return [s for s in self.slots if s.state == FREE]

    def prefilling(self) -> List[Slot]:
        return [s for s in self.slots if s.state == PREFILL]

    def active(self) -> List[Slot]:
        return [s for s in self.slots if s.state == ACTIVE]

    def busy(self) -> List[Slot]:
        return [s for s in self.slots if s.state != FREE]

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s.state == ACTIVE)

    def youngest_busy(self) -> Optional[Slot]:
        """The most recently admitted busy slot — the preemption victim."""
        busy = self.busy()
        return max(busy, key=lambda s: s.admit_seq) if busy else None

    # -- lifecycle --------------------------------------------------------
    def assign(self, slot: Slot, request: Request) -> None:
        if slot.state != FREE:
            raise RuntimeError(f"slot {slot.index} not free")
        need = len(request.prompt) + request.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {request.req_id} needs {need} cache positions, "
                f"slot holds {self.max_len}")
        self._admits += 1
        slot.state = PREFILL
        slot.request = request
        slot.length = 0
        slot.prefill_pos = 0
        slot.generated = 0
        slot.pending_token = 0
        slot.output = []
        slot.blocks = []
        slot.rec_row = 0
        slot.admit_seq = self._admits

    def activate(self, slot: Slot, first_token: int) -> None:
        """Prefill finished: cache holds the prompt, first token sampled."""
        if slot.state != PREFILL:
            raise RuntimeError(f"slot {slot.index} not prefilling")
        slot.state = ACTIVE
        slot.length = len(slot.request.prompt)
        slot.pending_token = int(first_token)
        slot.generated = 1
        slot.output = [int(first_token)]

    def release(self, slot: Slot) -> Request:
        """Free the slot.  Paged callers must hand the slot's blocks back
        to the allocator FIRST — release only drops the host references."""
        if slot.state == FREE:
            raise RuntimeError(f"slot {slot.index} already free")
        if slot.blocks:
            raise RuntimeError(
                f"slot {slot.index} released with {len(slot.blocks)} live "
                "blocks — free them through the allocator first")
        if slot.rec_row:
            raise RuntimeError(
                f"slot {slot.index} released with live recurrent row "
                f"{slot.rec_row} — free it through the row pool first")
        request = slot.request
        slot.state = FREE
        slot.request = None
        slot.length = 0
        slot.prefill_pos = 0
        slot.generated = 0
        slot.pending_token = 0
        slot.admit_seq = -1
        return request

    # -- device-input builders --------------------------------------------
    @property
    def _sentinel_pos(self) -> int:
        """Masked rows write here: the last virtual position.  Contiguous:
        ``max_len - 1``.  Paged: ``n_max * block_size - 1`` — which equals
        ``max_len - 1`` when block_size divides max_len (the paged engine
        enforces that, so the two backends mask identically)."""
        if self.paged:
            return self.n_max * self.block_size - 1
        return self.max_len - 1

    def decode_inputs(self):
        """(tokens [S,1], offsets [S], active [S], req_ids [S], tok_idx [S]).

        ``offsets`` is each ACTIVE slot's current length (the position its
        pending token is written to and attends from); masked rows write to
        the sentinel position.  ``tok_idx`` is the per-request token index
        of the token being sampled THIS step (generated count), the second
        fold-in of the RNG discipline.
        """
        S = self.max_slots
        tokens = np.zeros((S, 1), np.int32)
        offsets = np.full((S,), self._sentinel_pos, np.int32)
        active = np.zeros((S,), bool)
        req_ids = np.zeros((S,), np.int32)
        tok_idx = np.zeros((S,), np.int32)
        for s in self.slots:
            if s.state != ACTIVE:
                continue
            tokens[s.index, 0] = s.pending_token
            offsets[s.index] = s.length
            active[s.index] = True
            req_ids[s.index] = s.req_id
            tok_idx[s.index] = s.generated
        return tokens, offsets, active, req_ids, tok_idx

    def rec_rows(self) -> np.ndarray:
        """[S] pooled recurrent-state rows for the batched decode step:
        ACTIVE slots address their own row, every other row the sentinel
        row 0 (whose gated write is a bit-exact no-op).  PREFILL slots'
        rows are deliberately NOT mapped — their state advances through
        the admission-prefill path only."""
        rows = np.zeros((self.max_slots,), np.int32)
        for s in self.slots:
            if s.state == ACTIVE:
                rows[s.index] = s.rec_row
        return rows

    def block_tables(self) -> np.ndarray:
        """[S, n_max] int32 physical-block tables, sentinel-padded.  Masked
        rows are all-sentinel, so their writes land in the garbage block."""
        if not self.paged:
            raise RuntimeError("block_tables() needs a paged SlotTable")
        tables = np.full((self.max_slots, self.n_max), SENTINEL, np.int32)
        for s in self.slots:
            if s.blocks:
                tables[s.index, :len(s.blocks)] = s.blocks
        return tables

    def block_table_row(self, slot: Slot) -> np.ndarray:
        """[1, n_max] table for one slot (the admission-prefill input)."""
        if not self.paged:
            raise RuntimeError("block_table_row() needs a paged SlotTable")
        row = np.full((1, self.n_max), SENTINEL, np.int32)
        row[0, :len(slot.blocks)] = slot.blocks
        return row
