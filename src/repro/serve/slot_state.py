"""SlotState protocol: per-layer decode-state backends for the engine.

One serving engine, one state protocol, three backends — the per-layer
analogue of the paper's one-sync-protocol-across-heterogeneous-units
lesson.  Each layer of an architecture carries decode state with one of
three shapes, and the engine composes whichever subset the config needs:

  * ``contiguous`` KV — one ``max_len`` cache row per slot (the slot index
    IS the cache batch row).  Resource: the slot itself; admission is
    free-slot driven, nothing can run out mid-decode.
  * ``paged`` KV — pooled ``num_blocks`` × ``block_size`` leaves addressed
    through per-slot block tables.  Resource: free blocks (admission gated
    on the prompt's block count, growth per decode step, preemption when
    the pool runs dry).  Host bookkeeping lives in ``blocks.BlockAllocator``.
  * ``recurrent`` rows — O(1) per-request state (mamba / xLSTM) in a
    pooled ``[rows + 1, ...]`` leaf; row 0 is the sentinel row masked
    decode slots address (and gate off), rows 1..R serve live requests.
    Resource: free rows, fixed at admission — recurrent state NEVER grows,
    so it can gate admission but never triggers mid-decode preemption.

``StatePlan.resolve`` maps an ArchConfig onto backends per layer: attention
and MLA layers follow the engine's KV mode, recurrent layers always take
the recurrent backend.  Hybrid stacks (Jamba) therefore mix paged-KV and
recurrent backends inside one model, and admission becomes a TWO-resource
budget: a request needs a free recurrent row AND enough free KV blocks
before either is committed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Set, Tuple

from repro.models.transformer import ATTN_KINDS, MLA_KINDS, REC_KINDS

# Recurrent-state row 0 is never allocated: masked decode rows gather and
# rewrite it (gated, so the write is a no-op bit-for-bit) the same way
# masked KV rows write to the causally-hidden sentinel position.
REC_SENTINEL = 0


class NoFreeRows(RuntimeError):
    """The recurrent-row pool is exhausted (admission must defer)."""


@dataclass(frozen=True)
class StatePlan:
    """Resolved per-layer backend selection for one engine instance.

    ``backends`` lists one entry per layer in segment order:
    "contiguous" | "paged" | "recurrent".
    """

    backends: Tuple[str, ...]
    kv_mode: Optional[str]        # backend of the KV layers (None if none)
    has_recurrent: bool
    has_kv: bool

    @staticmethod
    def resolve(cfg, kv_mode: str) -> "StatePlan":
        if kv_mode not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_mode {kv_mode!r}")
        backends: List[str] = []
        for unit, reps in cfg.segments():
            for kind in unit * reps:
                if kind in REC_KINDS:
                    backends.append("recurrent")
                elif kind in ATTN_KINDS or kind in MLA_KINDS:
                    backends.append(kv_mode)
                else:
                    raise ValueError(
                        f"{cfg.name}: no SlotState backend for layer kind "
                        f"{kind!r}")
        has_rec = "recurrent" in backends
        has_kv = any(b != "recurrent" for b in backends)
        return StatePlan(backends=tuple(backends),
                         kv_mode=kv_mode if has_kv else None,
                         has_recurrent=has_rec, has_kv=has_kv)

    def describe(self) -> str:
        """Human-readable layer census, e.g. ``24×paged + 8×recurrent``."""
        counts = {}
        for b in self.backends:
            counts[b] = counts.get(b, 0) + 1
        return " + ".join(f"{n}×{b}" for b, n in sorted(counts.items()))


class RecurrentRows:
    """Host-side allocator for pooled recurrent-state rows.

    Mirrors ``BlockAllocator``'s contract at its natural size: no refcounts
    (recurrent state is position-free, so there is nothing to share — a
    prefix-cache hit would SKIP the state computation and serve from a
    stale recurrence), no growth, no copy-on-write.  One row per live
    request, allocated at admission, freed at completion or preemption.
    """

    def __init__(self, rows: int):
        if rows < 1:
            raise ValueError("need at least one recurrent row")
        self.capacity = rows
        # pop() from the end → row 1 first: allocation order is
        # deterministic, and row 0 (the sentinel) is never handed out
        self._free: List[int] = list(range(rows, 0, -1))
        self._live: Set[int] = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._live)

    def alloc(self) -> int:
        if not self._free:
            raise NoFreeRows(
                f"all {self.capacity} recurrent rows are live")
        row = self._free.pop()
        self._live.add(row)
        return row

    def free(self, row: int) -> None:
        if row not in self._live:
            raise ValueError(f"row {row} is not live")
        self._live.remove(row)
        self._free.append(row)

    def assert_consistent(self) -> None:
        assert len(self._free) + len(self._live) == self.capacity
        assert not (set(self._free) & self._live)
        assert REC_SENTINEL not in self._live
        assert REC_SENTINEL not in self._free
