"""SuperstepEngine: bucketed, overlap-aware BSP gradient synchronization.

The paper makes the BSP barrier nearly free, which moves the superstep
bottleneck to the communication phase itself.  The monolithic path
(flatten → one all-reduce → unflatten) serializes compute and
communication: no gradient byte moves until the *whole* backward pass has
finished.  This module makes the Schedule IR a **runtime** concept:

  1. the gradient pytree is partitioned into size-bounded **buckets** in
     reverse-layer order (leaf order reversed), so bucket 0 — the LAST
     layers — is complete while backward is still chewing on the first
     layers;
  2. each bucket is compiled to its own Schedule-IR ``Program`` (tagged
     with ``BucketMeta`` so all IR consumers agree on bucket identity),
     with the autotuner picking a schedule *per bucket* — small late
     buckets lean butterfly (latency-bound), large early buckets lean ring
     (bandwidth-bound);
  3. the runtime lowering issues one collective per bucket inside the same
     jitted superstep.  The collectives are data-independent, so XLA's
     latency-hiding scheduler may overlap bucket i's communication with
     whatever compute still feeds bucket j>i — the structural opportunity
     the monolithic path denies it;
  4. ``cost_model.overlap_step_cost`` and ``simulator.pipelined_on_noc``
     price/replay the bucket pipeline on a *shared* fabric timeline, so
     predicted step time reflects compute/comm overlap instead of a sum
     (``benchmarks/overlap.py`` sweeps this against the monolithic
     baseline).

Numerics: bucketing permutes and re-groups the flat vector but reduces
every element through the same schedule arithmetic, so the bucketed sync
is equivalent to the monolithic path within f32 tolerance (bit-identical
for codec-free schedules; asserted in ``tests/superstep_checks.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives as C
from . import schedule_ir
from .bsp import BSPConfig, make_codec
from .cost_model import (LinkParams, OverlapTimeline, TPU_V5E_ICI,
                         overlap_step_cost)


@dataclass(frozen=True)
class LeafSpec:
    """Host-static shape/dtype of one gradient (or parameter) leaf."""

    shape: Tuple[int, ...]
    dtype: str

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass(frozen=True)
class Bucket:
    """One size-bounded slice of the bucket-ordered flat payload.

    ``leaf_ids`` index the *original* pytree leaf list; buckets concatenate
    leaves in reverse-layer order, so bucket 0 holds the tail of the model.
    ``offset``/``length`` locate the bucket's padded segment in the
    bucket-ordered flat vector (elements, not bytes).
    """

    index: int
    leaf_ids: Tuple[int, ...]
    raw: int                      # unpadded element count
    offset: int                   # start in the bucket-ordered flat vector
    length: int                   # padded element count (divides by world)

    def meta(self, n_buckets: int) -> schedule_ir.BucketMeta:
        return schedule_ir.BucketMeta(index=self.index, n_buckets=n_buckets,
                                      offset_elems=self.offset,
                                      length_elems=self.length)


def partition_buckets(leaf_sizes: Sequence[int], order: Sequence[int],
                      bucket_elems: Optional[int], pad_unit: int
                      ) -> Tuple[Bucket, ...]:
    """Greedy size-bounded partition of leaves (in ``order``) into buckets.

    A bucket closes once it holds ≥ ``bucket_elems`` raw elements (None →
    one bucket holds everything).  A single leaf larger than the bound gets
    its own bucket — the bound is a target, not a hard cap.  Every bucket
    is padded up to a multiple of ``pad_unit`` (world × pad_align, so the
    halving steps and per-rank shards stay lane-aligned).
    """
    groups: List[List[int]] = []
    cur: List[int] = []
    cur_elems = 0
    for i in order:
        if cur and bucket_elems is not None and \
                cur_elems + leaf_sizes[i] > bucket_elems:
            groups.append(cur)
            cur, cur_elems = [], 0
        cur.append(i)
        cur_elems += leaf_sizes[i]
    if cur:
        groups.append(cur)
    buckets: List[Bucket] = []
    offset = 0
    for bi, ids in enumerate(groups):
        raw = sum(leaf_sizes[i] for i in ids)
        length = ((raw + pad_unit - 1) // pad_unit) * pad_unit
        buckets.append(Bucket(index=bi, leaf_ids=tuple(ids), raw=raw,
                              offset=offset, length=length))
        offset += length
    return tuple(buckets)


class SuperstepEngine:
    """Compile-once bucket plan + runtime lowering for one (pytree, mesh).

    Everything the engine computes is host-static (leaf specs, mesh shape,
    config), so it is safe to build at trace time and cache; the runtime
    methods (``pack``/``sync``/ZeRO helpers) are pure traced functions.
    """

    def __init__(self, leaf_specs: Sequence[LeafSpec], cfg: BSPConfig,
                 sizes: Sequence[int], zero1: bool = False):
        self.cfg = cfg
        self.sizes = tuple(sizes)
        self.axes = cfg.sync_axes
        self.world = math.prod(self.sizes)
        self.leaf_specs = tuple(leaf_specs)
        self.codec = make_codec(cfg.compression)
        # zero1: schedule picks price the trainer lowering (RS + shard
        # update + publish all-gather) instead of a bare all-reduce
        self.zero1 = zero1

        leaf_sizes = [s.size for s in self.leaf_specs]
        order = tuple(reversed(range(len(self.leaf_specs))))
        pad_unit = max(1, self.world) * cfg.pad_align
        self.flat_itemsize = int(jnp.dtype(self._flat_dtype()).itemsize)
        bucket_elems = None
        if cfg.bucket_mb is not None and cfg.overlap:
            bucket_elems = max(
                1, int(cfg.bucket_mb * 1e6 / self.flat_itemsize))
        self.buckets = partition_buckets(leaf_sizes, order, bucket_elems,
                                         pad_unit)
        self.total_padded = sum(b.length for b in self.buckets)

        if cfg.schedule == "auto":
            from .autotune import pick_bucket_schedules
            self.schedules = pick_bucket_schedules(
                self.sizes,
                [b.length * self.flat_itemsize for b in self.buckets],
                zero1_publish=zero1)
        else:
            self.schedules = (cfg.schedule,) * len(self.buckets)

    # -- plan inspection ----------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def shard_len(self, bucket: Bucket) -> int:
        return bucket.length // self.world

    def shard_offsets(self) -> Tuple[int, ...]:
        """Per-bucket start of this rank's shard in the rank-local moment
        vector (bucket-ordered concat of per-bucket shards)."""
        out, acc = [], 0
        for b in self.buckets:
            out.append(acc)
            acc += self.shard_len(b)
        return tuple(out)

    def programs(self) -> Tuple[schedule_ir.Program, ...]:
        """Bucket-tagged IR programs (one per bucket; "xla" not lowerable)."""
        out = []
        for b, name in zip(self.buckets, self.schedules):
            if name == "xla":
                raise ValueError("'xla' buckets have no IR program")
            prog = schedule_ir.build_program(name, self.sizes)
            out.append(prog.with_bucket(b.meta(self.n_buckets)))
        return tuple(out)

    def describe(self) -> str:
        bs = self.flat_itemsize
        parts = ", ".join(
            f"b{b.index}:{b.length * bs / 1e6:.1f}MB→{s}"
            for b, s in zip(self.buckets, self.schedules))
        return (f"{self.n_buckets} bucket(s) over world {self.world} "
                f"({self.total_padded * bs / 1e6:.1f}MB padded): {parts}")

    def timeline(self, backward_s: float,
                 link: LinkParams = TPU_V5E_ICI,
                 outer_link: Optional[LinkParams] = None,
                 mesh_contention: bool = True) -> OverlapTimeline:
        """Overlap-aware predicted step time for a given backward duration.

        Bucket i (reverse-layer) becomes ready once backward has produced
        its slice of the gradients: ready_i = backward_s × (cumulative
        parameter fraction through bucket i) — last layers first.
        """
        total_raw = max(1, sum(b.raw for b in self.buckets))
        ready, cum = [], 0
        for b in self.buckets:
            cum += b.raw
            ready.append(backward_s * cum / total_raw)
        vols = [float(b.length * self.flat_itemsize) for b in self.buckets]
        return overlap_step_cost(self.programs(), vols, ready, link,
                                 outer_link, mesh_contention)

    # -- runtime lowering ---------------------------------------------------

    def _flat_dtype(self):
        if not self.leaf_specs:
            return jnp.dtype(jnp.float32)
        return jnp.result_type(*[jnp.dtype(s.dtype)
                                 for s in self.leaf_specs])

    def pack(self, leaves: Sequence[jax.Array],
             dtype=None) -> List[jax.Array]:
        """Leaves → per-bucket padded flat vectors (bucket-ordered)."""
        dtype = dtype or self._flat_dtype()
        parts = []
        for b in self.buckets:
            segs = [leaves[i].reshape(-1).astype(dtype) for i in b.leaf_ids]
            flat = segs[0] if len(segs) == 1 else jnp.concatenate(segs)
            if b.raw != b.length:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((b.length - b.raw,), dtype)])
            parts.append(flat)
        return parts

    def unpack(self, parts: Sequence[jax.Array],
               like_leaves: Sequence[jax.Array]) -> List[jax.Array]:
        """Per-bucket flat vectors → leaves (original order, original
        dtypes)."""
        out: List[Optional[jax.Array]] = [None] * len(self.leaf_specs)
        for b, part in zip(self.buckets, parts):
            off = 0
            for i in b.leaf_ids:
                spec = self.leaf_specs[i]
                seg = lax.slice_in_dim(part, off, off + spec.size)
                out[i] = seg.reshape(spec.shape).astype(like_leaves[i].dtype)
                off += spec.size
        return out  # type: ignore[return-value]

    def _bucket_all_reduce(self, part: jax.Array, schedule: str) -> jax.Array:
        if schedule == "xla":
            return lax.psum(part, self.axes)
        if schedule == "fractal":
            return C.fractal_all_reduce(part, self.axes, self.sizes,
                                        codec=self.codec)
        return C.all_reduce(part, schedule, self.axes, self.sizes)

    def sync(self, grads: Any, mean: bool = True) -> Any:
        """Bucketed all-reduce of a gradient pytree — the drop-in
        replacement for the monolithic ``bsp.sync_gradients`` body."""
        if self.world == 1:
            return grads
        leaves, treedef = jax.tree.flatten(grads)
        parts = self.pack(leaves)
        out_parts = []
        for b, schedule, part in zip(self.buckets, self.schedules, parts):
            red = self._bucket_all_reduce(part, schedule)
            if mean:
                red = red / self.world
            out_parts.append(red)
        return treedef.unflatten(self.unpack(out_parts, leaves))

    def reduce_scatter_bucket(self, part: jax.Array,
                              schedule: str) -> jax.Array:
        """Sum-reduce-scatter of one bucket part (ZeRO-1 grad shard)."""
        return C.reduce_scatter(part, schedule, self.axes, self.sizes)

    def all_gather_bucket(self, shard: jax.Array) -> jax.Array:
        """Gather updated per-rank shards back into bucket flat order."""
        return C.all_gather_flat(shard, self.axes, self.sizes)


def leaf_specs_of(tree: Any, force_dtype=None) -> Tuple[LeafSpec, ...]:
    """LeafSpecs of a pytree of arrays / ShapeDtypeStructs."""
    return tuple(
        LeafSpec(shape=tuple(l.shape),
                 dtype=jnp.dtype(force_dtype or l.dtype).name)
        for l in jax.tree.leaves(tree))


@lru_cache(maxsize=64)
def _cached_engine(leaf_specs: Tuple[LeafSpec, ...], cfg: BSPConfig,
                   sizes: Tuple[int, ...], zero1: bool) -> SuperstepEngine:
    return SuperstepEngine(leaf_specs, cfg, sizes, zero1=zero1)


def engine_for(tree: Any, cfg: BSPConfig, sizes: Sequence[int],
               force_dtype=None, zero1: bool = False) -> SuperstepEngine:
    """The (cached) engine for this pytree's leaf structure.

    The plan depends only on leaf shapes/dtypes + config + mesh (+ the
    zero1 pricing mode), all host-static, so repeated traces reuse one
    engine.
    """
    return _cached_engine(leaf_specs_of(tree, force_dtype), cfg,
                          tuple(sizes), zero1)
