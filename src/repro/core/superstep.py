"""SuperstepEngine: bucketed, overlap-aware BSP gradient synchronization.

The paper makes the BSP barrier nearly free, which moves the superstep
bottleneck to the communication phase itself.  The monolithic path
(flatten → one all-reduce → unflatten) serializes compute and
communication: no gradient byte moves until the *whole* backward pass has
finished.  This module makes the Schedule IR a **runtime** concept:

  1. the gradient pytree is partitioned into size-bounded **buckets** in
     reverse-layer order (leaf order reversed), so bucket 0 — the LAST
     layers — is complete while backward is still chewing on the first
     layers;
  2. each bucket is compiled to its own Schedule-IR ``Program`` (tagged
     with ``BucketMeta`` so all IR consumers agree on bucket identity),
     with the autotuner picking a schedule *per bucket* — small late
     buckets lean butterfly (latency-bound), large early buckets lean ring
     (bandwidth-bound);
  3. the runtime lowering issues one collective per bucket inside the same
     jitted superstep.  The collectives are data-independent, so XLA's
     latency-hiding scheduler may overlap bucket i's communication with
     whatever compute still feeds bucket j>i — the structural opportunity
     the monolithic path denies it;
  4. ``cost_model.overlap_step_cost`` and ``simulator.pipelined_on_noc``
     price/replay the bucket pipeline on a *shared* fabric timeline, so
     predicted step time reflects compute/comm overlap instead of a sum
     (``benchmarks/overlap.py`` sweeps this against the monolithic
     baseline).

Numerics: bucketing permutes and re-groups the flat vector but reduces
every element through the same schedule arithmetic, so the bucketed sync
is equivalent to the monolithic path within f32 tolerance (bit-identical
for codec-free schedules; asserted in ``tests/superstep_checks.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives as C
from . import schedule_ir
from .bsp import BSPConfig, make_codec
from .cost_model import (LinkParams, OverlapTimeline, TPU_V5E_ICI,
                         overlap_step_cost)


@dataclass(frozen=True)
class LeafSpec:
    """Host-static shape/dtype of one gradient (or parameter) leaf."""

    shape: Tuple[int, ...]
    dtype: str

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass(frozen=True)
class Bucket:
    """One size-bounded slice of the bucket-ordered flat payload.

    ``leaf_ids`` index the *original* pytree leaf list; buckets concatenate
    leaves in reverse-layer order, so bucket 0 holds the tail of the model.
    ``offset``/``length`` locate the bucket's padded segment in the
    bucket-ordered flat vector (elements, not bytes).
    """

    index: int
    leaf_ids: Tuple[int, ...]
    raw: int                      # unpadded element count
    offset: int                   # start in the bucket-ordered flat vector
    length: int                   # padded element count (divides by world)

    def meta(self, n_buckets: int,
             codec: Optional[str] = None) -> schedule_ir.BucketMeta:
        return schedule_ir.BucketMeta(index=self.index, n_buckets=n_buckets,
                                      offset_elems=self.offset,
                                      length_elems=self.length,
                                      codec=codec)


def partition_buckets(leaf_sizes: Sequence[int], order: Sequence[int],
                      bucket_elems: Optional[int], pad_unit: int
                      ) -> Tuple[Bucket, ...]:
    """Greedy size-bounded partition of leaves (in ``order``) into buckets.

    A bucket closes once it holds ≥ ``bucket_elems`` raw elements (None →
    one bucket holds everything).  A single leaf larger than the bound gets
    its own bucket — the bound is a target, not a hard cap.  Every bucket
    is padded up to a multiple of ``pad_unit`` (world × pad_align, so the
    halving steps and per-rank shards stay lane-aligned).
    """
    groups: List[List[int]] = []
    cur: List[int] = []
    cur_elems = 0
    for i in order:
        if cur and bucket_elems is not None and \
                cur_elems + leaf_sizes[i] > bucket_elems:
            groups.append(cur)
            cur, cur_elems = [], 0
        cur.append(i)
        cur_elems += leaf_sizes[i]
    if cur:
        groups.append(cur)
    buckets: List[Bucket] = []
    offset = 0
    for bi, ids in enumerate(groups):
        raw = sum(leaf_sizes[i] for i in ids)
        length = ((raw + pad_unit - 1) // pad_unit) * pad_unit
        buckets.append(Bucket(index=bi, leaf_ids=tuple(ids), raw=raw,
                              offset=offset, length=length))
        offset += length
    return tuple(buckets)


# ---------------------------------------------------------------------------
# DP bucket-boundary search (BSPConfig(bucket_mb="auto"))
# ---------------------------------------------------------------------------
#
# A fixed ``bucket_mb`` is one point on a curve: small buckets start
# communication early but pay per-collective latency and padding; big
# buckets amortize both but idle the fabric while backward still computes.
# The overlapped finish time of a partition follows the shared-fabric
# recurrence
#
#     finish_k = max(finish_{k-1}, ready_k) + cost(bytes_k)
#
# which is monotone in finish_{k-1} — so the minimal finish over all
# boundary placements decomposes over prefixes and an O(n²) dynamic program
# over leaf prefix sums finds the EXACT optimum (the property test
# cross-checks it against brute-force boundary enumeration).  The greedy
# packer supplies the initial upper bound (branch pruning) and remains the
# fallback if float noise ever puts the DP above it.


@dataclass(frozen=True)
class PartitionPlan:
    """A searched bucket partition plus the objective it was chosen by."""

    buckets: Tuple[Bucket, ...]
    objective_s: float            # overlapped finish time under cost_fn
    source: str                   # "dp" | "greedy:<mb>MB"
    backward_s: float             # the backward duration the search assumed


GREEDY_FALLBACK_MBS = (4.0, 16.0, 64.0, 256.0)


def partition_objective(buckets: Sequence[Bucket],
                        cost_of_bytes: Callable[[float], float],
                        itemsize: int, backward_s: float) -> float:
    """Overlapped finish time of a partition on the shared-fabric timeline:
    bucket k enters the fabric at max(fabric-free, ready_k) — the same
    recurrence ``cost_model.overlap_step_cost`` prices, with per-bucket
    costs delegated to ``cost_of_bytes(padded bytes)``."""
    total_raw = max(1, sum(b.raw for b in buckets))
    fabric, cum = 0.0, 0
    for b in buckets:
        cum += b.raw
        ready = backward_s * cum / total_raw
        fabric = max(fabric, ready) + cost_of_bytes(b.length * itemsize)
    return fabric


def dp_partition(leaf_sizes: Sequence[int], order: Sequence[int],
                 pad_unit: int, itemsize: int,
                 cost_of_bytes: Callable[[float], float],
                 backward_s: float,
                 upper_bound: float = math.inf) -> Tuple[Bucket, ...]:
    """Optimal contiguous partition of ``order``-ed leaves into buckets,
    minimizing ``partition_objective``.

    ``f[i]`` = minimal fabric-free time after syncing the first ``i`` leaves;
    ``f[i] = min_j max(f[j], ready_i) + cost(bytes(j..i))``.  States already
    at or above ``upper_bound`` (the greedy packer's objective) are pruned —
    they cannot lead to a better plan since costs are nonnegative.
    """
    sizes_o = [leaf_sizes[i] for i in order]
    n = len(sizes_o)
    prefix = [0] * (n + 1)
    for i, s in enumerate(sizes_o):
        prefix[i + 1] = prefix[i] + s
    total_raw = max(1, prefix[n])

    def padded(raw: int) -> int:
        return ((raw + pad_unit - 1) // pad_unit) * pad_unit

    f = [math.inf] * (n + 1)
    f[0] = 0.0
    parent = [0] * (n + 1)
    for i in range(1, n + 1):
        ready = backward_s * prefix[i] / total_raw
        best, arg = math.inf, 0
        for j in range(i):
            if f[j] >= upper_bound or f[j] >= best:
                continue
            c = cost_of_bytes(padded(prefix[i] - prefix[j]) * itemsize)
            v = max(f[j], ready) + c
            if v < best:
                best, arg = v, j
        f[i], parent[i] = best, arg

    bounds: List[Tuple[int, int]] = []
    i = n
    while i > 0:
        bounds.append((parent[i], i))
        i = parent[i]
    bounds.reverse()
    buckets: List[Bucket] = []
    offset = 0
    for bi, (j, i) in enumerate(bounds):
        ids = tuple(order[j:i])
        raw = prefix[i] - prefix[j]
        length = padded(raw)
        buckets.append(Bucket(index=bi, leaf_ids=ids, raw=raw,
                              offset=offset, length=length))
        offset += length
    return tuple(buckets)


def search_bucket_partition(leaf_sizes: Sequence[int], order: Sequence[int],
                            pad_unit: int, itemsize: int,
                            cost_of_bytes: Callable[[float], float],
                            backward_s: Optional[float] = None,
                            greedy_mbs: Sequence[float] = GREEDY_FALLBACK_MBS
                            ) -> PartitionPlan:
    """Greedy candidates for the upper bound, then the DP for the optimum.

    ``backward_s`` is the assumed backward-pass duration the ready times
    scale against; None defaults to the cost of one monolithic collective
    over the whole payload — the balanced compute≈comm regime where bucket
    boundaries matter most (a workload-measured value refines it).
    """
    total = sum(leaf_sizes)
    total_padded = ((total + pad_unit - 1) // pad_unit) * pad_unit
    if backward_s is None:
        backward_s = cost_of_bytes(total_padded * itemsize)
    best: Optional[PartitionPlan] = None
    for mb in greedy_mbs:
        elems = max(1, int(mb * 1e6 / itemsize))
        g = partition_buckets(leaf_sizes, order, elems, pad_unit)
        obj = partition_objective(g, cost_of_bytes, itemsize, backward_s)
        if best is None or obj < best.objective_s:
            best = PartitionPlan(g, obj, f"greedy:{mb:g}MB", backward_s)
    dp = dp_partition(leaf_sizes, order, pad_unit, itemsize, cost_of_bytes,
                      backward_s, upper_bound=best.objective_s)
    dp_obj = partition_objective(dp, cost_of_bytes, itemsize, backward_s)
    if dp_obj <= best.objective_s:
        return PartitionPlan(dp, dp_obj, "dp", backward_s)
    return best


class SuperstepEngine:
    """Compile-once bucket plan + runtime lowering for one (pytree, mesh).

    Everything the engine computes is host-static (leaf specs, mesh shape,
    config), so it is safe to build at trace time and cache; the runtime
    methods (``pack``/``sync``/ZeRO helpers) are pure traced functions.
    """

    def __init__(self, leaf_specs: Sequence[LeafSpec], cfg: BSPConfig,
                 sizes: Sequence[int], zero1: bool = False,
                 backward_s: Optional[float] = None):
        self.cfg = cfg
        self.sizes = tuple(sizes)
        self.axes = cfg.sync_axes
        self.world = math.prod(self.sizes)
        self.leaf_specs = tuple(leaf_specs)
        self.codec = make_codec(cfg.compression)   # uniform legacy codec
        # zero1: schedule picks price the trainer lowering (RS + shard
        # update + publish all-gather) instead of a bare all-reduce
        self.zero1 = zero1
        # cost-model link the tuner prices with: fitted (calibrated) params
        # when the config carries them, analytic TPU defaults otherwise
        self.link = cfg.link if cfg.link is not None else TPU_V5E_ICI
        self.backward_s_hint = backward_s

        from . import autotune
        leaf_sizes = [s.size for s in self.leaf_specs]
        order = tuple(reversed(range(len(self.leaf_specs))))
        pad_unit = max(1, self.world) * cfg.pad_align
        self.flat_itemsize = int(jnp.dtype(self._flat_dtype()).itemsize)

        auto_codec = cfg.bucket_codec == "auto"
        # int8's per-128-block scales need 128-aligned wire payloads
        codec_candidates = ("none", "bf16") + \
            (("int8",) if cfg.pad_align % 128 == 0 else ())
        if cfg.schedule == "auto":
            sched_candidates = None
        elif cfg.schedule == "xla":
            sched_candidates = ("fractal",)    # price psum as the butterfly
        else:
            sched_candidates = (cfg.schedule,)

        def policy_rank(payload_bytes: float):
            return autotune.rank_policies(
                self.sizes, payload_bytes, link=self.link,
                schedules=sched_candidates,
                codecs=codec_candidates if auto_codec else ("none",),
                zero1_publish=zero1)

        self.plan: Optional[PartitionPlan] = None
        if cfg.overlap and cfg.bucket_mb == "auto":
            self.plan = search_bucket_partition(
                leaf_sizes, order, pad_unit, self.flat_itemsize,
                cost_of_bytes=lambda by: policy_rank(by)[0].predicted_s,
                backward_s=backward_s)
            self.buckets = self.plan.buckets
        else:
            bucket_elems = None
            if cfg.bucket_mb is not None and cfg.overlap:
                bucket_elems = max(
                    1, int(cfg.bucket_mb * 1e6 / self.flat_itemsize))
            self.buckets = partition_buckets(leaf_sizes, order, bucket_elems,
                                             pad_unit)
        self.total_padded = sum(b.length for b in self.buckets)

        bucket_bytes = [b.length * self.flat_itemsize for b in self.buckets]
        if cfg.schedule == "xla" or \
                (cfg.schedule != "auto" and not auto_codec):
            self.schedules = (cfg.schedule,) * len(self.buckets)
            self.codec_names = self._uniform_codec_names()
        else:
            policies = [policy_rank(by)[0] for by in bucket_bytes]
            self.schedules = tuple(p.schedule for p in policies)
            self.codec_names = tuple(p.codec for p in policies) \
                if auto_codec else self._uniform_codec_names()
        if cfg.bucket_codec is not None:
            # only the fractal lowering carries a wire codec — a forced
            # codec on any other schedule would be silently inert on the
            # wire while still costing EF quantization in the trainer, so
            # it is normalized away per bucket.  (The legacy uniform
            # `compression` keeps its historical EF-always semantics.)
            self.codec_names = tuple(
                c if s == "fractal" else "none"
                for s, c in zip(self.schedules, self.codec_names))
        self.bucket_codecs = tuple(make_codec(n) for n in self.codec_names)

    def _uniform_codec_names(self) -> Tuple[str, ...]:
        name = self.cfg.bucket_codec \
            if self.cfg.bucket_codec not in (None, "auto") \
            else (self.cfg.compression or "none")
        return (name,) * len(self.buckets)

    def refined(self, measure: Callable[[str, float], float],
                measure_budget: int,
                measure_top_k: int = 2) -> "SuperstepEngine":
        """Measured-refinement of the per-bucket schedule picks.

        Spends up to ``measure_budget`` calls of ``measure(schedule,
        payload_bytes) → seconds`` (real jitted timings) re-picking the
        analytic winners, priciest buckets first — see
        ``autotune.pick_bucket_schedules``.  Returns a shallow copy with
        the refined picks.  The engine's existing (codec-aware) picks are
        the refinement's baseline: buckets the budget never reaches keep
        them untouched, and a measured bucket only changes when another
        candidate out-measured its incumbent.  A bucket whose schedule
        does change keeps its codec only if the new schedule can carry one
        (the fractal lowering is the only wire-codec path).  A forced
        schedule (anything but "auto") is respected: refinement then has
        nothing to re-pick and the engine comes back unchanged.
        """
        import copy

        from .autotune import pick_bucket_schedules
        if self.cfg.schedule != "auto":
            return copy.copy(self)     # forced/xla: no candidates to try
        names = pick_bucket_schedules(
            self.sizes,
            [b.length * self.flat_itemsize for b in self.buckets],
            link=self.link, zero1_publish=self.zero1, measure=measure,
            measure_budget=measure_budget, measure_top_k=measure_top_k,
            baseline=self.schedules)
        eng = copy.copy(self)
        eng.schedules = tuple(names)
        eng.codec_names = tuple(
            c if new == "fractal" else "none"
            for new, c in zip(names, self.codec_names))
        eng.bucket_codecs = tuple(make_codec(n) for n in eng.codec_names)
        return eng

    # -- plan inspection ----------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def shard_len(self, bucket: Bucket) -> int:
        return bucket.length // self.world

    def shard_offsets(self) -> Tuple[int, ...]:
        """Per-bucket start of this rank's shard in the rank-local moment
        vector (bucket-ordered concat of per-bucket shards)."""
        out, acc = [], 0
        for b in self.buckets:
            out.append(acc)
            acc += self.shard_len(b)
        return tuple(out)

    def programs(self) -> Tuple[schedule_ir.Program, ...]:
        """Bucket-tagged IR programs (one per bucket; "xla" not lowerable)."""
        out = []
        for b, name, codec in zip(self.buckets, self.schedules,
                                  self.codec_names):
            if name == "xla":
                raise ValueError("'xla' buckets have no IR program")
            prog = schedule_ir.build_program(name, self.sizes)
            meta = b.meta(self.n_buckets,
                          codec=None if codec == "none" else codec)
            out.append(prog.with_bucket(meta))
        return tuple(out)

    def describe(self) -> str:
        bs = self.flat_itemsize
        parts = ", ".join(
            f"b{b.index}:{b.length * bs / 1e6:.1f}MB→{s}"
            + ("" if c == "none" else f"+{c}")
            for b, s, c in zip(self.buckets, self.schedules,
                               self.codec_names))
        src = f" [{self.plan.source}]" if self.plan is not None else ""
        return (f"{self.n_buckets} bucket(s) over world {self.world} "
                f"({self.total_padded * bs / 1e6:.1f}MB padded){src}: "
                f"{parts}")

    def timeline(self, backward_s: float,
                 link: Optional[LinkParams] = None,
                 outer_link: Optional[LinkParams] = None,
                 mesh_contention: bool = True) -> OverlapTimeline:
        """Overlap-aware predicted step time for a given backward duration.

        Bucket i (reverse-layer) becomes ready once backward has produced
        its slice of the gradients: ready_i = backward_s × (cumulative
        parameter fraction through bucket i) — last layers first.
        ``link=None`` prices with the engine's own link (the calibrated
        params when ``BSPConfig(link=…)`` carries them).  Per-bucket codecs
        shrink the priced wire volume by their wire-bytes ratio and pay
        their quant/dequant launch overhead — the same terms the policy
        pricing (``autotune.rank_policies``) chose them by.
        """
        from .autotune import CODEC_WIRE_RATIO, codec_step_alphas
        alphas = codec_step_alphas()
        link = link if link is not None else self.link
        total_raw = max(1, sum(b.raw for b in self.buckets))
        ready, cum = [], 0
        for b in self.buckets:
            cum += b.raw
            ready.append(backward_s * cum / total_raw)
        vols = [float(b.length * self.flat_itemsize)
                * CODEC_WIRE_RATIO.get(c, 1.0)
                for b, c in zip(self.buckets, self.codec_names)]
        progs = self.programs()
        extra = [alphas.get(c, 0.0) * link.alpha_s * p.num_steps
                 for c, p in zip(self.codec_names, progs)]
        return overlap_step_cost(progs, vols, ready, link,
                                 outer_link, mesh_contention, extra_s=extra)

    # -- runtime lowering ---------------------------------------------------

    def _flat_dtype(self):
        if not self.leaf_specs:
            return jnp.dtype(jnp.float32)
        return jnp.result_type(*[jnp.dtype(s.dtype)
                                 for s in self.leaf_specs])

    def pack(self, leaves: Sequence[jax.Array],
             dtype=None) -> List[jax.Array]:
        """Leaves → per-bucket padded flat vectors (bucket-ordered)."""
        dtype = dtype or self._flat_dtype()
        parts = []
        for b in self.buckets:
            segs = [leaves[i].reshape(-1).astype(dtype) for i in b.leaf_ids]
            flat = segs[0] if len(segs) == 1 else jnp.concatenate(segs)
            if b.raw != b.length:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((b.length - b.raw,), dtype)])
            parts.append(flat)
        return parts

    def unpack(self, parts: Sequence[jax.Array],
               like_leaves: Sequence[jax.Array]) -> List[jax.Array]:
        """Per-bucket flat vectors → leaves (original order, original
        dtypes)."""
        out: List[Optional[jax.Array]] = [None] * len(self.leaf_specs)
        for b, part in zip(self.buckets, parts):
            off = 0
            for i in b.leaf_ids:
                spec = self.leaf_specs[i]
                seg = lax.slice_in_dim(part, off, off + spec.size)
                out[i] = seg.reshape(spec.shape).astype(like_leaves[i].dtype)
                off += spec.size
        return out  # type: ignore[return-value]

    def _bucket_all_reduce(self, part: jax.Array, schedule: str,
                           codec=None) -> jax.Array:
        if schedule == "xla":
            return lax.psum(part, self.axes)
        if schedule == "fractal":
            return C.fractal_all_reduce(part, self.axes, self.sizes,
                                        codec=codec)
        return C.all_reduce(part, schedule, self.axes, self.sizes)

    def sync(self, grads: Any, mean: bool = True) -> Any:
        """Bucketed all-reduce of a gradient pytree — the drop-in
        replacement for the monolithic ``bsp.sync_gradients`` body.
        Each bucket rides its own codec (per-bucket policy under
        ``bucket_codec="auto"``; the uniform ``compression`` otherwise)."""
        if self.world == 1:
            return grads
        leaves, treedef = jax.tree.flatten(grads)
        parts = self.pack(leaves)
        out_parts = []
        for b, schedule, codec, part in zip(self.buckets, self.schedules,
                                            self.bucket_codecs, parts):
            red = self._bucket_all_reduce(part, schedule, codec)
            if mean:
                red = red / self.world
            out_parts.append(red)
        return treedef.unflatten(self.unpack(out_parts, leaves))

    def reduce_scatter_bucket(self, part: jax.Array, schedule: str,
                              codec=None) -> jax.Array:
        """Sum-reduce-scatter of one bucket part (ZeRO-1 grad shard);
        ``codec`` wire-compresses the fractal halving exchanges."""
        return C.reduce_scatter(part, schedule, self.axes, self.sizes,
                                codec=codec)

    def all_gather_bucket(self, shard: jax.Array) -> jax.Array:
        """Gather updated per-rank shards back into bucket flat order."""
        return C.all_gather_flat(shard, self.axes, self.sizes)


def leaf_specs_of(tree: Any, force_dtype=None) -> Tuple[LeafSpec, ...]:
    """LeafSpecs of a pytree of arrays / ShapeDtypeStructs."""
    return tuple(
        LeafSpec(shape=tuple(l.shape),
                 dtype=jnp.dtype(force_dtype or l.dtype).name)
        for l in jax.tree.leaves(tree))


@lru_cache(maxsize=64)
def _cached_engine(leaf_specs: Tuple[LeafSpec, ...], cfg: BSPConfig,
                   sizes: Tuple[int, ...], zero1: bool,
                   backward_s: Optional[float]) -> SuperstepEngine:
    return SuperstepEngine(leaf_specs, cfg, sizes, zero1=zero1,
                           backward_s=backward_s)


def engine_for(tree: Any, cfg: BSPConfig, sizes: Sequence[int],
               force_dtype=None, zero1: bool = False,
               backward_s: Optional[float] = None) -> SuperstepEngine:
    """The (cached) engine for this pytree's leaf structure.

    The plan depends only on leaf shapes/dtypes + config + mesh (+ the
    zero1 pricing mode and the DP search's backward hint), all host-static,
    so repeated traces reuse one engine.
    """
    return _cached_engine(leaf_specs_of(tree, force_dtype), cfg,
                          tuple(sizes), zero1, backward_s)
