"""Cycle-accurate discrete-event simulator of MAGIA synchronization (paper §4.1).

Reproduces Table 1: the latency of four barrier schemes on tile meshes from
*Neighbor* (two adjacent tiles) up to 16×16:

  * **FSync**    — native FractalSync H-tree (dedicated wires, no NoC traffic).
  * **FSync+P**  — FractalSync with pipeline registers on wires longer than one
                   NoC pitch (closes 1 GHz timing; paper's headline scheme).
  * **Naïve**    — software barrier via atomic memory operations (AMOs) to a
                   single master tile over the NoC: fetch-add a counter, last
                   arriver writes a release flag, everyone else spin-polls it.
  * **XY**       — dimension-ordered software barrier: each row barriers on its
                   row-master (phase 1), row-masters barrier on the global
                   master (phase 2), release cascades back. Linear scaling.

The NoC model is an XY-routed 2D mesh with contended links (1-flit messages,
store-and-forward, per-hop latency + link occupancy) and a per-tile AMO unit
that serializes atomic operations (models MAGIA's HCI AMO module). Software
overheads (issue, poll loop, exit) are parameters; ``DEFAULT_PARAMS`` was
calibrated against Table 1 (see ``core/calibrate.py`` and EXPERIMENTS.md).

Synchronization overhead metric (paper §4.1):  Ŝ := max(F) − max(R), where R
are the cycles at which tiles request synchronization and F the cycles at which
they execute the instruction following synchronization.
"""

from __future__ import annotations

import heapq
import itertools
import math
from collections import defaultdict
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .tree import FractalTree

Coord = Tuple[int, int]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimParams:
    """Micro-architectural + software constants (cycles @ 1 GHz).

    Calibrated against the paper's Table 1 AMO baselines (16 KiB I$, cache
    pre-heating). The FractalSync columns are parameter-free (pure topology).
    """

    hop_latency: int = 4        # router→router traversal (FlooNoC-like)
    link_occupancy: int = 3     # cycles a 1-flit msg holds a link
    inj_latency: int = 0        # tile↔router network-interface latency
    amo_service: int = 11       # AMO unit service time per op (HCI + bank)
    sw_pre: int = 0             # sync request → first AMO issued
    sw_between: int = 17        # gap between dependent ops in SW
    sw_poll: int = 22           # spin-loop overhead between polls
    sw_post: int = 3            # release observed → next instruction retires


DEFAULT_PARAMS = SimParams()


# ---------------------------------------------------------------------------
# Event engine
# ---------------------------------------------------------------------------


class SimBudgetExceeded(RuntimeError):
    """Simulation ran past its cycle/event budget (pathological parameters)."""


class EventSim:
    """Minimal deterministic discrete-event engine."""

    def __init__(self) -> None:
        self.now = 0
        self._q: List[Tuple[int, int, Callable[[int], None]]] = []
        self._seq = itertools.count()

    def at(self, time: int, fn: Callable[[int], None]) -> None:
        if time < self.now:
            raise RuntimeError(f"scheduling into the past: {time} < {self.now}")
        heapq.heappush(self._q, (time, next(self._seq), fn))

    def run(self, horizon: int = 200_000, max_events: int = 2_000_000) -> None:
        events = 0
        while self._q:
            t, _, fn = heapq.heappop(self._q)
            events += 1
            if t > horizon or events > max_events:
                raise SimBudgetExceeded(
                    f"simulation exceeded budget (t={t}, events={events})")
            self.now = t
            fn(t)


# ---------------------------------------------------------------------------
# NoC: XY-routed 2D mesh with contended links
# ---------------------------------------------------------------------------


class NoC:
    """XY dimension-ordered routing, single physical channel (paper §2.2).

    Links (incl. tile↔router injection/ejection ports) are modeled as
    resources with an occupancy window; 1-flit messages advance hop-by-hop.
    Contention at the master tile's ejection port is what makes centralized
    AMO barriers quadratic — exactly the effect the paper measures.
    """

    def __init__(self, sim: EventSim, rows: int, cols: int, p: SimParams):
        self.sim = sim
        self.rows, self.cols = rows, cols
        self.p = p
        self.link_free: Dict[tuple, int] = defaultdict(int)
        self.total_msgs = 0
        self.total_hops = 0

    def _path(self, src: Coord, dst: Coord) -> List[tuple]:
        """List of (link_key, latency) from src tile to dst tile."""
        links: List[tuple] = [(("inj", src), self.p.inj_latency)]
        r, c = src
        while c != dst[1]:
            nc = c + (1 if dst[1] > c else -1)
            links.append(((("rtr", (r, c)), ("rtr", (r, nc))), self.p.hop_latency))
            c = nc
        while r != dst[0]:
            nr = r + (1 if dst[0] > r else -1)
            links.append(((("rtr", (r, c)), ("rtr", (nr, c))), self.p.hop_latency))
            r = nr
        links.append((("ej", dst), self.p.inj_latency))
        return links

    def send(self, t: int, src: Coord, dst: Coord,
             on_deliver: Callable[[int], None]) -> None:
        """Inject a 1-flit message at time t; call on_deliver at arrival."""
        assert src != dst, "local operations must not use the NoC"
        path = self._path(src, dst)
        self.total_msgs += 1
        self.total_hops += len(path) - 2

        def advance(i: int, t: int) -> None:
            if i == len(path):
                on_deliver(t)
                return
            key, lat = path[i]
            free = self.link_free[key]
            if free > t:
                self.sim.at(free, lambda tt: advance(i, tt))
                return
            self.link_free[key] = t + self.p.link_occupancy
            self.sim.at(t + lat, lambda tt: advance(i + 1, tt))

        advance(0, t)


# ---------------------------------------------------------------------------
# AMO unit (per tile): serializes atomic ops on that tile's L1
# ---------------------------------------------------------------------------


class AMOUnit:
    def __init__(self, sim: EventSim, p: SimParams):
        self.sim = sim
        self.p = p
        self.busy_until = 0
        self.mem: Dict[str, int] = defaultdict(int)
        self.ops_served = 0

    def request(self, t: int, op: str, addr: str, val: int,
                reply: Callable[[int, int], None]) -> None:
        """op ∈ {fetch_add, read, write}; reply(time, old_value)."""
        start = max(t, self.busy_until)
        done = start + self.p.amo_service
        self.busy_until = done
        self.ops_served += 1

        def fire(tt: int) -> None:
            old = self.mem[addr]
            if op == "fetch_add":
                self.mem[addr] = old + val
            elif op == "write":
                self.mem[addr] = val
            elif op != "read":
                raise ValueError(op)
            reply(tt, old)

        self.sim.at(done, fire)


# ---------------------------------------------------------------------------
# Software AMO barrier schemes (the paper's baselines)
# ---------------------------------------------------------------------------


class _AMOMachine:
    """Shared plumbing: issue an AMO op to a (possibly remote) tile."""

    def __init__(self, rows: int, cols: int, p: SimParams):
        self.rows, self.cols = rows, cols
        self.p = p
        self.sim = EventSim()
        self.noc = NoC(self.sim, rows, cols, p)
        self.amo = {
            (r, c): AMOUnit(self.sim, p)
            for r in range(rows) for c in range(cols)
        }
        self.finish: Dict[Coord, int] = {}

    def tiles(self) -> List[Coord]:
        return [(r, c) for r in range(self.rows) for c in range(self.cols)]

    def amo_op(self, t: int, src: Coord, dst: Coord, op: str, addr: str,
               val: int, reply: Callable[[int, int], None]) -> None:
        """Round-trip AMO: NoC request → AMO unit → NoC response (or local)."""
        unit = self.amo[dst]
        if src == dst:
            unit.request(t, op, addr, val, reply)
            return

        def deliver_req(tt: int) -> None:
            unit.request(tt, op, addr, val,
                         lambda td, old: self.noc.send(
                             td, dst, src, lambda ta: reply(ta, old)))

        self.noc.send(t, src, dst, deliver_req)

    def overhead(self, requests: Dict[Coord, int]) -> int:
        """Ŝ = max(F) − max(R)."""
        return max(self.finish.values()) - max(requests.values())


class NaiveBarrier(_AMOMachine):
    """Single master tile accepts requests and dispatches responses (§4.1).

    fetch-add a counter at the master; the arriver that reads N-1 writes the
    release flag; all others spin-poll the flag over the NoC.
    """

    def run(self, requests: Optional[Dict[Coord, int]] = None,
            master: Coord = (0, 0)) -> int:
        tiles = self.tiles()
        n = len(tiles)
        requests = requests or {t: 0 for t in tiles}
        p = self.p

        def poll(tile: Coord, t: int) -> None:
            def on_flag(tt: int, flag: int) -> None:
                if flag:
                    self.finish[tile] = tt + p.sw_post
                else:
                    self.sim.at(tt + p.sw_poll,
                                lambda t2: poll(tile, t2))
            self.amo_op(t, tile, master, "read", "flag", 0, on_flag)

        def start(tile: Coord, t: int) -> None:
            def on_count(tt: int, old: int) -> None:
                if old == n - 1:  # last arriver: release everyone
                    def on_release(td: int, _old: int) -> None:
                        self.finish[tile] = td + p.sw_post
                    self.amo_op(tt + p.sw_between, tile, master,
                                "write", "flag", 1, on_release)
                else:
                    self.sim.at(tt + p.sw_between,
                                lambda t2: poll(tile, t2))
            self.amo_op(t + p.sw_pre, tile, master, "fetch_add", "count", 1,
                        on_count)

        for tile, r in requests.items():
            self.sim.at(r, lambda t, tile=tile: start(tile, t))
        self.sim.run()
        return self.overhead(requests)


class XYBarrier(_AMOMachine):
    """Two 1D phases: rows barrier on row-masters (col 0), then row-masters
    barrier on the global master (0,0); release cascades back (§4.1)."""

    def run(self, requests: Optional[Dict[Coord, int]] = None) -> int:
        tiles = self.tiles()
        requests = requests or {t: 0 for t in tiles}
        p = self.p
        k_cols = self.cols
        k_rows = self.rows
        gmaster = (0, 0)

        def poll(tile: Coord, at_tile: Coord, addr: str,
                 on_set: Callable[[int], None], t: int) -> None:
            def on_rd(tt: int, v: int) -> None:
                if v:
                    on_set(tt)
                else:
                    self.sim.at(tt + p.sw_poll,
                                lambda t2: poll(tile, at_tile, addr, on_set, t2))
            self.amo_op(t, tile, at_tile, "read", addr, 0, on_rd)

        # ---- phase 2: row masters barrier at global master -----------------
        def phase2(rm: Coord, t: int) -> None:
            def on_count(tt: int, old: int) -> None:
                if old == k_rows - 1:
                    def on_release(td: int, _o: int) -> None:
                        release_row(rm, td)
                    self.amo_op(tt + p.sw_between, rm, gmaster,
                                "write", "gflag", 1, on_release)
                else:
                    self.sim.at(tt + p.sw_between,
                                lambda t2: poll(rm, gmaster, "gflag",
                                                lambda td: release_row(rm, td),
                                                t2))
            self.amo_op(t + p.sw_between, rm, gmaster, "fetch_add", "gcount",
                        1, on_count)

        # ---- release: row master writes its local row flag ------------------
        def release_row(rm: Coord, t: int) -> None:
            def on_wr(tt: int, _o: int) -> None:
                self.finish[rm] = tt + p.sw_post
            self.amo_op(t + p.sw_between, rm, rm, "write", "rflag", 1, on_wr)

        # ---- phase 1: tiles barrier at their row master ----------------------
        def start(tile: Coord, t: int) -> None:
            r, c = tile
            rm = (r, 0)
            if tile == rm:
                # Row master spin-polls its LOCAL row counter until the other
                # k-1 row tiles have arrived, then enters phase 2.
                def wait_row(tt: int) -> None:
                    def on_rd(td: int, v: int) -> None:
                        if v == k_cols - 1:
                            phase2(rm, td)
                        else:
                            self.sim.at(td + p.sw_poll, wait_row)
                    self.amo_op(tt, rm, rm, "read", "rcount", 0, on_rd)
                self.sim.at(t + p.sw_pre, wait_row)
            else:
                def on_count(tt: int, _old: int) -> None:
                    self.sim.at(tt + p.sw_between,
                                lambda t2: poll(tile, rm, "rflag",
                                                lambda td: self.finish.__setitem__(
                                                    tile, td + p.sw_post),
                                                t2))
                self.amo_op(t + p.sw_pre, tile, rm, "fetch_add", "rcount", 1,
                            on_count)

        for tile, r in requests.items():
            self.sim.at(r, lambda t, tile=tile: start(tile, t))
        self.sim.run()
        return self.overhead(requests)


# ---------------------------------------------------------------------------
# FractalSync event model (dedicated H-tree network, §3)
# ---------------------------------------------------------------------------


class FractalSyncSim:
    """Event-driven model of the FS tree with arbitrary arrival skew.

    Up-edge into a level-l module costs 1 cycle (FSM) plus, if pipelined, the
    level's pipeline registers; the down (wake) path mirrors it; +2 cycles for
    request sampling and wake detection at the tile.  With aligned arrivals
    this equals ``FractalTree.fsync_latency`` (Table 1 exactly).
    """

    def __init__(self, tree: FractalTree, pipelined: bool = False):
        self.tree = tree
        self.pipelined = pipelined

    def run(self, requests: Optional[Dict[tuple, int]] = None,
            level: Optional[int] = None) -> Tuple[int, Dict[tuple, int]]:
        tree = self.tree
        level = tree.num_levels if level is None else level
        tiles = list(tree.tiles())
        requests = requests or {t: 0 for t in tiles}

        # Upward sweep: module at (lvl, key) fires at max(children)+cost(lvl).
        fire_time: Dict[tuple, int] = {}
        arrive: Dict[tuple, int] = {("tile", t): requests[t] + 1 for t in tiles}
        groups: Dict[tuple, List[int]] = defaultdict(list)
        for t in tiles:
            groups[tree.domain_key(t, 1)].append(arrive[("tile", t)])
        prev = {k: v for k, v in groups.items()}
        for lvl in range(1, level + 1):
            spec = tree.level(lvl)
            cost = 1 + (spec.pipeline_regs if self.pipelined else 0)
            nxt: Dict[tuple, List[int]] = defaultdict(list)
            fired: Dict[tuple, int] = {}
            for key, times in prev.items():
                fired[key] = max(times) + cost
            fire_time.update({(lvl, k): v for k, v in fired.items()})
            if lvl < level:
                for t in tiles:
                    k_here = tree.domain_key(t, lvl)
                    k_up = tree.domain_key(t, lvl + 1)
                    nxt[k_up].append(fired[k_here])
                # dedupe: each module reports once, not once per tile
                prev = {k: sorted(set(v)) for k, v in nxt.items()}

        # Downward sweep: wake propagates back through the same edges.
        down_cost = sum(
            1 + (tree.level(l).pipeline_regs if self.pipelined else 0)
            for l in range(1, level + 1)
        )
        finish: Dict[tuple, int] = {}
        for t in tiles:
            root_key = tree.domain_key(t, level)
            finish[t] = fire_time[(level, root_key)] + down_cost + 1

        overhead = max(finish.values()) - max(requests.values())
        return overhead, finish


# ---------------------------------------------------------------------------
# Table 1 driver
# ---------------------------------------------------------------------------

PAPER_TABLE1 = {
    # mesh: (FSync, FSync+P, Naive, XY, speedup "FSync+P vs best AMO")
    "Neighbor": (4, 4, 79, 79, 19),
    "2x2": (6, 6, 119, 219, 19),
    "4x4": (10, 10, 512, 347, 34),
    "8x8": (14, 18, 2488, 614, 34),
    "16x16": (18, 34, 13961, 1462, 43),
}


def _mesh_of(name: str) -> Tuple[int, int]:
    if name == "Neighbor":
        return (1, 2)
    k = int(name.split("x")[0])
    return (k, k)


def simulate_config(name: str, params: SimParams = DEFAULT_PARAMS
                    ) -> Dict[str, float]:
    rows, cols = _mesh_of(name)
    tree = FractalTree((rows, cols))
    fsync = tree.fsync_latency()
    fsync_p = tree.fsync_latency(pipelined=True)
    naive = NaiveBarrier(rows, cols, params).run()
    # Paper reports identical Neighbor numbers for Naive and XY (2 tiles: XY
    # degenerates to the centralized scheme).
    xy = naive if rows * cols == 2 else XYBarrier(rows, cols, params).run()
    best_amo = min(naive, xy)
    return {
        "fsync": fsync,
        "fsync_p": fsync_p,
        "naive": naive,
        "xy": xy,
        "best_amo": best_amo,
        "speedup": best_amo / fsync_p,
    }


def table1(params: SimParams = DEFAULT_PARAMS,
           configs: Sequence[str] = tuple(PAPER_TABLE1)) -> Dict[str, Dict[str, float]]:
    return {name: simulate_config(name, params) for name in configs}


def scaling_sweep(ks: Sequence[int] = (2, 4, 8, 16, 32, 64),
                  params: SimParams = DEFAULT_PARAMS,
                  max_amo_k: int = 16) -> Dict[str, Dict[str, float]]:
    """Beyond-paper: extend the sweep past 16×16. AMO sims above ``max_amo_k``
    are skipped (quadratic event counts); FSync columns are analytic."""
    out: Dict[str, Dict[str, float]] = {}
    for k in ks:
        name = f"{k}x{k}"
        tree = FractalTree((k, k))
        row: Dict[str, float] = {
            "fsync": tree.fsync_latency(),
            "fsync_p": tree.fsync_latency(pipelined=True),
        }
        if k <= max_amo_k:
            row.update(
                naive=NaiveBarrier(k, k, params).run(),
                xy=XYBarrier(k, k, params).run(),
            )
            row["speedup"] = min(row["naive"], row["xy"]) / row["fsync_p"]
        out[name] = row
    return out
