"""Cycle-accurate discrete-event simulator of MAGIA synchronization (paper §4.1).

Reproduces Table 1: the latency of four barrier schemes on tile meshes from
*Neighbor* (two adjacent tiles) up to 16×16:

  * **FSync**    — native FractalSync H-tree (dedicated wires, no NoC traffic).
  * **FSync+P**  — FractalSync with pipeline registers on wires longer than one
                   NoC pitch (closes 1 GHz timing; paper's headline scheme).
  * **Naïve**    — software barrier via atomic memory operations (AMOs) to a
                   single master tile over the NoC: fetch-add a counter, last
                   arriver writes a release flag, everyone else spin-polls it.
  * **XY**       — dimension-ordered software barrier: each row barriers on its
                   row-master (phase 1), row-masters barrier on the global
                   master (phase 2), release cascades back. Linear scaling.

The NoC model is an XY-routed 2D mesh with contended links (1-flit messages,
store-and-forward, per-hop latency + link occupancy) and a per-tile AMO unit
that serializes atomic operations (models MAGIA's HCI AMO module). Software
overheads (issue, poll loop, exit) are parameters; ``DEFAULT_PARAMS`` was
calibrated against Table 1 (see ``core/calibrate.py`` and EXPERIMENTS.md).

Synchronization overhead metric (paper §4.1):  Ŝ := max(F) − max(R), where R
are the cycles at which tiles request synchronization and F the cycles at which
they execute the instruction following synchronization.
"""

from __future__ import annotations

import heapq
import itertools
import math
from collections import defaultdict
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import schedule_ir
from .tree import FractalTree

Coord = Tuple[int, int]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimParams:
    """Micro-architectural + software constants (cycles @ 1 GHz).

    Calibrated against the paper's Table 1 AMO baselines (16 KiB I$, cache
    pre-heating). The FractalSync columns are parameter-free (pure topology).
    """

    hop_latency: int = 4        # router→router traversal (FlooNoC-like)
    link_occupancy: int = 3     # cycles a 1-flit msg holds a link
    inj_latency: int = 0        # tile↔router network-interface latency
    amo_service: int = 11       # AMO unit service time per op (HCI + bank)
    sw_pre: int = 0             # sync request → first AMO issued
    sw_between: int = 17        # gap between dependent ops in SW
    sw_poll: int = 22           # spin-loop overhead between polls
    sw_post: int = 3            # release observed → next instruction retires


DEFAULT_PARAMS = SimParams()


# ---------------------------------------------------------------------------
# Event engine
# ---------------------------------------------------------------------------


class SimBudgetExceeded(RuntimeError):
    """Simulation ran past its cycle/event budget (pathological parameters)."""


class EventSim:
    """Minimal deterministic discrete-event engine."""

    def __init__(self) -> None:
        self.now = 0
        self._q: List[Tuple[int, int, Callable[[int], None]]] = []
        self._seq = itertools.count()

    def at(self, time: int, fn: Callable[[int], None]) -> None:
        if time < self.now:
            raise RuntimeError(f"scheduling into the past: {time} < {self.now}")
        heapq.heappush(self._q, (time, next(self._seq), fn))

    def run(self, horizon: int = 200_000, max_events: int = 2_000_000) -> None:
        events = 0
        while self._q:
            t, _, fn = heapq.heappop(self._q)
            events += 1
            if t > horizon or events > max_events:
                raise SimBudgetExceeded(
                    f"simulation exceeded budget (t={t}, events={events})")
            self.now = t
            fn(t)


# ---------------------------------------------------------------------------
# NoC: XY-routed 2D mesh with contended links
# ---------------------------------------------------------------------------


class NoC:
    """XY dimension-ordered routing, single physical channel (paper §2.2).

    Links (incl. tile↔router injection/ejection ports) are modeled as
    resources with an occupancy window; 1-flit messages advance hop-by-hop.
    Contention at the master tile's ejection port is what makes centralized
    AMO barriers quadratic — exactly the effect the paper measures.
    """

    def __init__(self, sim: EventSim, rows: int, cols: int, p: SimParams):
        self.sim = sim
        self.rows, self.cols = rows, cols
        self.p = p
        self.link_free: Dict[tuple, int] = defaultdict(int)
        self.total_msgs = 0
        self.total_hops = 0

    def _path(self, src: Coord, dst: Coord) -> List[tuple]:
        """List of (link_key, latency) from src tile to dst tile."""
        links: List[tuple] = [(("inj", src), self.p.inj_latency)]
        r, c = src
        while c != dst[1]:
            nc = c + (1 if dst[1] > c else -1)
            links.append(((("rtr", (r, c)), ("rtr", (r, nc))), self.p.hop_latency))
            c = nc
        while r != dst[0]:
            nr = r + (1 if dst[0] > r else -1)
            links.append(((("rtr", (r, c)), ("rtr", (nr, c))), self.p.hop_latency))
            r = nr
        links.append((("ej", dst), self.p.inj_latency))
        return links

    def send(self, t: int, src: Coord, dst: Coord,
             on_deliver: Callable[[int], None], flits: int = 1) -> None:
        """Inject a message at time t; call on_deliver at (tail) arrival.

        ``flits > 1`` models payload-carrying messages: each traversed link
        is held for ``flits · link_occupancy`` cycles and the tail trails
        the head by the serialization delay (wormhole-ish store-and-forward,
        used by ``schedule_on_noc`` for all-reduce payloads)."""
        assert src != dst, "local operations must not use the NoC"
        path = self._path(src, dst)
        self.total_msgs += 1
        self.total_hops += len(path) - 2
        occupy = self.p.link_occupancy * max(1, flits)
        serial = self.p.link_occupancy * (max(1, flits) - 1)

        def advance(i: int, t: int) -> None:
            if i == len(path):
                on_deliver(t)
                return
            key, lat = path[i]
            free = self.link_free[key]
            if free > t:
                self.sim.at(free, lambda tt: advance(i, tt))
                return
            self.link_free[key] = t + occupy
            self.sim.at(t + lat + serial, lambda tt: advance(i + 1, tt))

        advance(0, t)


# ---------------------------------------------------------------------------
# AMO unit (per tile): serializes atomic ops on that tile's L1
# ---------------------------------------------------------------------------


class AMOUnit:
    def __init__(self, sim: EventSim, p: SimParams):
        self.sim = sim
        self.p = p
        self.busy_until = 0
        self.mem: Dict[str, int] = defaultdict(int)
        self.ops_served = 0

    def request(self, t: int, op: str, addr: str, val: int,
                reply: Callable[[int, int], None]) -> None:
        """op ∈ {fetch_add, read, write}; reply(time, old_value)."""
        start = max(t, self.busy_until)
        done = start + self.p.amo_service
        self.busy_until = done
        self.ops_served += 1

        def fire(tt: int) -> None:
            old = self.mem[addr]
            if op == "fetch_add":
                self.mem[addr] = old + val
            elif op == "write":
                self.mem[addr] = val
            elif op != "read":
                raise ValueError(op)
            reply(tt, old)

        self.sim.at(done, fire)


# ---------------------------------------------------------------------------
# Software AMO barrier schemes (the paper's baselines)
# ---------------------------------------------------------------------------


class _AMOMachine:
    """Shared plumbing: issue an AMO op to a (possibly remote) tile."""

    def __init__(self, rows: int, cols: int, p: SimParams):
        self.rows, self.cols = rows, cols
        self.p = p
        self.sim = EventSim()
        self.noc = NoC(self.sim, rows, cols, p)
        self.amo = {
            (r, c): AMOUnit(self.sim, p)
            for r in range(rows) for c in range(cols)
        }
        self.finish: Dict[Coord, int] = {}

    def tiles(self) -> List[Coord]:
        return [(r, c) for r in range(self.rows) for c in range(self.cols)]

    def amo_op(self, t: int, src: Coord, dst: Coord, op: str, addr: str,
               val: int, reply: Callable[[int, int], None]) -> None:
        """Round-trip AMO: NoC request → AMO unit → NoC response (or local)."""
        unit = self.amo[dst]
        if src == dst:
            unit.request(t, op, addr, val, reply)
            return

        def deliver_req(tt: int) -> None:
            unit.request(tt, op, addr, val,
                         lambda td, old: self.noc.send(
                             td, dst, src, lambda ta: reply(ta, old)))

        self.noc.send(t, src, dst, deliver_req)

    def overhead(self, requests: Dict[Coord, int]) -> int:
        """Ŝ = max(F) − max(R)."""
        return max(self.finish.values()) - max(requests.values())


class HierarchicalAMOBarrier(_AMOMachine):
    """Generic AMO barrier executor over any gather-tree barrier Program.

    The IR supplies the *topology* — its reduce steps, bottom-up, define the
    levels of a synchronization hierarchy (group members per master); this
    class supplies the *protocol* the paper's software baselines use:

      * lower levels: members fetch-add the group counter at their master
        and spin-poll the group flag over the NoC; the master local-polls
        its counter and escalates to the next level when the group is in;
      * top level: all participants (incl. the master) fetch-add at the top
        master; the last arriver writes the release flag, everyone else
        spin-polls it; release then cascades down through the group flags.

    ``NaiveBarrier`` (star topology), ``XYBarrier`` (row/column 2-level
    tree) and ``tree_amo_barrier`` (full H-tree, SynCron-style) are just IR
    instances of this executor — one protocol, many topologies.
    """

    def __init__(self, prog: schedule_ir.Program,
                 p: SimParams = DEFAULT_PARAMS):
        rows, cols = schedule_ir.as_2d(prog.shape)
        super().__init__(rows, cols, p)
        self.prog = prog
        # bottom-up levels from the IR's reduce (gather) steps
        self.levels: List[Dict[Coord, List[Coord]]] = []
        for step in prog.steps:
            if not step.transfers or not all(t.reduce for t in step.transfers):
                continue  # broadcast mirror steps: release is protocol-implied
            groups: Dict[Coord, List[Coord]] = defaultdict(list)
            for t in step.transfers:
                groups[self._coord(t.dst)].append(self._coord(t.src))
            self.levels.append(dict(groups))
        if not self.levels:
            raise ValueError(f"{prog.name!r} has no gather steps")
        self._member_master: List[Dict[Coord, Coord]] = [
            {m: master for master, ms in lvl.items() for m in ms}
            for lvl in self.levels
        ]

    def _coord(self, rank: int) -> Coord:
        return divmod(rank, self.cols)

    def _entry_level(self, tile: Coord) -> Optional[int]:
        for lvl, groups in enumerate(self.levels):
            if tile in groups or tile in self._member_master[lvl]:
                return lvl
        return None

    def run(self, requests: Optional[Dict[Coord, int]] = None) -> int:
        tiles = self.tiles()
        requests = requests or {t: 0 for t in tiles}
        p = self.p
        top = len(self.levels) - 1

        def addr(kind: str, lvl: int) -> str:
            return f"{kind}{lvl}"

        def poll_remote(x: Coord, at: Coord, a: str,
                        on_set: Callable[[int], None], t: int) -> None:
            def on_rd(tt: int, v: int) -> None:
                if v:
                    on_set(tt)
                else:
                    self.sim.at(tt + p.sw_poll,
                                lambda t2: poll_remote(x, at, a, on_set, t2))
            self.amo_op(t, x, at, "read", a, 0, on_rd)

        def descend(x: Coord, lvl: int, t: int) -> None:
            """x released at level lvl+1: publish its own group flags down."""
            if lvl < 0 or x not in self.levels[lvl]:
                self.finish[x] = t + p.sw_post
                return

            def on_wr(tt: int, _o: int) -> None:
                descend(x, lvl - 1, tt)
            self.amo_op(t + p.sw_between, x, x, "write", addr("flag", lvl),
                        1, on_wr)

        def arrive(x: Coord, lvl: int, t: int) -> None:
            pre = p.sw_pre if lvl == 0 else p.sw_between
            if lvl == top:
                (master, members), = self.levels[lvl].items()
                target = len(members) + 1  # master fetch-adds too

                def on_count(tt: int, old: int) -> None:
                    if old == target - 1:    # last arriver: release everyone
                        def on_release(td: int, _o: int) -> None:
                            descend(x, lvl - 1, td)
                        self.amo_op(tt + p.sw_between, x, master, "write",
                                    addr("flag", lvl), 1, on_release)
                    else:
                        self.sim.at(tt + p.sw_between,
                                    lambda t2: poll_remote(
                                        x, master, addr("flag", lvl),
                                        lambda td: descend(x, lvl - 1, td),
                                        t2))
                self.amo_op(t + pre, x, master, "fetch_add",
                            addr("cnt", lvl), 1, on_count)
            elif x in self.levels[lvl]:
                # group master: spin-poll the LOCAL counter, then escalate
                members = self.levels[lvl][x]

                def wait_group(tt: int) -> None:
                    def on_rd(td: int, v: int) -> None:
                        if v == len(members):
                            arrive(x, lvl + 1, td)
                        else:
                            self.sim.at(td + p.sw_poll, wait_group)
                    self.amo_op(tt, x, x, "read", addr("cnt", lvl), 0, on_rd)
                self.sim.at(t + pre, wait_group)
            else:
                # member: fetch-add at the master, then poll the group flag
                master = self._member_master[lvl][x]

                def on_count(tt: int, _old: int) -> None:
                    self.sim.at(tt + p.sw_between,
                                lambda t2: poll_remote(
                                    x, master, addr("flag", lvl),
                                    lambda td: descend(x, lvl - 1, td), t2))
                self.amo_op(t + pre, x, master, "fetch_add",
                            addr("cnt", lvl), 1, on_count)

        for tile, r in requests.items():
            lvl = self._entry_level(tile)
            if lvl is None:     # world of 1: nothing to synchronize
                self.finish[tile] = r
                continue
            self.sim.at(r, lambda t, tile=tile, lvl=lvl: arrive(tile, lvl, t))
        self.sim.run()
        return self.overhead(requests)


class NaiveBarrier(HierarchicalAMOBarrier):
    """Single master tile accepts requests and dispatches responses (§4.1):
    the star-topology instance of the generic AMO executor."""

    def __init__(self, rows: int, cols: int, p: SimParams = DEFAULT_PARAMS):
        super().__init__(schedule_ir.naive_barrier((rows, cols)), p)

    def run(self, requests: Optional[Dict[Coord, int]] = None,
            master: Coord = (0, 0)) -> int:
        if master != (0, 0):
            root = master[0] * self.cols + master[1]
            world = self.rows * self.cols
            gather = schedule_ir.Step(tuple(
                schedule_ir.Transfer(r, root, (0,), reduce=True)
                for r in range(world) if r != root), level=1)
            prog = schedule_ir.Program("naive_barrier",
                                       (self.rows, self.cols), 1, (gather,),
                                       kind=schedule_ir.BARRIER)
            HierarchicalAMOBarrier.__init__(self, prog, self.p)
        return super().run(requests)


class XYBarrier(HierarchicalAMOBarrier):
    """Two 1D phases: rows barrier on row-masters (col 0), then row-masters
    barrier on the global master (0,0); release cascades back (§4.1): the
    two-level-tree instance of the generic AMO executor."""

    def __init__(self, rows: int, cols: int, p: SimParams = DEFAULT_PARAMS):
        super().__init__(schedule_ir.xy_barrier((rows, cols)), p)


def tree_amo_barrier(shape: Tuple[int, ...],
                     p: SimParams = DEFAULT_PARAMS) -> HierarchicalAMOBarrier:
    """Beyond-paper software baseline: the H-tree topology run with AMO
    counters/flags instead of dedicated FS modules (SynCron-style
    hierarchical synchronization) — log-depth, but each level pays the
    full software counter/poll protocol."""
    return HierarchicalAMOBarrier(schedule_ir.tree_barrier(shape), p)


# ---------------------------------------------------------------------------
# FractalSync event model (dedicated H-tree network, §3)
# ---------------------------------------------------------------------------


class FractalSyncSim:
    """Event-driven model of the FS tree with arbitrary arrival skew.

    Up-edge into a level-l module costs 1 cycle (FSM) plus, if pipelined, the
    level's pipeline registers; the down (wake) path mirrors it; +2 cycles for
    request sampling and wake detection at the tile.  With aligned arrivals
    this equals ``FractalTree.fsync_latency`` (Table 1 exactly).
    """

    def __init__(self, tree: FractalTree, pipelined: bool = False):
        self.tree = tree
        self.pipelined = pipelined

    def run(self, requests: Optional[Dict[tuple, int]] = None,
            level: Optional[int] = None) -> Tuple[int, Dict[tuple, int]]:
        tree = self.tree
        level = tree.num_levels if level is None else level
        tiles = list(tree.tiles())
        requests = requests or {t: 0 for t in tiles}

        # Upward sweep: module at (lvl, key) fires at max(children)+cost(lvl).
        fire_time: Dict[tuple, int] = {}
        arrive: Dict[tuple, int] = {("tile", t): requests[t] + 1 for t in tiles}
        groups: Dict[tuple, List[int]] = defaultdict(list)
        for t in tiles:
            groups[tree.domain_key(t, 1)].append(arrive[("tile", t)])
        prev = {k: v for k, v in groups.items()}
        for lvl in range(1, level + 1):
            spec = tree.level(lvl)
            cost = 1 + (spec.pipeline_regs if self.pipelined else 0)
            nxt: Dict[tuple, List[int]] = defaultdict(list)
            fired: Dict[tuple, int] = {}
            for key, times in prev.items():
                fired[key] = max(times) + cost
            fire_time.update({(lvl, k): v for k, v in fired.items()})
            if lvl < level:
                for t in tiles:
                    k_here = tree.domain_key(t, lvl)
                    k_up = tree.domain_key(t, lvl + 1)
                    nxt[k_up].append(fired[k_here])
                # dedupe: each module reports once, not once per tile
                prev = {k: sorted(set(v)) for k, v in nxt.items()}

        # Downward sweep: wake propagates back through the same edges.
        down_cost = sum(
            1 + (tree.level(l).pipeline_regs if self.pipelined else 0)
            for l in range(1, level + 1)
        )
        finish: Dict[tuple, int] = {}
        for t in tiles:
            root_key = tree.domain_key(t, level)
            finish[t] = fire_time[(level, root_key)] + down_cost + 1

        overhead = max(finish.values()) - max(requests.values())
        return overhead, finish


# ---------------------------------------------------------------------------
# Generic NoC replay of any Schedule IR program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NoCReplay:
    """Result of replaying an IR program on the contended mesh NoC."""

    overhead: int                  # Ŝ = max(F) − max(R), cycles
    finish: Dict[int, int]         # per flat rank
    total_msgs: int
    total_hops: int

    def __float__(self) -> float:
        return float(self.overhead)


@dataclass(frozen=True)
class PipelineReplay:
    """Result of replaying a *sequence* of bucket programs on one NoC.

    ``program_finish[i]`` is the cycle at which the last rank completed
    program i — the simulated analogue of ``OverlapTimeline.comm_end_s``,
    with link contention between in-flight buckets included.
    """

    overhead: int                  # max(F) − max(R) across the whole pipeline
    finish: Dict[int, int]         # per flat rank, after the last program
    program_finish: Tuple[int, ...]
    total_msgs: int
    total_hops: int

    def __float__(self) -> float:
        return float(self.overhead)


def pipelined_on_noc(progs: Sequence[schedule_ir.Program],
                     params: SimParams = DEFAULT_PARAMS,
                     payload_flits: Optional[Sequence[int]] = None,
                     ready: Optional[Sequence[int]] = None,
                     requests: Optional[Dict[int, int]] = None
                     ) -> PipelineReplay:
    """Replay a pipeline of IR programs (superstep buckets) on a shared NoC.

    Each rank advances through the concatenated step sequence BSP-style:
    entering a step it issues its messages (size ∝ chunk fraction of that
    program's ``payload_flits``), then waits for every message addressed to
    it in that step before advancing.  A rank may not enter program i before
    cycle ``ready[i]`` (gradient-readiness during backward) — but ranks
    progress *independently*, so bucket i+1's messages from fast ranks
    contend on the NoC with bucket i's stragglers: the overlap-aware mode
    the cost model approximates analytically, simulated with real link
    contention.
    """
    if not progs:
        raise ValueError("need at least one program")
    shape = progs[0].shape
    if any(p.shape != shape for p in progs):
        raise ValueError("all pipelined programs must share one mesh shape")
    flits = list(payload_flits) if payload_flits is not None \
        else [1] * len(progs)
    ready = list(ready) if ready is not None else [0] * len(progs)
    if not (len(progs) == len(flits) == len(ready)):
        raise ValueError("progs, payload_flits, ready must align")

    rows, cols = schedule_ir.as_2d(shape)
    world = progs[0].world
    requests = requests or {r: 0 for r in range(world)}
    sim = EventSim()
    noc = NoC(sim, rows, cols, params)
    p = params
    coord = lambda r: divmod(r, cols)  # noqa: E731

    # concatenate the programs' steps; remember which program owns each step
    steps: List[Tuple[int, schedule_ir.Step]] = []
    start_step = []            # first combined-step index of each program
    for i, prog in enumerate(progs):
        start_step.append(len(steps))
        steps.extend((i, st) for st in prog.steps)
    n_steps = len(steps)
    boundary = {s: i for i, s in enumerate(start_step)}   # step → program
    last_of = {start_step[i + 1] - 1: i for i in range(len(progs) - 1)}
    if n_steps:
        last_of[n_steps - 1] = len(progs) - 1

    sends: List[List[List[schedule_ir.Transfer]]] = [
        [[] for _ in range(n_steps)] for _ in range(world)]
    expected = [[0] * n_steps for _ in range(world)]
    for s, (_, step) in enumerate(steps):
        for t in step.transfers:
            sends[t.src][s].append(t)
            expected[t.dst][s] += 1

    got = [[0] * n_steps for _ in range(world)]
    arr_t = [[0] * n_steps for _ in range(world)]
    entered = [[None] * n_steps for _ in range(world)]
    advanced = [[False] * n_steps for _ in range(world)]
    finish: Dict[int, int] = {}
    prog_finish = [0] * len(progs)

    def flits_of(s: int, tr: schedule_ir.Transfer) -> int:
        i = steps[s][0]
        return max(1, round(len(tr.chunks) / progs[i].n_chunks * flits[i]))

    def try_advance(r: int, s: int) -> None:
        if entered[r][s] is None or got[r][s] < expected[r][s] \
                or advanced[r][s]:
            return
        advanced[r][s] = True
        # bounce through the event queue: long runs of pass-through steps
        # (e.g. a naive rank waiting its serial turn) must not recurse
        done = max(entered[r][s], arr_t[r][s], sim.now)
        if s in last_of:
            prog_finish[last_of[s]] = max(prog_finish[last_of[s]], done)
        sim.at(done, lambda tt, r=r, s=s: enter(r, s + 1, tt))

    def enter(r: int, s: int, t: int) -> None:
        if s == n_steps:
            finish[r] = t + p.sw_post
            return
        if s in boundary:      # bucket i's grads not ready before ready[i]
            t = max(t, ready[boundary[s]])
        # software issue overhead only where the rank actually acts; idle
        # pass-through steps (e.g. a naive rank waiting its serial turn)
        # cost nothing — the rank is simply parked on its receive
        t_issue = t + ((p.sw_pre if s == 0 else p.sw_between)
                       if sends[r][s] else 0)
        for tr in sends[r][s]:
            def deliver(tt: int, tr=tr, s=s) -> None:
                d = tr.dst
                got[d][s] += 1
                arr_t[d][s] = max(arr_t[d][s], tt)
                try_advance(d, s)
            sim.at(t_issue,
                   lambda tt, tr=tr, s=s, deliver=deliver: noc.send(
                       tt, coord(tr.src), coord(tr.dst), deliver,
                       flits=flits_of(s, tr)))
        entered[r][s] = t_issue
        try_advance(r, s)

    for r, t0 in requests.items():
        sim.at(t0, lambda t, r=r: enter(r, 0, t))
    max_flits = max([1, *flits])
    horizon = max(200_000, 1000 * (n_steps + 1) * max_flits,
                  2 * max([0, *ready]) + 1000 * (n_steps + 1) * max_flits)
    sim.run(horizon=horizon,
            max_events=5_000_000 + 200 * world * max(1, n_steps))
    overhead = max(finish.values()) - max(requests.values())
    return PipelineReplay(overhead=overhead, finish=finish,
                          program_finish=tuple(prog_finish),
                          total_msgs=noc.total_msgs,
                          total_hops=noc.total_hops)


def schedule_on_noc(prog: schedule_ir.Program,
                    params: SimParams = DEFAULT_PARAMS,
                    payload_flits: int = 1,
                    requests: Optional[Dict[int, int]] = None) -> NoCReplay:
    """Replay one Schedule IR program on the XY-routed contended mesh.

    The single-program view of ``pipelined_on_noc``: per-rank progress is
    asynchronous but data dependencies are honored, giving *simulated*
    latency (link contention included) for every software schedule, not
    just the two AMO baselines the paper measures.
    """
    out = pipelined_on_noc([prog], params, [payload_flits], [0], requests)
    return NoCReplay(overhead=out.overhead, finish=out.finish,
                     total_msgs=out.total_msgs, total_hops=out.total_hops)


def software_schedule_latency(schedule: str, shape: Tuple[int, ...],
                              params: SimParams = DEFAULT_PARAMS,
                              payload_flits: int = 1) -> int:
    """Simulated NoC latency of a *software all-reduce schedule* (cycles)."""
    prog = schedule_ir.build_program(schedule, tuple(shape))
    return schedule_on_noc(prog, params, payload_flits).overhead


# ---------------------------------------------------------------------------
# Table 1 driver
# ---------------------------------------------------------------------------

PAPER_TABLE1 = {
    # mesh: (FSync, FSync+P, Naive, XY, speedup "FSync+P vs best AMO")
    "Neighbor": (4, 4, 79, 79, 19),
    "2x2": (6, 6, 119, 219, 19),
    "4x4": (10, 10, 512, 347, 34),
    "8x8": (14, 18, 2488, 614, 34),
    "16x16": (18, 34, 13961, 1462, 43),
}


def _mesh_of(name: str) -> Tuple[int, int]:
    if name == "Neighbor":
        return (1, 2)
    k = int(name.split("x")[0])
    return (k, k)


def simulate_config(name: str, params: SimParams = DEFAULT_PARAMS
                    ) -> Dict[str, float]:
    rows, cols = _mesh_of(name)
    tree = FractalTree((rows, cols))
    fsync = tree.fsync_latency()
    fsync_p = tree.fsync_latency(pipelined=True)
    naive = NaiveBarrier(rows, cols, params).run()
    # Paper reports identical Neighbor numbers for Naive and XY (2 tiles: XY
    # degenerates to the centralized scheme).
    xy = naive if rows * cols == 2 else XYBarrier(rows, cols, params).run()
    best_amo = min(naive, xy)
    return {
        "fsync": fsync,
        "fsync_p": fsync_p,
        "naive": naive,
        "xy": xy,
        "best_amo": best_amo,
        "speedup": best_amo / fsync_p,
    }


def table1(params: SimParams = DEFAULT_PARAMS,
           configs: Sequence[str] = tuple(PAPER_TABLE1)) -> Dict[str, Dict[str, float]]:
    return {name: simulate_config(name, params) for name in configs}


def scaling_sweep(ks: Sequence[int] = (2, 4, 8, 16, 32, 64),
                  params: SimParams = DEFAULT_PARAMS,
                  max_amo_k: int = 16) -> Dict[str, Dict[str, float]]:
    """Beyond-paper: extend the sweep past 16×16. AMO sims above ``max_amo_k``
    are skipped (quadratic event counts); FSync columns are analytic."""
    out: Dict[str, Dict[str, float]] = {}
    for k in ks:
        name = f"{k}x{k}"
        tree = FractalTree((k, k))
        row: Dict[str, float] = {
            "fsync": tree.fsync_latency(),
            "fsync_p": tree.fsync_latency(pipelined=True),
        }
        if k <= max_amo_k:
            row.update(
                naive=NaiveBarrier(k, k, params).run(),
                xy=XYBarrier(k, k, params).run(),
            )
            row["speedup"] = min(row["naive"], row["xy"]) / row["fsync_p"]
        out[name] = row
    return out
