"""fsync(level): programmable synchronization domains (paper §3.2).

The paper extends the tile ISA with a single instruction, ``fsync(level)``:
synchronize with every PE under the level-``level`` node of the synchronization
tree.  Disjoint subtrees (synchronization domains) proceed independently; a
level mismatch between neighbors raises the FS module's *error* signal.

JAX mapping:

  * ``SyncDomainMesh`` wraps a ``jax.sharding.Mesh`` plus a ``FractalTree``
    over its synchronization axes (the data-parallel axes; the "model" axis is
    inside a BSP rank).  It resolves a *level* to the tuple of mesh sub-axes
    that participate.
  * ``fsync(level)`` inside ``shard_map``: a recursive-doubling token barrier
    over the domain (``collectives.fractal_barrier``).  The returned token ==
    domain size; downstream ops data-depend on it via ``barrier_tie``.
  * Level-mismatch detection is a host-side check: ``SyncScope`` records the
    level each superstep requests per domain and raises ``FSyncError`` on
    conflicting concurrent levels (the paper's *error* wire).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .collectives import fractal_barrier
from .tree import FractalTree


class FSyncError(RuntimeError):
    """Synchronization-level mismatch (paper: the FS module's *error* signal)."""


@dataclass(frozen=True)
class SyncDomainMesh:
    """A device mesh with an H-tree synchronization hierarchy over its
    data-parallel axes.

    ``sync_axes`` are mesh axis names ordered outermost-first (e.g.
    ``("pod", "data")``); the flattened product forms the tree's leaves with
    the innermost axis merging first (neighbors first, pods last).
    """

    mesh: jax.sharding.Mesh
    sync_axes: Tuple[str, ...]

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(self.mesh.shape[a] for a in self.sync_axes)

    @property
    def world(self) -> int:
        return math.prod(self.sizes)

    @property
    def tree(self) -> FractalTree:
        return FractalTree(self.sizes)

    @property
    def num_levels(self) -> int:
        return self.tree.num_levels

    def domain_size(self, level: Optional[int] = None) -> int:
        level = self.num_levels if level is None else level
        return 1 << level

    def fsync(self, level: Optional[int] = None, token=None) -> jax.Array:
        """Issue the barrier (must run inside shard_map over ``sync_axes``).

        Returns the sync token (== domain size, asserted in tests)."""
        return fractal_barrier(self.sync_axes, self.sizes, level=level,
                               token=token)


def barrier_tie(x: jax.Array, token: jax.Array) -> jax.Array:
    """Make ``x`` data-depend on a barrier token without changing its value.

    ``optimization_barrier`` stops XLA from sinking work across the BSP
    superstep boundary (the compiled analogue of 'wake gates the next
    instruction')."""
    x, _ = jax.lax.optimization_barrier((x, token))
    return x


@dataclass
class SyncScope:
    """Host-side bookkeeping of concurrently-active fsync levels.

    The paper's FS module flags an *error* when its two slave ports request
    different levels.  In SPMD JAX a single program cannot diverge, but a
    *runtime* composing per-domain programs can: this scope performs the
    equivalent check when supersteps are scheduled (see runtime/trainer.py).
    """

    mesh: SyncDomainMesh
    active: Dict[Tuple[int, ...], int] = field(default_factory=dict)

    def request(self, domain_key: Tuple[int, ...], level: int) -> None:
        tree = self.mesh.tree
        if not 0 <= level <= tree.num_levels:
            raise FSyncError(f"level {level} outside 0..{tree.num_levels}")
        for other_key, other_level in self.active.items():
            # two concurrent requests conflict if one domain contains the
            # other but the levels disagree (mismatched subtree roots)
            lo, hi = sorted((level, other_level))
            a, b = (domain_key, other_key) if level <= other_level \
                else (other_key, domain_key)
            # project the smaller domain's key up to the larger level
            if _project(self.mesh.tree, a, hi) == b and lo != hi:
                raise FSyncError(
                    f"fsync level mismatch: domain {domain_key} at level "
                    f"{level} vs domain {other_key} at level {other_level}")
        self.active[domain_key] = level

    def complete(self, domain_key: Tuple[int, ...]) -> None:
        self.active.pop(domain_key, None)


def _project(tree: FractalTree, key: Tuple[int, ...], level: int) -> Tuple[int, ...]:
    return tree.domain_key(key, level)
