"""Runtime schedule autotuner: pick the best schedule per workload.

The ROADMAP north-star is "add schedules and pick the fastest one per
workload"; with every schedule now a Schedule IR program, selection is a
query, not a code path:

  1. **Cost-model ranking** — price every registered IR builder for the
     concrete ``(mesh shape, payload bytes, link parameters)`` with
     ``cost_model.program_cost`` (mesh-contention mode by default: that is
     what separates the latency-optimal butterfly from the
     bandwidth-optimal ring);
  2. **Optional measured refinement** — time the top-k candidates with a
     caller-supplied ``measure(schedule) → seconds`` (e.g. the jitted
     lowering on real devices; see ``benchmarks/schedule_matrix.py``) and
     let measurement override the model where they disagree.

Wired through ``BSPConfig(schedule="auto")`` → ``bsp.sync_gradients`` /
``runtime.trainer.make_bsp_train_step``: the trainer resolves "auto" once
at build time from the flat gradient size and logs the choice.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, List, Optional, Sequence, Tuple

from . import cost_model, schedule_ir
from .cost_model import LinkParams, TPU_V5E_ICI


@dataclass(frozen=True)
class TuneResult:
    """Outcome of one autotuning query."""

    schedule: str                              # the winner
    shape: Tuple[int, ...]
    payload_bytes: float
    ranking: Tuple[Tuple[str, float], ...]     # (schedule, predicted s) asc
    measured: Tuple[Tuple[str, float], ...] = ()   # (schedule, measured s)

    @property
    def predicted_s(self) -> float:
        return dict(self.ranking)[self.schedule]


@dataclass(frozen=True)
class BucketPolicy:
    """One bucket's tuned (schedule, codec) pick and its predicted price."""

    schedule: str
    codec: str = "none"
    predicted_s: float = 0.0


# How a codec changes a bucket's wire price: wire-bytes ratio vs f32, and
# encode/decode overhead charged as extra launch latencies per program step
# (quant/dequant kernels bracket every exchange).  The ratio shrinks the β
# term only, so small latency-bound buckets never win from compression — the
# per-bucket policy the ROADMAP asks for falls out of the pricing.
CODEC_WIRE_RATIO = {"none": 1.0, "bf16": 0.5, "int8": (1.0 + 4.0 / 128) / 4.0}
CODEC_STEP_ALPHAS = {"none": 0.0, "bf16": 1.0, "int8": 2.0}
# With the codec-fused tree_reduce/decode_add kernels (dequant folded into
# the receive-side accumulate, one launch instead of dequant-then-add) each
# exchange pays one launch fewer: bf16's encode is a cast XLA fuses into the
# send slice (decode side free → 0.5 total), int8 still pays its quant
# kernel but the dequant launch disappears (2.0 → 1.0).
CODEC_STEP_ALPHAS_FUSED = {"none": 0.0, "bf16": 0.5, "int8": 1.0}
CODECS = tuple(CODEC_WIRE_RATIO)


def codec_step_alphas() -> dict:
    """Per-step codec launch-overhead table for THIS install: the fused
    table when the Pallas kernels actually dispatch (collectives then route
    receive hops through ``kernels.tree_reduce.ops.decode_add``), the
    unfused one on reference installs.  Every codec-pricing consumer
    (``rank_policies``, ``SuperstepEngine.timeline``) reads this resolver so
    the calibrated tuner re-prices automatically when fusion is available.
    """
    from repro.kernels import kernels_backend
    return (CODEC_STEP_ALPHAS_FUSED if kernels_backend() == "pallas"
            else CODEC_STEP_ALPHAS)


@lru_cache(maxsize=512)
def _candidates(shape: Tuple[int, ...],
                schedules: Optional[Tuple[str, ...]]) -> Tuple[str, ...]:
    names = list(schedules) if schedules else list(schedule_ir.SCHEDULES)
    world = math.prod(shape)
    pow2 = world >= 1 and (world & (world - 1)) == 0
    if not pow2:
        # tree-structured schedules need a power-of-two world
        names = [n for n in names if n in ("ring", "xy", "naive")]
    if not names:
        raise ValueError(
            f"no schedule among {schedules} can run on shape {tuple(shape)}")
    return tuple(names)


@lru_cache(maxsize=8192)
def _rank_banded(shape: Tuple[int, ...], band: int, link: LinkParams,
                 outer_link: Optional[LinkParams],
                 schedules: Optional[Tuple[str, ...]],
                 mesh_contention: bool) -> Tuple[Tuple[str, float], ...]:
    """Ranking memoized per (shape, payload-band, links, candidates): engine
    builds and the DP bucket search stop re-pricing identical candidates."""
    names = _candidates(shape, schedules)
    if math.prod(shape) == 1:
        # nothing to communicate: every schedule is a no-op, don't build IR
        return ((names[0], 0.0),)
    payload = cost_model.band_payload(band)
    out = []
    for name in names:
        prog = schedule_ir.build_program(name, shape)
        cost = cost_model.program_cost(prog, payload, link,
                                       outer_link=outer_link,
                                       mesh_contention=mesh_contention)
        out.append((name, cost))
    out.sort(key=lambda kv: kv[1])
    return tuple(out)


def rank_schedules(shape: Sequence[int], payload_bytes: float,
                   link: LinkParams = TPU_V5E_ICI,
                   outer_link: Optional[LinkParams] = None,
                   schedules: Optional[Sequence[str]] = None,
                   mesh_contention: bool = True
                   ) -> List[Tuple[str, float]]:
    """All candidate schedules priced for this workload, cheapest first.

    Prices are evaluated at the payload's quarter-octave band center
    (``cost_model.payload_band``) so repeated queries for near-identical
    payloads — every engine build, every DP segment — hit one cache line.
    Pass a fitted ``link`` (``core.calibrate.fit_link_params``) to rank with
    measured platform parameters instead of the analytic defaults.
    """
    sched_key = tuple(schedules) if schedules is not None else None
    return list(_rank_banded(tuple(shape),
                             cost_model.payload_band(payload_bytes),
                             link, outer_link, sched_key, mesh_contention))


def pick_schedule(shape: Sequence[int], payload_bytes: float,
                  link: LinkParams = TPU_V5E_ICI,
                  outer_link: Optional[LinkParams] = None,
                  schedules: Optional[Sequence[str]] = None,
                  mesh_contention: bool = True) -> str:
    """Cost-model-optimal schedule name for ``(shape, payload, link)``."""
    return rank_schedules(shape, payload_bytes, link, outer_link, schedules,
                          mesh_contention)[0][0]


def _zero1_adjust(ranking: Sequence[Tuple[str, float]]
                  ) -> List[Tuple[str, float]]:
    """Re-price a ranking for the ZeRO-1 trainer lowering: the fractal
    schedule reduce-scatters natively and its all-gather half doubles as the
    parameter publish, while every other schedule pays its full all-reduce
    PLUS the butterfly publish all-gather (half a fractal all-reduce) on
    top — without this, "auto" would pick ring for large buckets the
    trainer then runs ~50% slower than fractal."""
    costs = dict(ranking)
    if "fractal" not in costs:
        return list(ranking)
    publish = 0.5 * costs["fractal"]
    return sorted(((n, c if n == "fractal" else c + publish)
                   for n, c in costs.items()), key=lambda kv: kv[1])


def pick_bucket_schedules(shape: Sequence[int],
                          bucket_bytes: Sequence[float],
                          link: LinkParams = TPU_V5E_ICI,
                          outer_link: Optional[LinkParams] = None,
                          schedules: Optional[Sequence[str]] = None,
                          mesh_contention: bool = True,
                          zero1_publish: bool = False,
                          measure: Optional[
                              Callable[[str, float], float]] = None,
                          measure_budget: int = 0,
                          measure_top_k: int = 2,
                          baseline: Optional[Sequence[str]] = None
                          ) -> Tuple[str, ...]:
    """Cost-model-optimal schedule *per bucket* of a bucketed superstep.

    Bucket payloads straddle the butterfly↔ring crossover by construction:
    the reverse-layer partition makes late (embedding/head) buckets big and
    the last buckets small, so one global pick is wrong for somebody.  Since
    buckets serialize on the shared fabric in ready order, the fabric-
    occupancy-minimizing joint choice decomposes into independent per-bucket
    minima — each bucket just takes the cheapest program for its own bytes.

    ``zero1_publish=True`` prices the ZeRO-1 trainer lowering (see
    ``_zero1_adjust``).

    ``measure(schedule, payload_bytes) → seconds`` plus a positive
    ``measure_budget`` spends up to that many real timings refining the
    picks, priciest buckets first (they have the most to gain): for each
    refined bucket the top ``measure_top_k`` analytic candidates are timed
    and the measured winner overrides the model.  Measurements that raise
    or return non-finite values are skipped.

    ``baseline`` seeds the picks with a prior choice per bucket (e.g. the
    engine's codec-aware policy winners): unmeasured buckets keep their
    baseline pick untouched, and each measured bucket's baseline is always
    in its timed candidate set — refinement can only override a pick that
    something actually out-measured.
    """
    rankings = []
    for payload in bucket_bytes:
        ranking = rank_schedules(shape, payload, link, outer_link,
                                 schedules, mesh_contention)
        if zero1_publish:
            ranking = _zero1_adjust(ranking)
        rankings.append(ranking)
    if baseline is not None:
        if len(baseline) != len(bucket_bytes):
            raise ValueError("baseline must match bucket_bytes in length")
        names = list(baseline)
    else:
        names = [r[0][0] for r in rankings]

    if measure is not None and measure_budget > 0:
        budget = int(measure_budget)
        # priciest buckets first: a wrong pick there costs the most
        order = sorted(range(len(names)),
                       key=lambda i: -rankings[i][0][1])
        for i in order:
            if budget <= 0:
                break
            cands = [n for n, _cost in rankings[i][:measure_top_k]]
            # the incumbent is timed FIRST: if the budget dies mid-bucket,
            # a challenger can never evict a pick it was not measured
            # against
            if names[i] in cands:
                cands.remove(names[i])
            cands.insert(0, names[i])
            timed: List[Tuple[str, float]] = []
            for name in cands:
                if budget <= 0:
                    break
                budget -= 1
                try:
                    t = float(measure(name, bucket_bytes[i]))
                except Exception:
                    continue
                if math.isfinite(t):
                    timed.append((name, t))
            if timed:
                names[i] = min(timed, key=lambda kv: kv[1])[0]
    return tuple(names)


def rank_policies(shape: Sequence[int], payload_bytes: float,
                  link: LinkParams = TPU_V5E_ICI,
                  outer_link: Optional[LinkParams] = None,
                  schedules: Optional[Sequence[str]] = None,
                  codecs: Sequence[str] = CODECS,
                  mesh_contention: bool = True,
                  zero1_publish: bool = False) -> List[BucketPolicy]:
    """All (schedule, codec) policies priced for one payload, cheapest first.

    Codecs ride the fractal schedule's point-to-point exchanges (that is the
    only lowering with wire compression), shrinking the bandwidth term by
    ``CODEC_WIRE_RATIO`` while paying ``codec_step_alphas()`` extra launch
    latencies per step for the quant/dequant kernels (the fused table when
    the codec-fused tree_reduce kernels dispatch).  Under
    ``zero1_publish`` only the reduce-scatter half compresses — the
    all-gather half publishes full-precision parameters.
    """
    shape = tuple(shape)
    ranking = rank_schedules(shape, payload_bytes, link, outer_link,
                             schedules, mesh_contention)
    if zero1_publish:
        ranking = _zero1_adjust(ranking)
    out = [BucketPolicy(n, "none", c) for n, c in ranking]
    if "fractal" in dict(ranking) and math.prod(shape) > 1:
        prog = schedule_ir.build_program("fractal", shape)
        base = dict(ranking)["fractal"]
        alphas = codec_step_alphas()
        for codec in codecs:
            if codec == "none":
                continue
            wire = cost_model.program_cost_banded(
                prog, payload_bytes * CODEC_WIRE_RATIO[codec], link,
                outer_link, mesh_contention)
            overhead = alphas[codec] * link.alpha_s * prog.num_steps
            if zero1_publish:
                # only the reduce-scatter half carries the codec — both
                # the wire saving AND the quant launches halve
                cost = 0.5 * base + 0.5 * wire + 0.5 * overhead
            else:
                cost = wire + overhead
            out.append(BucketPolicy("fractal", codec, cost))
    out.sort(key=lambda p: p.predicted_s)
    return out


def pick_bucket_policies(shape: Sequence[int],
                         bucket_bytes: Sequence[float],
                         link: LinkParams = TPU_V5E_ICI,
                         outer_link: Optional[LinkParams] = None,
                         schedules: Optional[Sequence[str]] = None,
                         codecs: Sequence[str] = CODECS,
                         mesh_contention: bool = True,
                         zero1_publish: bool = False
                         ) -> Tuple[BucketPolicy, ...]:
    """Joint (schedule, codec) pick per bucket: large early buckets compress
    harder (the β saving dwarfs the quant overhead), small latency-bound
    tail buckets skip compression — the per-bucket policy priced through
    the same (optionally calibrated) cost model as the schedule picks."""
    return tuple(rank_policies(shape, b, link, outer_link, schedules,
                               codecs, mesh_contention, zero1_publish)[0]
                 for b in bucket_bytes)


def autotune(shape: Sequence[int], payload_bytes: float,
             link: LinkParams = TPU_V5E_ICI,
             outer_link: Optional[LinkParams] = None,
             schedules: Optional[Sequence[str]] = None,
             measure: Optional[Callable[[str], float]] = None,
             measure_top_k: int = 3,
             mesh_contention: bool = True) -> TuneResult:
    """Rank by cost model; optionally refine the top-k with measurements.

    ``measure(schedule)`` returns observed seconds (or raises / returns
    ``inf`` for schedules that fail to run — they are skipped).
    """
    shape = tuple(shape)
    ranking = tuple(rank_schedules(shape, payload_bytes, link, outer_link,
                                   schedules, mesh_contention))
    winner = ranking[0][0]
    measured: List[Tuple[str, float]] = []
    if measure is not None:
        for name, _cost in ranking[:measure_top_k]:
            try:
                t = float(measure(name))
            except Exception:
                continue
            if math.isfinite(t):
                measured.append((name, t))
        if measured:
            measured.sort(key=lambda kv: kv[1])
            winner = measured[0][0]
    return TuneResult(schedule=winner, shape=shape,
                      payload_bytes=payload_bytes, ranking=ranking,
                      measured=tuple(measured))
