"""Runtime schedule autotuner: pick the best schedule per workload.

The ROADMAP north-star is "add schedules and pick the fastest one per
workload"; with every schedule now a Schedule IR program, selection is a
query, not a code path:

  1. **Cost-model ranking** — price every registered IR builder for the
     concrete ``(mesh shape, payload bytes, link parameters)`` with
     ``cost_model.program_cost`` (mesh-contention mode by default: that is
     what separates the latency-optimal butterfly from the
     bandwidth-optimal ring);
  2. **Optional measured refinement** — time the top-k candidates with a
     caller-supplied ``measure(schedule) → seconds`` (e.g. the jitted
     lowering on real devices; see ``benchmarks/schedule_matrix.py``) and
     let measurement override the model where they disagree.

Wired through ``BSPConfig(schedule="auto")`` → ``bsp.sync_gradients`` /
``runtime.trainer.make_bsp_train_step``: the trainer resolves "auto" once
at build time from the flat gradient size and logs the choice.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from . import cost_model, schedule_ir
from .cost_model import LinkParams, TPU_V5E_ICI


@dataclass(frozen=True)
class TuneResult:
    """Outcome of one autotuning query."""

    schedule: str                              # the winner
    shape: Tuple[int, ...]
    payload_bytes: float
    ranking: Tuple[Tuple[str, float], ...]     # (schedule, predicted s) asc
    measured: Tuple[Tuple[str, float], ...] = ()   # (schedule, measured s)

    @property
    def predicted_s(self) -> float:
        return dict(self.ranking)[self.schedule]


def _candidates(shape: Sequence[int],
                schedules: Optional[Sequence[str]]) -> List[str]:
    names = list(schedules) if schedules else list(schedule_ir.SCHEDULES)
    world = math.prod(shape)
    pow2 = world >= 1 and (world & (world - 1)) == 0
    if not pow2:
        # tree-structured schedules need a power-of-two world
        names = [n for n in names if n in ("ring", "xy", "naive")]
    if not names:
        raise ValueError(
            f"no schedule among {schedules} can run on shape {tuple(shape)}")
    return names


def rank_schedules(shape: Sequence[int], payload_bytes: float,
                   link: LinkParams = TPU_V5E_ICI,
                   outer_link: Optional[LinkParams] = None,
                   schedules: Optional[Sequence[str]] = None,
                   mesh_contention: bool = True
                   ) -> List[Tuple[str, float]]:
    """All candidate schedules priced for this workload, cheapest first."""
    shape = tuple(shape)
    names = _candidates(shape, schedules)
    if math.prod(shape) == 1:
        # nothing to communicate: every schedule is a no-op, don't build IR
        return [(names[0], 0.0)]
    out = []
    for name in names:
        prog = schedule_ir.build_program(name, shape)
        cost = cost_model.program_cost(prog, payload_bytes, link,
                                       outer_link=outer_link,
                                       mesh_contention=mesh_contention)
        out.append((name, cost))
    out.sort(key=lambda kv: kv[1])
    return out


def pick_schedule(shape: Sequence[int], payload_bytes: float,
                  link: LinkParams = TPU_V5E_ICI,
                  outer_link: Optional[LinkParams] = None,
                  schedules: Optional[Sequence[str]] = None,
                  mesh_contention: bool = True) -> str:
    """Cost-model-optimal schedule name for ``(shape, payload, link)``."""
    return rank_schedules(shape, payload_bytes, link, outer_link, schedules,
                          mesh_contention)[0][0]


def pick_bucket_schedules(shape: Sequence[int],
                          bucket_bytes: Sequence[float],
                          link: LinkParams = TPU_V5E_ICI,
                          outer_link: Optional[LinkParams] = None,
                          schedules: Optional[Sequence[str]] = None,
                          mesh_contention: bool = True,
                          zero1_publish: bool = False) -> Tuple[str, ...]:
    """Cost-model-optimal schedule *per bucket* of a bucketed superstep.

    Bucket payloads straddle the butterfly↔ring crossover by construction:
    the reverse-layer partition makes late (embedding/head) buckets big and
    the last buckets small, so one global pick is wrong for somebody.  Since
    buckets serialize on the shared fabric in ready order, the fabric-
    occupancy-minimizing joint choice decomposes into independent per-bucket
    minima — each bucket just takes the cheapest program for its own bytes.

    ``zero1_publish=True`` prices the ZeRO-1 trainer lowering rather than a
    bare all-reduce: the fractal schedule reduce-scatters natively and its
    all-gather half doubles as the parameter publish, while every other
    schedule pays its full all-reduce PLUS the butterfly publish all-gather
    (half a fractal all-reduce) on top — without this, "auto" would pick
    ring for large buckets the trainer then runs ~50% slower than fractal.
    """
    def pick(payload: float) -> str:
        ranking = rank_schedules(shape, payload, link, outer_link,
                                 schedules, mesh_contention)
        if zero1_publish:
            costs = dict(ranking)
            if "fractal" in costs:
                publish = 0.5 * costs["fractal"]
                ranking = sorted(
                    ((n, c if n == "fractal" else c + publish)
                     for n, c in costs.items()), key=lambda kv: kv[1])
        return ranking[0][0]

    return tuple(pick(b) for b in bucket_bytes)


def autotune(shape: Sequence[int], payload_bytes: float,
             link: LinkParams = TPU_V5E_ICI,
             outer_link: Optional[LinkParams] = None,
             schedules: Optional[Sequence[str]] = None,
             measure: Optional[Callable[[str], float]] = None,
             measure_top_k: int = 3,
             mesh_contention: bool = True) -> TuneResult:
    """Rank by cost model; optionally refine the top-k with measurements.

    ``measure(schedule)`` returns observed seconds (or raises / returns
    ``inf`` for schedules that fail to run — they are skipped).
    """
    shape = tuple(shape)
    ranking = tuple(rank_schedules(shape, payload_bytes, link, outer_link,
                                   schedules, mesh_contention))
    winner = ranking[0][0]
    measured: List[Tuple[str, float]] = []
    if measure is not None:
        for name, _cost in ranking[:measure_top_k]:
            try:
                t = float(measure(name))
            except Exception:
                continue
            if math.isfinite(t):
                measured.append((name, t))
        if measured:
            measured.sort(key=lambda kv: kv[1])
            winner = measured[0][0]
    return TuneResult(schedule=winner, shape=shape,
                      payload_bytes=payload_bytes, ranking=ranking,
                      measured=tuple(measured))
