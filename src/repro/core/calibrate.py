"""Calibrate the AMO-baseline simulator parameters against paper Table 1.

The FractalSync columns of Table 1 are parameter-free (exact from topology).
The Naïve/XY software-AMO baselines depend on micro-architectural constants the
paper does not publish (AMO service time, NoC per-hop latency, software loop
overheads).  We fit those by randomized search + coordinate descent against the
nine distinct published numbers:

    Naïve: 79 (Neighbor), 119 (2×2), 512 (4×4), 2488 (8×8), 13961 (16×16)
    XY:                    219 (2×2), 347 (4×4),  614 (8×8),  1462 (16×16)

Loss = mean squared log-ratio (scale-aware, symmetric).  The fitted parameters
are frozen into ``simulator.DEFAULT_PARAMS`` and the residuals are reported in
EXPERIMENTS.md §Table-1.

Run:  PYTHONPATH=src python -m repro.core.calibrate [--iters N]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import random
import sys

from .simulator import (DEFAULT_PARAMS, NaiveBarrier, PAPER_TABLE1,
                        SimBudgetExceeded, SimParams, XYBarrier, _mesh_of)

PENALTY = 1e6  # loss for configs that blow the simulation budget

TARGETS = []
for name, (_, _, naive, xy, _) in PAPER_TABLE1.items():
    TARGETS.append((name, "naive", naive))
    if name != "Neighbor":  # XY degenerates to Naive for 2 tiles
        TARGETS.append((name, "xy", xy))

SEARCH_SPACE = {
    "hop_latency": (1, 6),
    "link_occupancy": (1, 3),
    "inj_latency": (0, 5),
    "amo_service": (1, 24),
    "sw_pre": (0, 40),
    "sw_between": (0, 24),
    "sw_poll": (4, 40),   # ≥4: bounds poll-storm event counts
    "sw_post": (0, 16),
}


def evaluate(params: SimParams) -> tuple[float, dict]:
    sims = {}
    try:
        # cheap meshes first so pathological configs fail fast
        for name in sorted(PAPER_TABLE1, key=lambda n: _mesh_of(n)[0] *
                           _mesh_of(n)[1]):
            rows, cols = _mesh_of(name)
            sims[(name, "naive")] = NaiveBarrier(rows, cols, params).run()
            if name != "Neighbor":
                sims[(name, "xy")] = XYBarrier(rows, cols, params).run()
    except SimBudgetExceeded:
        return PENALTY, sims
    loss = 0.0
    for name, scheme, target in TARGETS:
        got = sims[(name, scheme)]
        loss += math.log(got / target) ** 2
    return loss / len(TARGETS), sims


def random_params(rng: random.Random) -> SimParams:
    return SimParams(**{k: rng.randint(lo, hi) for k, (lo, hi) in SEARCH_SPACE.items()})


def neighbors(p: SimParams, rng: random.Random, step: int = 1):
    for k, (lo, hi) in SEARCH_SPACE.items():
        v = getattr(p, k)
        for dv in (-step, step):
            nv = min(hi, max(lo, v + dv))
            if nv != v:
                yield dataclasses.replace(p, **{k: nv})


def search(iters: int = 200, seed: int = 0, start: SimParams | None = None):
    rng = random.Random(seed)
    best_p = start or DEFAULT_PARAMS
    best_loss, _ = evaluate(best_p)
    # Phase 1: random restarts
    for i in range(iters):
        p = random_params(rng)
        loss, _ = evaluate(p)
        if loss < best_loss:
            best_loss, best_p = loss, p
            print(f"[random {i}] loss={loss:.4f} {p}", flush=True)
    # Phase 2: coordinate descent from the best point
    improved = True
    while improved:
        improved = False
        for cand in neighbors(best_p, rng):
            loss, _ = evaluate(cand)
            if loss < best_loss - 1e-9:
                best_loss, best_p = loss, cand
                improved = True
                print(f"[descend] loss={loss:.4f} {cand}", flush=True)
    return best_p, best_loss


def report(params: SimParams) -> str:
    loss, sims = evaluate(params)
    lines = [f"params = {params}", f"mean sq log-ratio loss = {loss:.4f}", ""]
    lines.append(f"{'mesh':<9s} {'scheme':<6s} {'paper':>7s} {'sim':>7s} {'ratio':>6s}")
    for name, scheme, target in TARGETS:
        got = sims[(name, scheme)]
        lines.append(f"{name:<9s} {scheme:<6s} {target:>7d} {got:>7d} {got/target:>6.2f}")
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default="results/calibration.json")
    args = ap.parse_args(argv)
    best_p, best_loss = search(args.iters, args.seed)
    print(report(best_p))
    with open(args.out, "w") as f:
        json.dump({"params": dataclasses.asdict(best_p), "loss": best_loss}, f,
                  indent=2)


if __name__ == "__main__":
    main()
