"""Calibration: fit cost-model/simulator parameters from measurements.

Two calibration paths live here:

1. **Link-parameter fitting** (``fit_link_params``): time a small grid of
   real jitted collectives — (schedule × payload) on ≥8 host devices — and
   least-squares-fit ``cost_model.LinkParams`` (α launch latency, per-hop
   latency, β inverse-bandwidth).  ``cost_model.step_features`` makes every
   IR program's predicted cost LINEAR in those three parameters, so the fit
   is one ``lstsq`` over the measured grid.  The fitted params plug straight
   into ``autotune.rank_schedules`` / ``pick_bucket_schedules`` /
   ``superstep.SuperstepEngine`` (via ``BSPConfig(link=…)``), replacing the
   analytic TPU defaults with measured platform numbers — the tuner fits
   the platform, it does not assume it.

2. **AMO-baseline simulator fitting** (``search``): the FractalSync columns
   of Table 1 are parameter-free (exact from topology), but the Naïve/XY
   software-AMO baselines depend on micro-architectural constants the paper
   does not publish (AMO service time, NoC per-hop latency, software loop
   overheads).  We fit those by randomized search + coordinate descent
   against the nine distinct published numbers:

       Naïve: 79 (Neighbor), 119 (2×2), 512 (4×4), 2488 (8×8), 13961 (16×16)
       XY:                    219 (2×2), 347 (4×4),  614 (8×8),  1462 (16×16)

   Loss = mean squared log-ratio (scale-aware, symmetric).  The fitted
   parameters are frozen into ``simulator.DEFAULT_PARAMS``.

Run:  PYTHONPATH=src python -m repro.core.calibrate [--iters N]
      PYTHONPATH=src python -m repro.core.calibrate --links --devices 8
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import random
import sys
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from . import cost_model, schedule_ir
from .cost_model import LinkParams
from .simulator import (DEFAULT_PARAMS, NaiveBarrier, PAPER_TABLE1,
                        SimBudgetExceeded, SimParams, XYBarrier, _mesh_of)

# ---------------------------------------------------------------------------
# Path 1: measured link-parameter fitting (α, hop, β) for the cost model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkSample:
    """One measured collective: (schedule, mesh, per-rank payload) → s."""

    schedule: str
    shape: Tuple[int, ...]
    payload_bytes: float
    seconds: float


@dataclass(frozen=True)
class LinkFit:
    """Fitted link parameters plus the grid and residual behind them."""

    link: LinkParams
    samples: Tuple[LinkSample, ...]
    residual: float       # rms relative residual of the fit

    def describe(self) -> str:
        lk = self.link
        head = (f"fitted {lk.name}: alpha={lk.alpha_s:.3e}s "
                f"hop={lk.hop:.3e}s bw={lk.bw_Bps / 1e9:.2f}GB/s "
                f"rms-rel-residual={self.residual:.2f} "
                f"({len(self.samples)} samples)")
        rows = [f"  {s.schedule:<12s} {s.payload_bytes / 1e3:>9.1f}KB "
                f"{s.seconds * 1e6:>9.1f}us" for s in self.samples]
        return "\n".join([head] + rows)


# The measurement grid: schedules with distinct (steps, hops, bytes)
# signatures so the three-parameter fit is well-conditioned — the butterfly
# contributes multi-hop steps, the ring pure 1-hop bandwidth, the tree
# full-payload log-depth.
FIT_SCHEDULES = ("fractal", "ring", "tree")
FIT_PAYLOAD_ELEMS = (1 << 10, 1 << 14, 1 << 17, 1 << 20)   # per rank, f32


def fit_from_samples(samples: Sequence[LinkSample],
                     mesh_contention: bool = True,
                     name: str = "fitted") -> LinkFit:
    """Least-squares (α, hop, β) from measured (program, payload) → seconds.

    ``cost_model.step_features`` decomposes every program's predicted cost
    as ``n_steps·α + extra_hops·hop + load_frac·V·β`` — linear in the
    parameters — so the fit is one weighted ``lstsq``.  Rows are weighted by
    1/seconds: relative (not absolute) error, or the multi-MB samples would
    drown the latency-regime ones that decide α.
    """
    import numpy as np

    if not samples:
        raise ValueError("need at least one LinkSample to fit")
    rows, ts = [], []
    for s in samples:
        prog = schedule_ir.build_program(s.schedule, s.shape)
        n_steps, extra_hops, load_frac = cost_model.step_features(
            prog, mesh_contention)
        rows.append((n_steps, extra_hops, load_frac * s.payload_bytes))
        ts.append(s.seconds)
    A = np.asarray(rows, dtype=np.float64)
    t = np.asarray(ts, dtype=np.float64)
    w = 1.0 / np.maximum(t, 1e-12)
    sol, *_ = np.linalg.lstsq(A * w[:, None], t * w, rcond=None)
    alpha, hop, beta = (max(float(v), 1e-12) for v in sol)
    pred = A @ np.asarray([alpha, hop, beta])
    resid = float(np.sqrt(np.mean(
        ((pred - t) / np.maximum(t, 1e-12)) ** 2)))
    link = LinkParams(alpha_s=alpha, bw_Bps=1.0 / beta, hop_s=hop, name=name)
    return LinkFit(link=link, samples=tuple(samples), residual=resid)


def _measure_collective(mesh, axis_names: Tuple[str, ...],
                        sizes: Tuple[int, ...], schedule: str,
                        per_rank_elems: int, repeats: int = 3,
                        inner: int = 5) -> float:
    """Best-of-``repeats`` mean seconds of the jitted IR lowering."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from . import collectives as C

    world = math.prod(sizes)
    spec = P(axis_names)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(per_rank_elems * world,)).astype(np.float32))
    fn = jax.jit(compat.shard_map(
        lambda v: C.all_reduce(v, schedule, axis_names, sizes),
        mesh, spec, spec, check_vma=False, axis_names=frozenset(axis_names)))
    fn(x).block_until_ready()      # compile outside the timed region
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(x)
        out.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def fit_link_params(shape: Optional[Tuple[int, ...]] = None,
                    schedules: Sequence[str] = FIT_SCHEDULES,
                    payload_elems: Sequence[int] = FIT_PAYLOAD_ELEMS,
                    repeats: int = 3,
                    mesh_contention: bool = True,
                    min_devices: int = 8) -> LinkFit:
    """Time a (schedule × payload) grid of real jitted collectives and fit
    ``LinkParams`` to the measurements.

    Runs on whatever devices jax sees (≥ ``min_devices`` required — use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` or the CLI
    ``--devices`` flags to get host devices).  ``shape`` defaults to the
    largest power-of-two 1-D mesh the devices allow.
    """
    import jax

    from repro import compat

    n_dev = len(jax.devices())
    if shape is None:
        world = 1 << int(math.log2(max(1, n_dev)))
        shape = (world,)
    world = math.prod(shape)
    if world < min_devices:
        raise ValueError(
            f"link calibration needs ≥{min_devices} devices, have {n_dev} "
            f"(mesh {shape}); set --devices / XLA_FLAGS host-device count")
    axis_names = tuple(f"cal{i}" for i in range(len(shape)))
    mesh = compat.make_mesh(shape, axis_names)
    samples: List[LinkSample] = []
    for schedule in schedules:
        for elems in payload_elems:
            per_rank = ((elems + world - 1) // world) * world
            secs = _measure_collective(mesh, axis_names, shape, schedule,
                                       per_rank, repeats=repeats)
            samples.append(LinkSample(schedule=schedule, shape=shape,
                                      payload_bytes=per_rank * 4.0,
                                      seconds=secs))
    backend = jax.devices()[0].platform
    return fit_from_samples(samples, mesh_contention,
                            name=f"fitted-{backend}{world}")


# ---------------------------------------------------------------------------
# Path 2: AMO-baseline simulator fitting against paper Table 1
# ---------------------------------------------------------------------------

PENALTY = 1e6  # loss for configs that blow the simulation budget

TARGETS = []
for name, (_, _, naive, xy, _) in PAPER_TABLE1.items():
    TARGETS.append((name, "naive", naive))
    if name != "Neighbor":  # XY degenerates to Naive for 2 tiles
        TARGETS.append((name, "xy", xy))

SEARCH_SPACE = {
    "hop_latency": (1, 6),
    "link_occupancy": (1, 3),
    "inj_latency": (0, 5),
    "amo_service": (1, 24),
    "sw_pre": (0, 40),
    "sw_between": (0, 24),
    "sw_poll": (4, 40),   # ≥4: bounds poll-storm event counts
    "sw_post": (0, 16),
}


def evaluate(params: SimParams) -> tuple[float, dict]:
    sims = {}
    try:
        # cheap meshes first so pathological configs fail fast
        for name in sorted(PAPER_TABLE1, key=lambda n: _mesh_of(n)[0] *
                           _mesh_of(n)[1]):
            rows, cols = _mesh_of(name)
            sims[(name, "naive")] = NaiveBarrier(rows, cols, params).run()
            if name != "Neighbor":
                sims[(name, "xy")] = XYBarrier(rows, cols, params).run()
    except SimBudgetExceeded:
        return PENALTY, sims
    loss = 0.0
    for name, scheme, target in TARGETS:
        got = sims[(name, scheme)]
        loss += math.log(got / target) ** 2
    return loss / len(TARGETS), sims


def random_params(rng: random.Random) -> SimParams:
    return SimParams(**{k: rng.randint(lo, hi) for k, (lo, hi) in SEARCH_SPACE.items()})


def neighbors(p: SimParams, rng: random.Random, step: int = 1):
    for k, (lo, hi) in SEARCH_SPACE.items():
        v = getattr(p, k)
        for dv in (-step, step):
            nv = min(hi, max(lo, v + dv))
            if nv != v:
                yield dataclasses.replace(p, **{k: nv})


def search(iters: int = 200, seed: int = 0, start: SimParams | None = None):
    rng = random.Random(seed)
    best_p = start or DEFAULT_PARAMS
    best_loss, _ = evaluate(best_p)
    # Phase 1: random restarts
    for i in range(iters):
        p = random_params(rng)
        loss, _ = evaluate(p)
        if loss < best_loss:
            best_loss, best_p = loss, p
            print(f"[random {i}] loss={loss:.4f} {p}", flush=True)
    # Phase 2: coordinate descent from the best point
    improved = True
    while improved:
        improved = False
        for cand in neighbors(best_p, rng):
            loss, _ = evaluate(cand)
            if loss < best_loss - 1e-9:
                best_loss, best_p = loss, cand
                improved = True
                print(f"[descend] loss={loss:.4f} {cand}", flush=True)
    return best_p, best_loss


def report(params: SimParams) -> str:
    loss, sims = evaluate(params)
    lines = [f"params = {params}", f"mean sq log-ratio loss = {loss:.4f}", ""]
    lines.append(f"{'mesh':<9s} {'scheme':<6s} {'paper':>7s} {'sim':>7s} {'ratio':>6s}")
    for name, scheme, target in TARGETS:
        got = sims[(name, scheme)]
        lines.append(f"{name:<9s} {scheme:<6s} {target:>7d} {got:>7d} {got/target:>6.2f}")
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None,
                    help="output JSON (default: results/calibration.json, "
                         "or results/link_calibration.json with --links — "
                         "the two modes write different schemas)")
    ap.add_argument("--links", action="store_true",
                    help="fit LinkParams from measured jitted collectives "
                         "instead of the Table-1 simulator parameters")
    ap.add_argument("--devices", type=int, default=0,
                    help="host-device override for --links (set before "
                         "jax init)")
    args = ap.parse_args(argv)
    if args.links:
        import os
        if args.devices:
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={args.devices} "
                + os.environ.get("XLA_FLAGS", ""))
        fit = fit_link_params()
        print(fit.describe())
        out = args.out or "results/link_calibration.json"
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"link": dataclasses.asdict(fit.link),
                       "residual": fit.residual,
                       "samples": [dataclasses.asdict(s)
                                   for s in fit.samples]}, f, indent=2)
        return
    best_p, best_loss = search(args.iters, args.seed)
    print(report(best_p))
    import os
    out = args.out or "results/calibration.json"
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump({"params": dataclasses.asdict(best_p), "loss": best_loss}, f,
                  indent=2)


if __name__ == "__main__":
    main()
