"""α-β (latency-bandwidth) cost model for synchronization/collective schedules.

Carries two parameter sets (DESIGN.md §7):

  * ``MAGIA``: the paper's system — 1 GHz tiles, 1-cycle NoC hops, pure-control
    barriers (payload ≈ 0) → latency-dominated, which is why the H-tree's
    O(log N) beats XY's O(k) and Naïve's O(N) (Table 1).
  * ``TPU_V5E``: our target — 197 bf16 TFLOP/s/chip, 819 GB/s HBM,
    ~50 GB/s/link ICI, ~1 µs software-visible collective launch latency.
    Barriers ride on gradient collectives, so both α (latency) and β
    (bytes/bandwidth) terms matter.

The model prices the schedules implemented in ``core/collectives.py``; the
benchmarks use it to (a) project Table 1 to pod scale and (b) napkin-math the
§Perf hillclimb hypotheses before each change.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

from . import schedule_ir


@dataclass(frozen=True)
class LinkParams:
    alpha_s: float          # per-step latency (s): hop/launch overhead
    bw_Bps: float           # per-link bandwidth, bytes/s
    name: str = "link"
    # per-hop latency of multi-hop mesh routes (s); None → alpha_s, which
    # reproduces the historical ``hops × alpha`` pricing.  ``fit_link_params``
    # (core.calibrate) fits it separately from alpha: on real fabrics the
    # launch overhead dwarfs the per-hop forwarding cost.
    hop_s: Optional[float] = None

    @property
    def hop(self) -> float:
        return self.alpha_s if self.hop_s is None else self.hop_s


MAGIA = LinkParams(alpha_s=1e-9, bw_Bps=4e9, name="magia-noc")      # 1 cycle @1GHz, 32bit@1GHz
TPU_V5E_ICI = LinkParams(alpha_s=1e-6, bw_Bps=50e9, name="v5e-ici")
TPU_DCN = LinkParams(alpha_s=10e-6, bw_Bps=25e9, name="dcn")        # inter-pod


@dataclass(frozen=True)
class ChipParams:
    peak_flops: float = 197e12     # bf16
    hbm_Bps: float = 819e9
    hbm_GiB: float = 16.0
    name: str = "tpu-v5e"


TPU_V5E = ChipParams()


# ---------------------------------------------------------------------------
# All-reduce schedule costs for N devices, V bytes per device
# ---------------------------------------------------------------------------


def ring_all_reduce(n: int, vol_B: float, link: LinkParams) -> float:
    """Dimension-flat ring: 2(n−1) steps, bandwidth-optimal: 2·V·(n−1)/n."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * link.alpha_s + 2 * vol_B * (n - 1) / n / link.bw_Bps


def fractal_all_reduce(n: int, vol_B: float, link: LinkParams) -> float:
    """Recursive halving-doubling (the H-tree/butterfly schedule):
    reduce-scatter by halves (log n steps, V(n−1)/n bytes) then all-gather by
    doubles.  Latency-optimal (2·log n steps) AND bandwidth-optimal."""
    if n <= 1:
        return 0.0
    steps = 2 * math.log2(n)
    return steps * link.alpha_s + 2 * vol_B * (n - 1) / n / link.bw_Bps


def xy_all_reduce(kx: int, ky: int, vol_B: float, link: LinkParams) -> float:
    """Dimension-ordered (paper's XY baseline): ring along x then along y.
    Latency O(kx+ky); bandwidth 2·V·[(kx−1)/kx + (ky−1)/ky]."""
    return ring_all_reduce(kx, vol_B, link) + ring_all_reduce(ky, vol_B, link)


def naive_all_reduce(n: int, vol_B: float, link: LinkParams) -> float:
    """Gather-to-root + broadcast (paper's Naïve): root port serializes n−1
    ingress and n−1 egress transfers of V bytes."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * (link.alpha_s + vol_B / link.bw_Bps)


def hierarchical_all_reduce(n_inner: int, n_outer: int, vol_B: float,
                            inner: LinkParams, outer: LinkParams) -> float:
    """The fractal idea at pod granularity: intra-pod reduce-scatter,
    inter-pod all-reduce over V/n_inner shards, intra-pod all-gather."""
    if n_inner <= 1:
        return fractal_all_reduce(n_outer, vol_B, outer)
    rs = math.log2(n_inner) * inner.alpha_s + vol_B * (n_inner - 1) / n_inner / inner.bw_Bps
    mid = fractal_all_reduce(n_outer, vol_B / n_inner, outer)
    ag = math.log2(n_inner) * inner.alpha_s + vol_B * (n_inner - 1) / n_inner / inner.bw_Bps
    return rs + mid + ag


def tree_all_reduce(n: int, vol_B: float, link: LinkParams) -> float:
    """Two-phase tree reduce-broadcast: 2·log n steps each moving the full
    payload — latency-optimal like the butterfly, but O(V·log n) bytes."""
    if n <= 1:
        return 0.0
    return 2 * math.log2(n) * (link.alpha_s + vol_B / link.bw_Bps)


def barrier_cost(n: int, link: LinkParams, schedule: str = "fractal") -> float:
    """Pure-control barrier (payload→0): only the α terms survive. This is the
    regime of the paper, where the H-tree's 2·log2(N) steps win."""
    if schedule == "fractal":
        return 2 * math.log2(n) * link.alpha_s
    if schedule == "xy":
        k = int(round(math.sqrt(n)))
        return 2 * (k - 1) * 2 * link.alpha_s
    if schedule == "naive":
        return 2 * (n - 1) * link.alpha_s
    if schedule == "ring":
        return 2 * (n - 1) * link.alpha_s
    raise ValueError(schedule)


def schedule_cost(schedule: str, n: int, vol_B: float, link: LinkParams,
                  mesh_xy: tuple[int, int] | None = None) -> float:
    if schedule == "fractal":
        return fractal_all_reduce(n, vol_B, link)
    if schedule == "ring":
        return ring_all_reduce(n, vol_B, link)
    if schedule == "naive":
        return naive_all_reduce(n, vol_B, link)
    if schedule == "tree":
        return tree_all_reduce(n, vol_B, link)
    if schedule == "xy":
        kx, ky = mesh_xy or _square(n)
        return xy_all_reduce(kx, ky, vol_B, link)
    raise ValueError(schedule)


def _square(n: int) -> tuple[int, int]:
    k = int(round(math.sqrt(n)))
    if k * k != n:
        raise ValueError(f"{n} is not square; pass mesh_xy explicitly")
    return k, k


# ---------------------------------------------------------------------------
# Schedule IR backend: price any program directly from its step structure
# ---------------------------------------------------------------------------
#
# Plain α-β mode (mesh_contention=False):
#
#     cost = Σ_steps [ α + max_edge_fraction(step) · V / bw ]
#
# which reproduces the closed forms above *exactly* for every IR builder
# (the tests cross-check this).  Mesh mode (mesh_contention=True)
# additionally routes every transfer XY on the 2D mesh and charges
#
#     cost_step = hops_max · α + max_link_load · V / bw
#
# where max_link_load is the largest payload fraction any single directed
# link carries.  This is what separates the butterfly from the ring: ring
# neighbors are 1 hop with load V/N per link, while butterfly partners at
# sub-step b sit 2^⌊b/2⌋ hops apart and 2^⌊b/2⌋ exchanges share the middle
# links — the latency-vs-bandwidth crossover the autotuner exploits.


def _route_links(rows: int, cols: int, src: int, dst: int):
    """Directed links of the XY route between flat ranks (mirrors NoC)."""
    r, c = divmod(src, cols)
    dr, dc = divmod(dst, cols)
    links = []
    while c != dc:
        nc = c + (1 if dc > c else -1)
        links.append(((r, c), (r, nc)))
        c = nc
    while r != dr:
        nr = r + (1 if dr > r else -1)
        links.append(((r, c), (nr, c)))
        r = nr
    return links


@lru_cache(maxsize=512)
def _step_geometry(prog: schedule_ir.Program) -> Tuple[Tuple[int, float], ...]:
    """Per step: (max hop distance, max per-directed-link payload load in V
    units), from XY-routing every transfer on the program's 2D projection."""
    rows, cols = schedule_ir.as_2d(prog.shape)
    out = []
    for step in prog.steps:
        hops_max = 1
        load: dict = {}
        for t in step.transfers:
            frac = prog.frac(t)
            links = _route_links(rows, cols, t.src, t.dst)
            hops_max = max(hops_max, len(links))
            for l in links:
                load[l] = load.get(l, 0.0) + frac
        out.append((hops_max, max(load.values(), default=0.0)))
    return tuple(out)


def program_cost(prog: schedule_ir.Program, vol_B: float,
                 link: LinkParams, outer_link: Optional[LinkParams] = None,
                 mesh_contention: bool = False) -> float:
    """Predicted wall time of an IR program moving ``vol_B`` bytes/rank.

    Steps tagged ``tier="outer"`` (the hierarchical schedule's inter-pod
    middle) are priced on ``outer_link`` with hop distance 1 — pod-level
    links are point-to-point, not mesh-routed.  Without a distinct
    ``outer_link`` there IS no separate pod fabric: outer steps then ride
    the same mesh as everything else and pay hops/contention like any
    other step (otherwise the hierarchical schedule would beat the
    butterfly on single-tier meshes by modeling fiat).
    """
    geometry = _step_geometry(prog) if mesh_contention else None
    total = 0.0
    for i, step in enumerate(prog.steps):
        if not step.transfers:
            continue
        outer = step.tier == schedule_ir.TIER_OUTER and outer_link is not None
        lp = outer_link if outer else link
        frac = step.max_chunks_moved / prog.n_chunks
        if geometry is not None and not outer:
            hops, link_load = geometry[i]
            total += (lp.alpha_s + (hops - 1) * lp.hop
                      + max(frac, link_load) * vol_B / lp.bw_Bps)
        else:
            total += lp.alpha_s + frac * vol_B / lp.bw_Bps
    return total


def step_features(prog: schedule_ir.Program,
                  mesh_contention: bool = True
                  ) -> Tuple[int, int, float]:
    """(n_steps, extra_hops, load_frac) such that, single-tier,

        program_cost ≡ n_steps·α + extra_hops·hop + load_frac·V·(1/bw)

    — the program's cost is LINEAR in the link parameters, which is what
    lets ``core.calibrate.fit_link_params`` least-squares-fit (α, hop, β)
    from measured (program, payload) → seconds samples.
    """
    geometry = _step_geometry(prog) if mesh_contention else None
    n_steps, extra_hops, load_frac = 0, 0, 0.0
    for i, step in enumerate(prog.steps):
        if not step.transfers:
            continue
        frac = step.max_chunks_moved / prog.n_chunks
        n_steps += 1
        if geometry is not None:
            hops, link_load = geometry[i]
            extra_hops += hops - 1
            load_frac += max(frac, link_load)
        else:
            load_frac += frac
    return n_steps, extra_hops, load_frac


# -- payload-band memoization ------------------------------------------------
#
# Engine builds price O(buckets × candidates) programs, and the DP bucket
# search prices O(leaves²) segment payloads.  Exact payloads rarely repeat,
# but prices within a quarter-octave of payload are indistinguishable for
# schedule choice — so cacheable pricing quantizes the payload to a
# geometric band and memoizes per (program, band, links, mode).

BANDS_PER_OCTAVE = 4


def payload_band(vol_B: float) -> int:
    """Quarter-octave band index of a payload size (0-byte payloads → -1)."""
    if vol_B <= 0:
        return -1
    return int(round(BANDS_PER_OCTAVE * math.log2(vol_B)))


def band_payload(band: int) -> float:
    """Representative payload (band center) of a band index."""
    if band < 0:
        return 0.0
    return 2.0 ** (band / BANDS_PER_OCTAVE)


@lru_cache(maxsize=16384)
def _program_cost_banded(prog: schedule_ir.Program, band: int,
                         link: LinkParams, outer_link: Optional[LinkParams],
                         mesh_contention: bool) -> float:
    return program_cost(prog, band_payload(band), link, outer_link,
                        mesh_contention)


def program_cost_banded(prog: schedule_ir.Program, vol_B: float,
                        link: LinkParams,
                        outer_link: Optional[LinkParams] = None,
                        mesh_contention: bool = False) -> float:
    """``program_cost`` with the payload quantized to its quarter-octave
    band — repeated pricings of near-identical payloads hit one cache line
    (the memoization the ISSUE's perf-fix satellite asks for)."""
    return _program_cost_banded(prog, payload_band(vol_B), link, outer_link,
                                mesh_contention)


def program_barrier_cost(prog: schedule_ir.Program, link: LinkParams,
                         outer_link: Optional[LinkParams] = None,
                         mesh_contention: bool = False) -> float:
    """Pure-control regime (payload → 0): only the α structure survives."""
    return program_cost(prog, 0.0, link, outer_link, mesh_contention)


# ---------------------------------------------------------------------------
# Overlap-aware mode: price a bucketed superstep on a shared-fabric timeline
# ---------------------------------------------------------------------------
#
# The monolithic superstep is compute, THEN one big collective:
#
#     serial_s = backward_s + Σ_i cost(bucket_i)
#
# The bucketed superstep overlaps: bucket i's grads are ready at
# ``ready_s[i]`` (reverse-layer order — the last layers' grads drop out of
# backward first), and its collective occupies the shared fabric as soon as
# both the fabric is free and the bucket is ready.  Buckets serialize on the
# fabric (one shared NoC / ICI domain) but run concurrently with the rest of
# backward — which is exactly the DDP/ZeRO bucketing overlap argument, made
# quantitative per IR program.


@dataclass(frozen=True)
class OverlapTimeline:
    """Shared-fabric timeline of a bucketed superstep (seconds)."""

    ready_s: Tuple[float, ...]       # per bucket: grads available
    comm_start_s: Tuple[float, ...]  # per bucket: collective enters fabric
    comm_end_s: Tuple[float, ...]
    comm_cost_s: Tuple[float, ...]   # per bucket: isolated collective cost
    overlapped_s: float              # pipelined step time (last comm end)
    serial_s: float                  # no-overlap baseline: max ready + Σ cost

    @property
    def overlap_gain(self) -> float:
        """Fraction of the serial step time hidden by overlap."""
        if self.serial_s <= 0:
            return 0.0
        return 1.0 - self.overlapped_s / self.serial_s


def overlap_step_cost(progs: Sequence[schedule_ir.Program],
                      vols_B: Sequence[float],
                      ready_s: Sequence[float],
                      link: LinkParams,
                      outer_link: Optional[LinkParams] = None,
                      mesh_contention: bool = True,
                      extra_s: Optional[Sequence[float]] = None
                      ) -> OverlapTimeline:
    """Price a sequence of bucket programs on one shared-fabric timeline.

    ``progs[i]`` moves ``vols_B[i]`` bytes/rank and may start no earlier
    than ``ready_s[i]``; programs occupy the fabric in order (bucket i+1
    waits for bucket i — in-order issue, matching the runtime lowering).
    ``extra_s[i]`` adds a fixed per-bucket cost on top of the program price
    (e.g. codec quant/dequant launches).  ``serial_s`` is the monolithic
    baseline where no communication starts until every bucket is ready
    (the sum the ISSUE's overlap benchmark compares against).
    """
    if not (len(progs) == len(vols_B) == len(ready_s)):
        raise ValueError("progs, vols_B, ready_s must have equal length")
    if extra_s is None:
        extra_s = (0.0,) * len(progs)
    elif len(extra_s) != len(progs):
        raise ValueError("extra_s must match progs in length")
    costs = tuple(program_cost(p, v, link, outer_link, mesh_contention) + e
                  for p, v, e in zip(progs, vols_B, extra_s))
    starts, ends = [], []
    fabric_free = 0.0
    for c, r in zip(costs, ready_s):
        start = max(fabric_free, r)
        fabric_free = start + c
        starts.append(start)
        ends.append(fabric_free)
    overlapped = ends[-1] if ends else max(ready_s, default=0.0)
    serial = (max(ready_s) if ready_s else 0.0) + sum(costs)
    return OverlapTimeline(ready_s=tuple(ready_s),
                           comm_start_s=tuple(starts),
                           comm_end_s=tuple(ends),
                           comm_cost_s=costs,
                           overlapped_s=overlapped,
                           serial_s=serial)
