"""Area model of MAGIA + FractalSync (paper §4.2, Fig. 4).

Published synthesis constants (GF 12nm FinFET, Design Compiler, SSPG −40°C,
1 GHz target):

  * MAGIA tile without FractalSync : 1.5816 mm²
  * MAGIA tile with    FractalSync : 1.5814 mm²   (difference = synthesis noise
    → FS adds no measurable tile area; AMO + FS each < 0.03% of the tile)
  * Full system (k=16, memory banks excluded from the 'total' in the paper's
    overhead quote): NoC ≤ 1.7%, synchronization network ≤ 0.007%, > 98%
    compute + communication logic.

We invert those shares at k = 16 to obtain per-element areas, then model

    total(k) = k²·(A_tile + A_router) + (k²−1)·A_fs

which reproduces the paper's overhead numbers at k = 16 (tests assert this)
and shows the key scalability property: the FS share is bounded as k → ∞
(both numerator and denominator scale as k²).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .tree import FractalTree

# Published constants -------------------------------------------------------
TILE_AREA_MM2 = 1.5814          # tile incl. AMO + FractalSync support
TILE_AREA_NO_FS_MM2 = 1.5816    # tile without FractalSync (synthesis noise)
NOC_SHARE_AT_16 = 0.017         # ≤1.7% of full system at k=16
FS_SHARE_AT_16 = 0.00007        # ≤0.007% of full system at k=16
K_REF = 16

# Invert the k=16 shares: with T = k²(A_t + A_r) + (k²−1)A_fs,
#   A_r  = share_noc · T / k²,   A_fs = share_fs · T / (k²−1)
# and T = k²·A_t / (1 − share_noc − share_fs).
_T16 = (K_REF**2 * TILE_AREA_MM2) / (1.0 - NOC_SHARE_AT_16 - FS_SHARE_AT_16)
ROUTER_AREA_MM2 = NOC_SHARE_AT_16 * _T16 / K_REF**2
FS_MODULE_AREA_MM2 = FS_SHARE_AT_16 * _T16 / (K_REF**2 - 1)


@dataclass(frozen=True)
class AreaBreakdown:
    k: int
    tiles_mm2: float
    noc_mm2: float
    fs_mm2: float

    @property
    def total_mm2(self) -> float:
        return self.tiles_mm2 + self.noc_mm2 + self.fs_mm2

    @property
    def noc_share(self) -> float:
        return self.noc_mm2 / self.total_mm2

    @property
    def fs_share(self) -> float:
        return self.fs_mm2 / self.total_mm2


def system_area(k: int) -> AreaBreakdown:
    """Full-system area for a k×k mesh (paper's model: k² tiles, k×k NoC,
    k²−1 FS modules)."""
    tree = FractalTree((k, k))
    return AreaBreakdown(
        k=k,
        tiles_mm2=k * k * TILE_AREA_MM2,
        noc_mm2=k * k * ROUTER_AREA_MM2,
        fs_mm2=tree.num_fs_modules * FS_MODULE_AREA_MM2,
    )


def fs_tile_overhead() -> float:
    """FractalSync overhead on the tile itself (paper: < 0.01%, in fact the
    synthesized tile got *smaller* within noise)."""
    return (TILE_AREA_MM2 - TILE_AREA_NO_FS_MM2) / TILE_AREA_NO_FS_MM2


# Fig. 4 tile breakdown (qualitative: the text pins >98% to compute+comm and
# AMO+FS < 0.03%; the named components below follow §2.1's inventory).
TILE_BREAKDOWN = {
    "redmule_gemm": 0.315,
    "tcdm_banks_logic": 0.330,
    "hci_interconnect": 0.085,
    "core_cv32e40x_icache": 0.130,
    "idma": 0.060,
    "axi_obi_xbar": 0.073,
    "amo_module": 0.0003,
    "fractalsync_support": 0.0002,
    "other": 0.0065,
}
assert abs(sum(TILE_BREAKDOWN.values()) - 1.0) < 1e-9
