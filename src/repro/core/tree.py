"""FractalSync synchronization tree (paper §3.1-§3.2).

The paper synchronizes a k×k tile mesh with a binary tree of FractalSync (FS)
modules laid out as an H-tree: level 1 synchronizes pairs of neighboring tiles,
level 2 synchronizes pairs of level-1 FS modules, ..., level L = log2(N) is the
root.  ``fsync(level)`` synchronizes the subtree rooted at ``level`` — a
*synchronization domain*.

This module is the pure-Python topological model shared by

  * the cycle-accurate simulator (``core/simulator.py``) — Table 1 reproduction,
  * the JAX collective schedules (``core/collectives.py``) — the butterfly /
    recursive halving-doubling generalization of the H-tree recursion,
  * the area model (``core/area.py``) — N-1 FS modules for N tiles.

Geometry/pipelining model (paper §4.1, FractalSync+Pipeline): the level-l FS
module sits midway between its two children, so the child→parent wire spans half
the child separation.  Wires longer than one NoC tile pitch are segmented with
pipeline registers so that no segment exceeds the distance between two
neighboring NoC nodes.  With child separation ``sep(l) = 2^((l-1)//2)`` tile
pitches (axes alternate per level — the H-tree recursion), the register count is
``max(0, sep(l)//2 - 1)``.  This reproduces Table 1 exactly:

  mesh      levels  FSync = 2+2L   FSync+P = 2+2·Σ(1+regs)
  Neighbor  1       4              4
  2×2       2       6              6
  4×4       4       10             10
  8×8       6       14             14+2·(1+1)        = 18
  16×16     8       18             18+2·(1+1+3+3)    = 34
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import Iterator, Sequence, Tuple

Coord = Tuple[int, ...]


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclass(frozen=True)
class LevelSpec:
    """One level of the synchronization tree.

    axis : mesh axis whose coordinate bit is merged at this level
    bit  : which bit of that coordinate (0 = LSB)
    separation : distance (tile pitches) between the centers of the two child
                 groups merged at this level
    """

    level: int
    axis: int
    bit: int
    separation: int

    @property
    def wire_pitches(self) -> float:
        """Child→parent wire length: half the child-center separation."""
        return self.separation / 2

    @property
    def pipeline_regs(self) -> int:
        """Registers needed so no wire segment exceeds one NoC pitch."""
        return max(0, self.separation // 2 - 1)


@dataclass(frozen=True)
class FractalTree:
    """Binary synchronization tree over a power-of-two mesh.

    ``shape`` is the mesh shape, e.g. (16, 16) for the paper's largest config,
    (1, 2) for the paper's *Neighbor* case, or (2, 16, 16) for a 2-pod TPU
    production mesh (the pod axis becomes the top of the tree).

    Levels are numbered 1..L (paper convention). Bits are interleaved across
    axes from the innermost (last) axis outward, LSB first — the H-tree
    alternates pairing direction every level and the outermost axes (e.g.
    "pod") join last, i.e. nearest neighbors synchronize first.
    """

    shape: Tuple[int, ...]

    def __post_init__(self):
        if not self.shape or any(not _is_pow2(d) for d in self.shape):
            raise ValueError(f"mesh shape must be powers of two, got {self.shape}")
        if all(d == 1 for d in self.shape):
            raise ValueError("mesh must contain at least 2 tiles")

    # -- basic sizes ---------------------------------------------------------

    @property
    def num_tiles(self) -> int:
        return math.prod(self.shape)

    @property
    def num_levels(self) -> int:
        """L = log2(N): depth of the binary synchronization tree."""
        return int(math.log2(self.num_tiles))

    @property
    def num_fs_modules(self) -> int:
        """A binary tree over N leaves has N-1 internal FS modules (paper §4.2)."""
        return self.num_tiles - 1

    # -- level structure -----------------------------------------------------

    @cached_property
    def levels(self) -> Tuple[LevelSpec, ...]:
        """Interleave coordinate bits across axes, innermost axis first.

        For a square k×k mesh this yields the classic H-tree alternation
        x,y,x,y,...; for (2,16,16) the single pod bit is emitted last (root).
        """
        bits = [int(math.log2(d)) for d in self.shape]
        max_bits = max(bits)
        next_bit = [0] * len(self.shape)
        order: list[tuple[int, int]] = []
        # Round-robin innermost→outermost; axes with fewer bits join in the
        # LAST rounds so that short outer axes (e.g. a 2-pod axis) merge at
        # the top of the tree — physically-farther groups synchronize last.
        for r in range(max_bits):
            for axis in range(len(self.shape) - 1, -1, -1):
                if bits[axis] >= max_bits - r:
                    order.append((axis, next_bit[axis]))
                    next_bit[axis] += 1
        specs = []
        for lvl, (axis, bit) in enumerate(order, start=1):
            specs.append(
                LevelSpec(level=lvl, axis=axis, bit=bit, separation=1 << bit)
            )
        return tuple(specs)

    def level(self, level: int) -> LevelSpec:
        if not 1 <= level <= self.num_levels:
            raise ValueError(f"level {level} outside 1..{self.num_levels}")
        return self.levels[level - 1]

    # -- tile/partner/domain queries ------------------------------------------

    def _check_tile(self, tile: Coord) -> None:
        if len(tile) != len(self.shape) or any(
            not 0 <= c < d for c, d in zip(tile, self.shape)
        ):
            raise ValueError(f"tile {tile} outside mesh {self.shape}")

    def partner(self, tile: Coord, level: int) -> Coord:
        """Butterfly partner of ``tile`` at ``level``: toggle the level's bit.

        This is the software (all-ranks-active) equivalent of the H-tree: after
        levels 1..l every tile agrees with all tiles in its level-l domain.
        """
        self._check_tile(tile)
        spec = self.level(level)
        t = list(tile)
        t[spec.axis] ^= 1 << spec.bit
        return tuple(t)

    def domain_key(self, tile: Coord, level: int) -> Coord:
        """Canonical id of the sync domain containing ``tile`` after ``level``
        levels: coordinates with all merged bits cleared."""
        self._check_tile(tile)
        t = list(tile)
        for spec in self.levels[:level]:
            t[spec.axis] &= ~(1 << spec.bit)
        return tuple(t)

    def domain(self, tile: Coord, level: int) -> Tuple[Coord, ...]:
        """All tiles in ``tile``'s level-``level`` synchronization domain."""
        key = self.domain_key(tile, level)
        return tuple(
            t for t in self.tiles() if self.domain_key(t, level) == key
        )

    def domains(self, level: int) -> Tuple[Tuple[Coord, ...], ...]:
        """Partition of the mesh into level-``level`` synchronization domains
        (paper Fig. 2 purple dashed lines)."""
        groups: dict[Coord, list[Coord]] = {}
        for t in self.tiles():
            groups.setdefault(self.domain_key(t, level), []).append(t)
        return tuple(tuple(v) for _, v in sorted(groups.items()))

    def domain_size(self, level: int) -> int:
        return 1 << level

    def tiles(self) -> Iterator[Coord]:
        def rec(prefix: Tuple[int, ...], dims: Sequence[int]) -> Iterator[Coord]:
            if not dims:
                yield prefix
                return
            for c in range(dims[0]):
                yield from rec(prefix + (c,), dims[1:])

        yield from rec((), self.shape)

    # -- latency model (Table 1) ----------------------------------------------

    def fsync_latency(self, level: int | None = None, pipelined: bool = False) -> int:
        """Synchronization overhead Ŝ in cycles for aligned arrivals.

        Native FractalSync: 2 + 2·L (1 cycle per level up, 1 down, plus request
        sampling + wake).  FractalSync+Pipeline adds the per-level pipeline
        registers in both directions (paper Table 1).
        """
        level = self.num_levels if level is None else level
        specs = self.levels[:level]
        per_level = sum(1 + (s.pipeline_regs if pipelined else 0) for s in specs)
        return 2 + 2 * per_level

    def total_pipeline_regs(self, level: int | None = None) -> int:
        level = self.num_levels if level is None else level
        return sum(s.pipeline_regs for s in self.levels[:level])

    # -- H-tree wire accounting (for the area model) --------------------------

    def total_wire_pitches(self) -> float:
        """Total H-tree wiring in tile pitches: each level has N/2^l modules,
        each with two child wires of wire_pitches(l)."""
        total = 0.0
        for spec in self.levels:
            n_modules = self.num_tiles >> spec.level
            total += n_modules * 2 * spec.wire_pitches
        return total


def neighbor_tree() -> FractalTree:
    """The paper's 'Neighbor' configuration: two adjacent tiles, one FS module."""
    return FractalTree((1, 2))


def square_tree(k: int) -> FractalTree:
    """A k×k mesh (paper sweeps k ∈ {2,4,8,16})."""
    return FractalTree((k, k))
