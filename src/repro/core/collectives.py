"""FractalSync collective schedules in JAX (shard_map + lax.ppermute).

The paper's H-tree barrier is recursive-pairwise: level l synchronizes pairs
of level-(l−1) groups, alternating mesh axes.  The software (all-ranks-active)
equivalent of that recursion is the **butterfly**: at step b every device
exchanges with the partner whose flat mesh index differs in bit b.  After
log2(N) steps every device has synchronized with all N.  We implement, inside
``shard_map``:

  * ``fractal_barrier``        — pure-control fsync: recursive doubling on a
                                 unit token (the paper's fsync(level)).
  * ``fractal_all_reduce``     — recursive halving-doubling all-reduce
                                 (reduce-scatter by halves + all-gather by
                                 doubles): 2·log2(N) steps (latency-optimal,
                                 like the H-tree) and 2·V·(N−1)/N bytes
                                 (bandwidth-optimal).  This is the schedule we
                                 deploy for BSP gradient synchronization.
  * ``fractal_reduce_scatter`` / ``fractal_all_gather`` — the two halves.
  * ``xy_all_reduce``          — the paper's XY baseline: dimension-ordered
                                 ring all-reduce (rows then columns).
  * ``naive_all_reduce``       — the paper's Naïve baseline: serial
                                 gather-to-root + broadcast-from-root.
  * ``hierarchical_all_reduce``— beyond-paper: the fractal recursion applied at
                                 pod granularity (intra-pod reduce-scatter →
                                 inter-pod all-reduce on 1/inner of the bytes →
                                 intra-pod all-gather), for meshes whose outer
                                 axis rides slower links.

All schedules are numerically validated against ``jax.lax.psum`` in
``tests/test_collectives.py`` on a 16-device host-platform mesh.

Conventions: ``axis_names`` is a tuple of mesh axis names, flattened row-major
into one logical rank index (outermost first), so bit 0 of the flat index is
the innermost axis — neighbors first, pods last, exactly the H-tree order.
Every axis size must be a power of two (as in the paper's meshes).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import schedule_ir

AxisNames = Tuple[str, ...]


# ---------------------------------------------------------------------------
# flat index helpers (inside shard_map)
# ---------------------------------------------------------------------------


def axis_sizes(axis_names: AxisNames) -> Tuple[int, ...]:
    return tuple(lax.psum(1, a) for a in axis_names)  # static under shard_map


def _static_sizes(mesh: jax.sharding.Mesh, axis_names: AxisNames) -> Tuple[int, ...]:
    return tuple(mesh.shape[a] for a in axis_names)


def flat_index(axis_names: AxisNames) -> jax.Array:
    """Row-major flat rank over ``axis_names`` (outermost first)."""
    idx = jnp.int32(0)
    for a in axis_names:
        idx = idx * lax.psum(1, a) + lax.axis_index(a)
    return idx


def _flat_perm(sizes: Sequence[int], fn: Callable[[int], int]):
    """Permutation [(src, fn(src))] over the flattened axis product."""
    n = math.prod(sizes)
    return [(i, fn(i)) for i in range(n)]


def _ppermute_flat(x, axis_names: AxisNames, perm):
    """ppermute over the flattened product of ``axis_names``.

    jax supports tuple axis_name for ppermute; indices are row-major over the
    named axes, matching ``flat_index``.
    """
    return lax.ppermute(x, axis_names, perm)


def _codec_exchange(send, axis_names: AxisNames, perm, codec):
    """One point-to-point exchange, optionally codec-compressed on the wire
    (the single definition of the wire protocol: encode → permute every
    wire leaf → decode).  Shared by every fractal halving/doubling step."""
    if codec is None:
        return _ppermute_flat(send, axis_names, perm)
    wire = codec.encode(send)
    wire = jax.tree.map(
        lambda leaf: _ppermute_flat(leaf, axis_names, perm), wire)
    return codec.decode(wire, send.shape, send.dtype)


def _codec_exchange_add(keep, send, axis_names: AxisNames, perm, codec):
    """``keep + exchange(send)`` — the receive side of every reduce hop.

    With a codec, the wire-decode is fused into the accumulate via
    ``kernels.tree_reduce.ops.decode_add`` (one launch instead of
    dequant-then-add; the fused per-step α that
    ``autotune.CODEC_STEP_ALPHAS_FUSED`` prices).  Off-TPU ``decode_add``
    IS ``keep + codec.decode(wire)``, so CPU numerics are bit-identical
    to the unfused expression the collective tests pin."""
    if codec is None:
        return keep + _ppermute_flat(send, axis_names, perm)
    from repro.kernels.tree_reduce.ops import decode_add
    wire = codec.encode(send)
    wire = jax.tree.map(
        lambda leaf: _ppermute_flat(leaf, axis_names, perm), wire)
    return decode_add(keep, wire, codec)


# ---------------------------------------------------------------------------
# fractal (H-tree / butterfly) schedules
# ---------------------------------------------------------------------------


def _n_levels(sizes: Sequence[int]) -> int:
    n = math.prod(sizes)
    L = int(math.log2(n))
    if 1 << L != n:
        raise ValueError(f"fractal schedules need power-of-two world, got {n}")
    return L


def fractal_barrier(axis_names: AxisNames, sizes: Sequence[int],
                    level: int | None = None, token=None) -> jax.Array:
    """fsync(level): recursive-doubling barrier over the lowest ``level``
    levels of the synchronization tree (level=None → root = full world).

    Returns a scalar token that equals the number of devices in the sync
    domain — threading it into downstream computation enforces the barrier
    dependency (see ``core.barrier.fsync``)."""
    L = _n_levels(sizes)
    level = L if level is None else level
    if not 0 <= level <= L:
        raise ValueError(f"fsync level {level} outside 0..{L}")
    tok = jnp.ones((), jnp.int32) if token is None else token
    for b in range(level):
        recv = _ppermute_flat(tok, axis_names,
                              _flat_perm(sizes, lambda i, b=b: i ^ (1 << b)))
        tok = tok + recv
    return tok


def fractal_all_reduce(x: jax.Array, axis_names: AxisNames,
                       sizes: Sequence[int], codec=None) -> jax.Array:
    """Recursive halving-doubling all-reduce (the FractalSync schedule).

    Phase 1 (reduce-scatter by halves): at step b exchange half the working
    buffer with partner ``i ^ (1<<b)``; devices with bit b = 0 keep the low
    half.  Phase 2 (all-gather by doubles) mirrors it.  Requires the leading
    dim of ``x`` to be divisible by N (pad upstream; ``sync_gradients`` does).

    ``codec`` (optim.compression.Codec) compresses each exchanged payload —
    gradient compression rides the schedule's point-to-point hops.
    """
    L = _n_levels(sizes)
    n = 1 << L
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by world {n}")
    idx = flat_index(axis_names)

    def exchange(send, b):
        perm = _flat_perm(sizes, lambda i: i ^ (1 << b))
        return _codec_exchange(send, axis_names, perm, codec)

    # ---- reduce-scatter by halves ----
    for b in range(L):
        half = x.shape[0] // 2
        bit = (idx >> b) & 1
        # keep-low if bit==0 (start 0) else keep-high (start half)
        keep = lax.dynamic_slice_in_dim(x, bit * half, half, axis=0)
        send = lax.dynamic_slice_in_dim(x, (1 - bit) * half, half, axis=0)
        perm = _flat_perm(sizes, lambda i, b=b: i ^ (1 << b))
        x = _codec_exchange_add(keep, send, axis_names, perm, codec)

    # ---- all-gather by doubles ----
    for b in reversed(range(L)):
        bit = (idx >> b) & 1
        recv = exchange(x, b)
        # my piece is the low part if bit==0
        x = lax.cond(bit == 0,
                     lambda a, r: jnp.concatenate([a, r], axis=0),
                     lambda a, r: jnp.concatenate([r, a], axis=0),
                     x, recv)
    return x


def fractal_reduce_scatter(x: jax.Array, axis_names: AxisNames,
                           sizes: Sequence[int], codec=None) -> jax.Array:
    """Reduce-scatter by recursive halving: log2(N) steps, V·(N−1)/N bytes.
    Output is this device's shard (leading dim / N). Shard order follows the
    butterfly bit order (LSB-first); ``fractal_all_gather`` inverts it.

    ``codec`` compresses each exchanged half on the wire (the RS half of the
    per-bucket compression policy; partial sums are re-quantized per hop, so
    accuracy rides the codec's tolerance like the all-reduce codec path).
    """
    L = _n_levels(sizes)
    n = 1 << L
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by world {n}")
    idx = flat_index(axis_names)
    for b in range(L):
        half = x.shape[0] // 2
        bit = (idx >> b) & 1
        keep = lax.dynamic_slice_in_dim(x, bit * half, half, axis=0)
        send = lax.dynamic_slice_in_dim(x, (1 - bit) * half, half, axis=0)
        perm = _flat_perm(sizes, lambda i, b=b: i ^ (1 << b))
        x = _codec_exchange_add(keep, send, axis_names, perm, codec)
    return x


def fractal_all_gather(x: jax.Array, axis_names: AxisNames,
                       sizes: Sequence[int]) -> jax.Array:
    """Inverse of ``fractal_reduce_scatter`` (all-gather by doubling)."""
    L = _n_levels(sizes)
    idx = flat_index(axis_names)
    for b in reversed(range(L)):
        recv = _ppermute_flat(x, axis_names,
                              _flat_perm(sizes, lambda i, b=b: i ^ (1 << b)))
        bit = (idx >> b) & 1
        x = lax.cond(bit == 0,
                     lambda a, r: jnp.concatenate([a, r], axis=0),
                     lambda a, r: jnp.concatenate([r, a], axis=0),
                     x, recv)
    return x


# ---------------------------------------------------------------------------
# paper baselines
# ---------------------------------------------------------------------------


def ring_all_reduce(x: jax.Array, axis_name: str, size: int) -> jax.Array:
    """Flat ring all-reduce along one axis: reduce-scatter ring + all-gather
    ring, 2(k−1) steps. (The bandwidth-optimal flat baseline.)"""
    k = size
    if k == 1:
        return x
    if x.shape[0] % k:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by ring {k}")
    idx = lax.axis_index(axis_name)
    chunk = x.shape[0] // k
    shift_down = [(i, (i - 1) % k) for i in range(k)]

    def chunk_at(buf, c):
        return lax.dynamic_slice_in_dim(buf, c * chunk, chunk, axis=0)

    # reduce-scatter: after k−1 steps, device i owns reduced chunk i
    acc = chunk_at(x, (idx + 1) % k)
    for s in range(k - 1):
        acc = lax.ppermute(acc, axis_name, shift_down)
        c = (idx + 1 + s + 1) % k  # chunk arriving at this step
        acc = acc + chunk_at(x, c)
    # now acc = full sum of chunk idx  (c ends at idx)

    # all-gather ring
    pieces = [acc]
    cur = acc
    for s in range(k - 1):
        cur = lax.ppermute(cur, axis_name, shift_down)
        pieces.append(cur)
    # piece j (0-based, in arrival order) is chunk (idx + j) % k
    out = jnp.zeros_like(x)
    for j, piece in enumerate(pieces):
        c = (idx + j) % k
        out = lax.dynamic_update_slice_in_dim(out, piece, c * chunk, axis=0)
    return out


def xy_all_reduce(x: jax.Array, axis_x: str, axis_y: str,
                  size_x: int, size_y: int) -> jax.Array:
    """Paper's XY scheme: 1D ring all-reduce along x, then along y."""
    x = ring_all_reduce(x, axis_x, size_x)
    x = ring_all_reduce(x, axis_y, size_y)
    return x


def naive_all_reduce(x: jax.Array, axis_names: AxisNames,
                     sizes: Sequence[int]) -> jax.Array:
    """Paper's Naïve scheme: every device's contribution is serially funneled
    to rank 0 (gather-to-root along a ring into the root), reduced there, then
    broadcast back out the same way.  O(N) serial steps — the quadratic-cost
    baseline (each step moves full V through the root's port)."""
    n = math.prod(sizes)
    if n == 1:
        return x
    idx = flat_index(axis_names)
    shift_down = _flat_perm(sizes, lambda i: (i - 1) % n)
    # gather: pass contributions toward root; root accumulates
    acc = x
    buf = x
    for _ in range(n - 1):
        buf = _ppermute_flat(buf, axis_names, shift_down)
        acc = jnp.where(idx == 0, acc + buf, acc)
    # broadcast from root: push the total outward ring-wise
    shift_up = _flat_perm(sizes, lambda i: (i + 1) % n)
    out = acc
    for _ in range(n - 1):
        nxt = _ppermute_flat(out, axis_names, shift_up)
        out = jnp.where(idx == 0, out, nxt)
    return jnp.where(idx == 0, acc, out)


# ---------------------------------------------------------------------------
# beyond-paper: hierarchical (multi-pod) schedule
# ---------------------------------------------------------------------------


def hierarchical_all_reduce(x: jax.Array, inner_axes: AxisNames,
                            inner_sizes: Sequence[int], outer_axes: AxisNames,
                            outer_sizes: Sequence[int]) -> jax.Array:
    """Fractal recursion at pod granularity: intra-pod reduce-scatter (fast
    links), inter-pod all-reduce on V/inner bytes (slow links), intra-pod
    all-gather.  Inter-pod traffic shrinks by the intra-pod world size —
    the property that makes BSP viable across pods."""
    x = fractal_reduce_scatter(x, inner_axes, inner_sizes)
    x = fractal_all_reduce(x, outer_axes, outer_sizes) \
        if math.prod(outer_sizes) > 1 else x
    x = fractal_all_gather(x, inner_axes, inner_sizes)
    return x


# ---------------------------------------------------------------------------
# Schedule IR lowering: any all-reduce Program → shard_map + ppermute
# ---------------------------------------------------------------------------


def _step_tables(prog: schedule_ir.Program, step: schedule_ir.Step):
    """Host-side constant tables for one IR step (hashable for jit reuse):
    per-rank send/recv chunk ids, destination mask, reduce-vs-copy mask."""
    world, k = prog.world, step.max_chunks_moved
    S = np.zeros((world, k), np.int32)
    R = np.zeros((world, k), np.int32)
    is_dst = np.zeros((world,), bool)
    red = np.zeros((world,), bool)
    perm = []
    for t in step.transfers:
        S[t.src] = t.chunks
        R[t.dst] = t.chunks
        is_dst[t.dst] = True
        red[t.dst] = t.reduce
        perm.append((t.src, t.dst))
    return perm, S, R, is_dst, red


def ir_all_reduce(x: jax.Array, prog: schedule_ir.Program,
                  axis_names: AxisNames) -> jax.Array:
    """Execute an all-reduce IR Program inside ``shard_map``.

    The generic lowering that subsumes the hand-rolled per-schedule loops:
    the payload is viewed as ``[n_chunks, chunk]``; each IR step becomes one
    ``lax.ppermute`` (the IR validator guarantees every step is a partial
    permutation with uniform message shapes) bracketed by chunk gathers and
    reduce-or-overwrite scatters driven by per-rank constant tables.
    """
    if prog.kind != schedule_ir.ALL_REDUCE:
        raise ValueError(f"cannot lower {prog.kind!r} program {prog.name!r}")
    n_chunks = prog.n_chunks
    if prog.world == 1:
        return x
    if x.shape[0] % n_chunks:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by "
                         f"{n_chunks} chunks of {prog.name!r}")
    idx = flat_index(axis_names)
    buf = x.reshape(n_chunks, x.shape[0] // n_chunks, *x.shape[1:])
    for step in prog.steps:
        if not step.transfers:
            continue
        perm, S, R, is_dst, red = _step_tables(prog, step)
        send = jnp.take(buf, jnp.asarray(S)[idx], axis=0)
        recv = lax.ppermute(send, axis_names, perm)
        rids = jnp.asarray(R)[idx]
        merged = jnp.where(jnp.asarray(red)[idx],
                           buf.at[rids].add(recv),
                           buf.at[rids].set(recv))
        buf = jnp.where(jnp.asarray(is_dst)[idx], merged, buf)
    return buf.reshape(x.shape)


# ---------------------------------------------------------------------------
# schedule registry + flat-tensor entry point (used by BSP gradient sync)
# ---------------------------------------------------------------------------

SCHEDULES = schedule_ir.SCHEDULES + ("xla",)


def all_reduce(x: jax.Array, schedule: str, axis_names: AxisNames,
               sizes: Sequence[int]) -> jax.Array:
    """Dispatch an all-reduce over the flattened ``axis_names`` world.

    Every software schedule routes through the Schedule IR (one builder per
    schedule, one generic lowering); ``"xla"`` short-circuits to
    ``lax.psum``.  ``x`` must have a leading dim divisible by the world size
    (BSP gradient sync pads to this).  The pre-IR hand-rolled lowerings
    above remain exported for the reduce-scatter/all-gather split that the
    ZeRO-1 trainer uses and as cross-checks in the test-suite.
    """
    if schedule == "xla":
        return lax.psum(x, axis_names)
    prog = schedule_ir.build_program(schedule, tuple(sizes))
    return ir_all_reduce(x, prog, axis_names)


def bit_reversed_index(axis_names: AxisNames, sizes: Sequence[int]
                       ) -> jax.Array:
    """Bit-reversal of this rank's flat index over log2(world) bits.

    After recursive-halving reduce-scatter, rank i holds the CONTIGUOUS
    payload chunk at bit-reversed position rev(i) — the coarsest split is
    decided by bit 0.  Every consumer of the ZeRO-1 shard layout (trainer,
    SuperstepEngine) derives shard placement from this one definition.
    """
    L = _n_levels(sizes)   # raises unless the world is a power of two
    idx = flat_index(axis_names)
    rev = jnp.zeros((), jnp.int32)
    for b in range(L):
        rev = rev | (((idx >> b) & 1) << (L - 1 - b))
    return rev


def reduce_scatter(x: jax.Array, schedule: str, axis_names: AxisNames,
                   sizes: Sequence[int], codec=None) -> jax.Array:
    """Schedule-dispatched reduce-scatter of a flat payload (sum, no mean).

    Returns this rank's shard (leading dim / world) at the bit-reversed
    position ``bit_reversed_index`` describes.  The fractal schedule
    reduce-scatters natively (half the butterfly); every other schedule
    falls back to its full all-reduce followed by a local slice — same
    bytes on the wire as its all-reduce, same shard layout out.

    ``codec`` wire-compresses the fractal path only (the per-bucket codec
    policy never assigns codecs to other schedules).
    """
    world = math.prod(sizes)
    if schedule == "fractal":
        return fractal_reduce_scatter(x, axis_names, sizes, codec=codec)
    shard_len = x.shape[0] // world
    full = all_reduce(x, schedule, axis_names, sizes)
    rev = bit_reversed_index(axis_names, sizes)
    return lax.dynamic_slice_in_dim(full, rev * shard_len, shard_len, axis=0)


def all_gather_flat(shard: jax.Array, axis_names: AxisNames,
                    sizes: Sequence[int]) -> jax.Array:
    """Inverse of ``reduce_scatter``'s placement: gather shards back into
    the original flat order (the butterfly all-gather inverts the
    bit-reversed scatter for every schedule, since the layout is shared)."""
    return fractal_all_gather(shard, axis_names, sizes)
