"""Step-graph Schedule IR: every synchronization schedule as *data*.

The paper's contribution is a schedule — the H-tree recursion — evaluated
against Naïve and XY baselines.  Before this module the repo re-implemented
each schedule three times (JAX ``ppermute`` loops in ``collectives.py``,
hand-written event logic in ``simulator.py``, closed forms in
``cost_model.py``), and the three copies drifted.  Here a schedule is a
single declarative **step graph**, and the three layers become *consumers*:

  * ``collectives.ir_all_reduce``    lowers any all-reduce Program to
    ``shard_map`` + ``lax.ppermute`` (validated against ``lax.psum``);
  * ``simulator.schedule_on_noc``    replays any Program on the contended
    XY-mesh NoC model (simulated latency for every software schedule);
  * ``cost_model.program_cost``      prices a Program from its step
    structure (α·steps + β·Σ payload, optional mesh congestion).

Representation (chunk DSL, in the spirit of MSCCLang): the payload V is cut
into ``n_chunks`` equal chunks; ranks are the row-major flattening of the
mesh ``shape`` (outermost axis first — bit 0 of the flat rank is the
innermost axis, exactly the H-tree order of ``core.tree.FractalTree``).  A
``Step`` is a set of ``Transfer``s executed concurrently; a ``Transfer``
moves a tuple of chunk ids from ``src`` to ``dst`` and either reduces into
the destination (``reduce=True``) or overwrites it.  Steps carry sync-tree
``level``, mesh ``axis`` and link ``tier`` metadata for the cost model and
the fsync-domain machinery.

Two program kinds:

  * ``all_reduce`` — lowerable: per step every rank sends at most one
    message and receives at most one (a partial permutation — exactly what
    one ``lax.ppermute`` can express), and all transfers in a step carry
    the same number of chunks.
  * ``barrier``    — token programs (fan-in/fan-out allowed); consumed by
    the simulator's NoC/AMO executors, not lowered to ``ppermute``.

``validate`` abstract-interprets a program over *contribution sets* (which
source ranks have been summed into each chunk) and rejects double-counting
reduces and incomplete schedules — the IR analogue of the numerical
``lax.psum`` check.

Adding a schedule ≈ 20 lines: write a builder returning a ``Program`` (see
``tree_all_reduce`` below for the template), register it in ``BUILDERS``,
and all three backends plus the autotuner pick it up.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .tree import FractalTree

Shape = Tuple[int, ...]

ALL_REDUCE = "all_reduce"
BARRIER = "barrier"

TIER_INNER = "inner"   # priced on the fast (intra-pod / NoC) link
TIER_OUTER = "outer"   # priced on the slow (inter-pod) link


# ---------------------------------------------------------------------------
# IR node types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Transfer:
    """One point-to-point message: ``chunks`` of the payload from src to dst.

    ``reduce=True``  → destination accumulates (+=) the incoming chunks;
    ``reduce=False`` → destination overwrites (gather/broadcast semantics).
    """

    src: int
    dst: int
    chunks: Tuple[int, ...]
    reduce: bool = True

    @property
    def n_chunks_moved(self) -> int:
        return len(self.chunks)


@dataclass(frozen=True)
class Step:
    """Transfers that may fly concurrently, plus scheduling metadata.

    level : synchronization-tree level this step realizes (1-based, None if
            the schedule is not tree-structured)
    axis  : mesh axis index the communication travels along (None if mixed)
    tier  : which link class prices this step ("inner" | "outer")
    """

    transfers: Tuple[Transfer, ...]
    level: Optional[int] = None
    axis: Optional[int] = None
    tier: str = TIER_INNER

    def senders(self) -> List[int]:
        return [t.src for t in self.transfers]

    def receivers(self) -> List[int]:
        return [t.dst for t in self.transfers]

    @property
    def max_chunks_moved(self) -> int:
        return max((t.n_chunks_moved for t in self.transfers), default=0)


@dataclass(frozen=True)
class BucketMeta:
    """Which slice of a bucketed superstep payload a Program moves.

    The SuperstepEngine (``core.superstep``) cuts the flat gradient vector
    into size-bounded buckets and compiles one Program per bucket; this
    metadata makes bucket identity part of the IR so every consumer — the
    JAX lowering, the NoC replay, the cost model, the autotuner — agrees on
    *which* bytes a program is responsible for and where they live in the
    step's flat payload.

    index        : bucket position in ready order (0 = first grads ready,
                   i.e. the LAST layers of the model — reverse-layer order)
    n_buckets    : total buckets in the superstep
    offset_elems : start of this bucket in the bucket-ordered flat vector
    length_elems : padded element count of this bucket
    codec        : wire codec this bucket's payload rides ("bf16" | "int8";
                   None = uncompressed) — the per-bucket compression policy
                   the autotuner picks is part of bucket identity too
    """

    index: int
    n_buckets: int
    offset_elems: int
    length_elems: int
    codec: Optional[str] = None


@dataclass(frozen=True)
class Program:
    """A complete schedule: ordered steps over a flat rank space."""

    name: str
    shape: Shape                 # mesh shape; ranks are row-major flattened
    n_chunks: int                # payload granularity (V / n_chunks per chunk)
    steps: Tuple[Step, ...]
    kind: str = ALL_REDUCE
    bucket: Optional[BucketMeta] = None   # set when part of a bucketed step

    def with_bucket(self, meta: BucketMeta) -> "Program":
        return Program(self.name, self.shape, self.n_chunks, self.steps,
                       self.kind, meta)

    @property
    def world(self) -> int:
        return math.prod(self.shape)

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def frac(self, transfer: Transfer) -> float:
        """Fraction of the full payload V a transfer moves."""
        return transfer.n_chunks_moved / self.n_chunks

    def per_rank_frac_sent(self) -> Dict[int, float]:
        """Σ payload fraction each rank puts on the wire across all steps."""
        out: Dict[int, float] = {r: 0.0 for r in range(self.world)}
        for step in self.steps:
            for t in step.transfers:
                out[t.src] += self.frac(t)
        return out

    def describe(self) -> str:
        msgs = sum(len(s.transfers) for s in self.steps)
        vol = max(self.per_rank_frac_sent().values(), default=0.0)
        tag = ""
        if self.bucket is not None:
            tag = (f" bucket {self.bucket.index}/{self.bucket.n_buckets}"
                   f" @{self.bucket.offset_elems}"
                   f"+{self.bucket.length_elems}")
        return (f"{self.name}[{'x'.join(map(str, self.shape))}]: "
                f"{self.num_steps} steps, {msgs} msgs, "
                f"{vol:.3g}·V max per-rank send volume{tag}")


class ScheduleError(ValueError):
    """An IR program violates its kind's structural or semantic invariants."""


# ---------------------------------------------------------------------------
# flat-rank geometry helpers
# ---------------------------------------------------------------------------


def rank_coords(shape: Shape, rank: int) -> Tuple[int, ...]:
    """Row-major (outermost-first) coordinates of a flat rank."""
    coords = []
    for d in reversed(shape):
        coords.append(rank % d)
        rank //= d
    return tuple(reversed(coords))


def coords_rank(shape: Shape, coords: Sequence[int]) -> int:
    rank = 0
    for c, d in zip(coords, shape):
        rank = rank * d + c
    return rank


def as_2d(shape: Shape) -> Tuple[int, int]:
    """Collapse a mesh shape to (rows, cols) for NoC placement/routing:
    the innermost axis becomes columns, everything else stacks into rows."""
    if len(shape) == 1:
        return (1, shape[0])
    return (math.prod(shape[:-1]), shape[-1])


def tree_bit_positions(shape: Shape) -> Tuple[int, ...]:
    """Flat-rank bit position merged at each FractalTree level (1-based
    levels → index 0 is level 1).  Bit 0 of the flat rank is the LSB of the
    innermost axis, so position(axis, bit) = Σ_{inner axes} log2(size) + bit.
    """
    tree = FractalTree(shape)
    width = [int(math.log2(d)) for d in shape]
    offset = []
    for a in range(len(shape)):
        offset.append(sum(width[a + 1:]))
    return tuple(offset[s.axis] + s.bit for s in tree.levels)


def _check_pow2(shape: Shape) -> int:
    n = math.prod(shape)
    L = int(math.log2(n)) if n > 0 else 0
    if n < 1 or (1 << L) != n:
        raise ScheduleError(f"IR schedules need a power-of-two world, "
                            f"got shape {shape} (world {n})")
    return L


def _bit(v: int, pos: int) -> int:
    return (v >> pos) & 1


def _agrees(c: int, r: int, positions: Iterable[int]) -> bool:
    return all(_bit(c, p) == _bit(r, p) for p in positions)


# ---------------------------------------------------------------------------
# builders: the six all-reduce schedules
# ---------------------------------------------------------------------------


def _butterfly_steps(world: int, n_chunks: int, bits: Sequence[int],
                     tiers: Sequence[str], axes: Sequence[Optional[int]],
                     base_level: int = 0) -> List[Step]:
    """Recursive halving-doubling over an explicit bit sequence.

    Phase 1 (reduce-scatter): at sub-step i every rank keeps the half of its
    working chunk set agreeing with its own bit ``bits[i]`` and sends the
    other half to the partner across that bit.  Phase 2 mirrors it with
    gathers.  The classic butterfly is ``bits = tree_bit_positions(shape)``;
    the hierarchical schedule is the same recursion with inner bits first.
    """
    steps: List[Step] = []
    # reduce-scatter by halves
    for i, p in enumerate(bits):
        transfers = []
        for r in range(world):
            send = tuple(c for c in range(n_chunks)
                         if _agrees(c, r, bits[:i]) and _bit(c, p) != _bit(r, p))
            transfers.append(Transfer(r, r ^ (1 << p), send, reduce=True))
        steps.append(Step(tuple(transfers), level=base_level + i + 1,
                          axis=axes[i], tier=tiers[i]))
    # all-gather by doubles
    for i in reversed(range(len(bits))):
        p = bits[i]
        transfers = []
        for r in range(world):
            own = tuple(c for c in range(n_chunks)
                        if _agrees(c, r, bits[:i + 1]))
            transfers.append(Transfer(r, r ^ (1 << p), own, reduce=False))
        steps.append(Step(tuple(transfers), level=base_level + i + 1,
                          axis=axes[i], tier=tiers[i]))
    return steps


def butterfly_all_reduce(shape: Shape) -> Program:
    """The FractalSync schedule: recursive halving-doubling whose partner
    sequence follows the H-tree level order (``FractalTree.partner``) —
    innermost axis first, axes alternating, pods last."""
    L = _check_pow2(shape)
    world = 1 << L
    bits = tree_bit_positions(shape)
    tree = FractalTree(shape)
    axes = [s.axis for s in tree.levels]
    steps = _butterfly_steps(world, world, bits, [TIER_INNER] * L, axes)
    return Program("fractal", shape, world, tuple(steps))


def hierarchical_all_reduce(shape: Shape, n_outer_axes: int = 1) -> Program:
    """The butterfly recursion at pod granularity: all inner-axis bits
    reduce-scatter first (fast links), the outer/pod bits all-reduce in the
    middle on 1/inner_world of the bytes (slow links), inner bits gather
    last.  Same algebra as the butterfly — only the bit order and the link
    tier of the middle steps change."""
    L = _check_pow2(shape)
    world = 1 << L
    if len(shape) <= n_outer_axes:
        return butterfly_all_reduce(shape)._replace_name("hierarchical")
    width = [int(math.log2(d)) for d in shape]
    offset = [sum(width[a + 1:]) for a in range(len(shape))]
    inner_axes = list(range(n_outer_axes, len(shape)))
    outer_axes = list(range(n_outer_axes))
    bits, axes, tiers = [], [], []
    for a in reversed(inner_axes):       # innermost first
        for b in range(width[a]):
            bits.append(offset[a] + b)
            axes.append(a)
            tiers.append(TIER_INNER)
    for a in reversed(outer_axes):
        for b in range(width[a]):
            bits.append(offset[a] + b)
            axes.append(a)
            tiers.append(TIER_OUTER)
    steps = _butterfly_steps(world, world, bits, tiers, axes)
    return Program("hierarchical", shape, world, tuple(steps))


def _ring_steps(ranks: Sequence[int], blocks: Sequence[Tuple[int, ...]],
                axis: Optional[int], tier: str) -> List[Step]:
    """Ring reduce-scatter + all-gather among ``ranks`` (in ring order),
    with ``blocks[j]`` the chunk block member j eventually owns+1."""
    k = len(ranks)
    rs: List[List[Transfer]] = [[] for _ in range(k - 1)]
    ag: List[List[Transfer]] = [[] for _ in range(k - 1)]
    for s in range(k - 1):
        for j in range(k):
            nxt = (j + 1) % k
            rs[s].append(Transfer(ranks[j], ranks[nxt],
                                  blocks[(j - s) % k], reduce=True))
            ag[s].append(Transfer(ranks[j], ranks[nxt],
                                  blocks[(j + 1 - s) % k], reduce=False))
    return [Step(tuple(ts), axis=axis, tier=tier) for ts in rs + ag]


def _contiguous_blocks(n_chunks: int, k: int) -> List[Tuple[int, ...]]:
    size = n_chunks // k
    return [tuple(range(j * size, (j + 1) * size)) for j in range(k)]


def ring_all_reduce(shape: Shape) -> Program:
    """Flat bandwidth-optimal ring over the whole world: 2(N−1) steps of
    V/N-sized chunks between flat-rank neighbors.  (Any world size — the
    ring does not need the power-of-two structure the tree schedules do.)"""
    world = math.prod(shape)
    if world == 1:
        return Program("ring", shape, 1, ())
    blocks = _contiguous_blocks(world, world)
    steps = _ring_steps(list(range(world)), blocks, axis=None,
                        tier=TIER_INNER)
    # interleave RS and AG metadata is already positional; merge into steps
    return Program("ring", shape, world, tuple(steps))


def xy_all_reduce(shape: Shape) -> Program:
    """The paper's XY baseline: dimension-ordered ring all-reduce — a full
    ring along the innermost axis within each line, then along each outer
    axis in turn.  Latency O(Σ axis sizes), bandwidth 2V·Σ (k−1)/k."""
    world = math.prod(shape)
    if world == 1:
        return Program("xy", shape, 1, ())
    n_chunks = world
    steps: List[Step] = []
    # innermost axis first, then outward — matches collectives.all_reduce
    for a in range(len(shape) - 1, -1, -1):
        k = shape[a]
        if k == 1:
            continue
        blocks = _contiguous_blocks(n_chunks, k)
        # one ring per line of constant other-coordinates
        lines: List[List[int]] = []
        for r in range(world):
            coords = rank_coords(shape, r)
            if coords[a] == 0:
                line = [coords_rank(shape, coords[:a] + (c,) + coords[a + 1:])
                        for c in range(k)]
                lines.append(line)
        # merge the per-line ring steps positionally (lines are disjoint)
        merged: List[List[Transfer]] = [[] for _ in range(2 * (k - 1))]
        for line in lines:
            for i, st in enumerate(_ring_steps(line, blocks, a, TIER_INNER)):
                merged[i].extend(st.transfers)
        steps.extend(Step(tuple(ts), axis=a, tier=TIER_INNER)
                     for ts in merged)
    return Program("xy", shape, n_chunks, tuple(steps))


def naive_all_reduce(shape: Shape) -> Program:
    """The paper's Naïve baseline: every contribution serially funneled into
    rank 0's port (N−1 full-payload steps), then serially broadcast back.
    O(N) steps each moving the whole V — the quadratic-cost scheme."""
    world = math.prod(shape)
    if world == 1:
        return Program("naive", shape, 1, ())
    all_chunks = tuple(range(world))
    steps = [Step((Transfer(s, 0, all_chunks, reduce=True),))
             for s in range(1, world)]
    steps += [Step((Transfer(0, s, all_chunks, reduce=False),))
              for s in range(1, world)]
    return Program("naive", shape, world, tuple(steps))


def tree_all_reduce(shape: Shape) -> Program:
    """Two-phase tree reduce-broadcast (beyond-paper; SynCron-style): phase 1
    reduces the full payload up the H-tree (only subtree masters active),
    phase 2 broadcasts the result back down.  2·log2(N) steps like the
    butterfly but O(V·log N) bytes — latency-optimal, bandwidth-greedy."""
    L = _check_pow2(shape)
    world = 1 << L
    bits = tree_bit_positions(shape)
    tree = FractalTree(shape)
    all_chunks = tuple(range(world))
    steps: List[Step] = []
    for i, p in enumerate(bits):    # reduce up: child with bit set → master
        transfers = tuple(
            Transfer(r | (1 << p), r, all_chunks, reduce=True)
            for r in range(world)
            if _bit(r, p) == 0 and all(_bit(r, q) == 0 for q in bits[:i]))
        steps.append(Step(transfers, level=i + 1,
                          axis=tree.levels[i].axis))
    for i in reversed(range(L)):    # broadcast down: master → child
        p = bits[i]
        transfers = tuple(
            Transfer(r, r | (1 << p), all_chunks, reduce=False)
            for r in range(world)
            if _bit(r, p) == 0 and all(_bit(r, q) == 0 for q in bits[:i]))
        steps.append(Step(transfers, level=i + 1,
                          axis=tree.levels[i].axis))
    return Program("tree", shape, world, tuple(steps))


def _replace_name(self: Program, name: str) -> Program:
    return Program(name, self.shape, self.n_chunks, self.steps, self.kind,
                   self.bucket)


Program._replace_name = _replace_name  # small private helper


# ---------------------------------------------------------------------------
# builders: barrier (token) programs
# ---------------------------------------------------------------------------


def butterfly_barrier(shape: Shape, level: Optional[int] = None) -> Program:
    """fsync(level) as IR: recursive doubling of a unit token over the first
    ``level`` tree levels (None → root = whole world)."""
    L = _check_pow2(shape)
    level = L if level is None else level
    if not 0 <= level <= L:
        raise ScheduleError(f"fsync level {level} outside 0..{L}")
    world = 1 << L
    bits = tree_bit_positions(shape)[:level]
    tree = FractalTree(shape)
    steps = [
        Step(tuple(Transfer(r, r ^ (1 << p), (0,), reduce=True)
                   for r in range(world)),
             level=i + 1, axis=tree.levels[i].axis)
        for i, p in enumerate(bits)
    ]
    return Program("fractal_barrier", shape, 1, tuple(steps), kind=BARRIER)


def naive_barrier(shape: Shape) -> Program:
    """Centralized AMO barrier topology: all tiles gather at the master,
    release fans back out (the simulator adds the counter/poll protocol)."""
    world = math.prod(shape)
    gather = Step(tuple(Transfer(r, 0, (0,), reduce=True)
                        for r in range(1, world)), level=1)
    release = Step(tuple(Transfer(0, r, (0,), reduce=False)
                         for r in range(1, world)), level=1)
    return Program("naive_barrier", shape, 1, (gather, release), kind=BARRIER)


def xy_barrier(shape: Shape) -> Program:
    """Dimension-ordered barrier topology: lines gather on line-masters
    (innermost axis), line-masters gather on the global master, release
    cascades back — the paper's XY scheme as a 2-level gather tree."""
    if len(shape) < 2:
        return naive_barrier(shape)._replace_name("xy_barrier")
    rows, cols = as_2d(shape)
    world = rows * cols

    def flat(r, c):
        return r * cols + c

    up1 = Step(tuple(Transfer(flat(r, c), flat(r, 0), (0,), reduce=True)
                     for r in range(rows) for c in range(1, cols)), level=1,
               axis=len(shape) - 1)
    up2 = Step(tuple(Transfer(flat(r, 0), 0, (0,), reduce=True)
                     for r in range(1, rows)), level=2, axis=0)
    down2 = Step(tuple(Transfer(0, flat(r, 0), (0,), reduce=False)
                       for r in range(1, rows)), level=2, axis=0)
    down1 = Step(tuple(Transfer(flat(r, 0), flat(r, c), (0,), reduce=False)
                       for r in range(rows) for c in range(1, cols)), level=1,
                 axis=len(shape) - 1)
    return Program("xy_barrier", shape, 1, (up1, up2, down2, down1),
                   kind=BARRIER)


def tree_barrier(shape: Shape, level: Optional[int] = None) -> Program:
    """H-tree barrier as a gather tree (masters only) — the software shape
    of the paper's dedicated FS-module tree, and the topology SynCron-style
    hierarchical AMO synchronization uses."""
    L = _check_pow2(shape)
    level = L if level is None else level
    world = math.prod(shape)
    bits = tree_bit_positions(shape)[:level]
    tree = FractalTree(shape)
    steps: List[Step] = []
    for i, p in enumerate(bits):
        steps.append(Step(tuple(
            Transfer(r | (1 << p), r, (0,), reduce=True)
            for r in range(world)
            if _bit(r, p) == 0 and all(_bit(r, q) == 0 for q in bits[:i])),
            level=i + 1, axis=tree.levels[i].axis))
    for i in reversed(range(len(bits))):
        p = bits[i]
        steps.append(Step(tuple(
            Transfer(r, r | (1 << p), (0,), reduce=False)
            for r in range(world)
            if _bit(r, p) == 0 and all(_bit(r, q) == 0 for q in bits[:i])),
            level=i + 1, axis=tree.levels[i].axis))
    return Program("tree_barrier", shape, 1, tuple(steps), kind=BARRIER)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BUILDERS = {
    "fractal": butterfly_all_reduce,
    "ring": ring_all_reduce,
    "xy": xy_all_reduce,
    "naive": naive_all_reduce,
    "hierarchical": hierarchical_all_reduce,
    "tree": tree_all_reduce,
}

BARRIER_BUILDERS = {
    "fractal": butterfly_barrier,
    "naive": naive_barrier,
    "xy": xy_barrier,
    "tree": tree_barrier,
}

SCHEDULES = tuple(BUILDERS)


@lru_cache(maxsize=256)
def build_program(schedule: str, shape: Shape) -> Program:
    """Build + validate the named all-reduce schedule for a mesh shape."""
    if schedule not in BUILDERS:
        raise ScheduleError(
            f"unknown schedule {schedule!r}; one of {SCHEDULES}")
    prog = BUILDERS[schedule](tuple(shape))
    validate(prog)
    return prog


# ---------------------------------------------------------------------------
# validation: structural invariants + contribution-set abstract interpretation
# ---------------------------------------------------------------------------


def validate(prog: Program) -> Dict[str, float]:
    """Check the program is executable and *means* an all-reduce/barrier.

    Structural (all_reduce kind — what one ppermute per step can express):
      * per step, every rank sends at most one message and receives at most
        one;
      * all transfers within a step move the same number of chunks;
      * chunk ids are within range and distinct per transfer.

    Semantic (contribution sets): start rank r with {r} on every chunk;
    reduces must merge *disjoint* sets (double-count = wrong sum), copies
    overwrite; at the end every rank's every chunk must hold the full set
    (for barrier kind: token knowledge must reach everyone — disjointness
    is waived because token counting is idempotent for the barrier's
    purpose).

    Returns summary stats used by tests and the autotuner.
    """
    world = prog.world
    n = prog.n_chunks
    full = frozenset(range(world))
    # state[r][c] = set of source ranks whose contribution is in chunk c at r
    state = [[frozenset([r]) for _ in range(n)] for r in range(world)]
    for si, step in enumerate(prog.steps):
        seen_src: Dict[int, int] = {}
        seen_dst: Dict[int, int] = {}
        sizes = set()
        staged: List[Tuple[Transfer, List[frozenset]]] = []
        for t in step.transfers:
            if not (0 <= t.src < world and 0 <= t.dst < world):
                raise ScheduleError(f"step {si}: rank out of range in {t}")
            if t.src == t.dst:
                raise ScheduleError(f"step {si}: self-send in {t}")
            if len(set(t.chunks)) != len(t.chunks):
                raise ScheduleError(f"step {si}: duplicate chunk ids in {t}")
            if any(not 0 <= c < n for c in t.chunks):
                raise ScheduleError(f"step {si}: chunk id out of range in {t}")
            if prog.kind == ALL_REDUCE:
                if t.src in seen_src:
                    raise ScheduleError(
                        f"step {si}: rank {t.src} sends twice")
                if t.dst in seen_dst:
                    raise ScheduleError(
                        f"step {si}: rank {t.dst} receives twice")
            seen_src[t.src] = seen_src.get(t.src, 0) + 1
            seen_dst[t.dst] = seen_dst.get(t.dst, 0) + 1
            sizes.add(t.n_chunks_moved)
            # snapshot sender state: all sends in a step happen before any
            # receive lands (BSP semantics within the step)
            staged.append((t, [state[t.src][c] for c in t.chunks]))
        if prog.kind == ALL_REDUCE and len(sizes) > 1:
            raise ScheduleError(
                f"step {si}: nonuniform transfer sizes {sorted(sizes)} "
                "(a single ppermute needs same-shaped operands)")
        for t, payload in staged:
            for c, contrib in zip(t.chunks, payload):
                if t.reduce:
                    if prog.kind == ALL_REDUCE and state[t.dst][c] & contrib:
                        raise ScheduleError(
                            f"step {si}: double-counted contribution "
                            f"{sorted(state[t.dst][c] & contrib)} into "
                            f"chunk {c} at rank {t.dst}")
                    state[t.dst][c] = state[t.dst][c] | contrib
                else:
                    state[t.dst][c] = contrib
    if prog.kind == ALL_REDUCE:
        for r in range(world):
            for c in range(n):
                if state[r][c] != full:
                    raise ScheduleError(
                        f"incomplete all-reduce: rank {r} chunk {c} has "
                        f"{len(state[r][c])}/{world} contributions")
    else:
        for r in range(world):
            if state[r][0] != full:
                raise ScheduleError(
                    f"incomplete barrier: rank {r} knows only "
                    f"{len(state[r][0])}/{world} ranks")
    fracs = prog.per_rank_frac_sent()
    return {
        "steps": prog.num_steps,
        "messages": sum(len(s.transfers) for s in prog.steps),
        "max_frac_sent": max(fracs.values(), default=0.0),
        "sum_step_frac": sum(
            s.max_chunks_moved / prog.n_chunks for s in prog.steps),
    }
