"""Bulk Synchronous Parallel superstep runtime (paper §1, Valiant's BSP).

BSP structures parallel execution as *supersteps*: (1) local computation,
(2) communication, (3) global barrier.  The paper's whole point is making (3)
cheap and scalable; its only synchronization primitive is the barrier.

This module gives the training/serving stack a BSP-shaped API whose
communication phase runs one of the FractalSync-family schedules:

  * ``sync_gradients``  — flatten a gradient pytree, pad, all-reduce with the
    configured schedule (fractal | ring | xy | naive | hierarchical | xla),
    optionally compressing exchanged payloads, then mean + unflatten.
  * ``superstep``       — compute → communicate → fsync barrier, with the
    barrier token tied into the outputs (``barrier_tie``) so XLA cannot blur
    the superstep boundary.

Everything here runs *inside* ``shard_map`` over the sync axes; the "model"
axis stays in GSPMD's hands (``auto``), which is how per-rank local compute
keeps its tensor parallelism.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple, Union

import jax

from repro import compat

from . import collectives
from .barrier import barrier_tie
from .collectives import fractal_barrier
from .cost_model import LinkParams


@dataclass(frozen=True)
class BSPConfig:
    """How a BSP step synchronizes.

    sync_axes   : mesh axes forming the synchronization tree, outermost first
                  (e.g. ("pod","data")); their product is the BSP world.
    schedule    : gradient all-reduce schedule (see collectives.SCHEDULES),
                  or "auto" — the cost-model autotuner picks at trace/build
                  time (core.autotune), per bucket when bucketing is on.
    compression : payload codec for the fractal schedule ("none"|"bf16"|"int8").
    fsync_level : barrier scope (None = root = whole world); lower levels
                  synchronize only a subtree (paper §3.2 domains).
    pad_align   : flat gradient vector padded to lcm(world, pad_align) so the
                  halving steps stay lane-aligned on TPU (128 lanes).
    bucket_mb   : partition the gradient pytree into ~this many MB per
                  bucket (reverse-layer order) and pipeline one collective
                  per bucket (core.superstep.SuperstepEngine); None → one
                  monolithic bucket (the pre-engine behavior); "auto" →
                  bucket boundaries searched by dynamic programming over
                  leaf prefix sums against the overlap-aware cost model
                  (greedy packing kept as the DP's upper bound/fallback).
    overlap     : the bucketing A/B switch — False disables bucketing even
                  when bucket_mb is set, collapsing the superstep back to
                  the monolithic single-collective baseline.
    bucket_codec: per-bucket wire-compression policy.  None → every bucket
                  uses the uniform ``compression`` codec (the historical
                  behavior); "auto" → the autotuner picks a codec PER
                  BUCKET through the cost model (large bandwidth-bound
                  buckets compress harder, small latency-bound tail buckets
                  skip compression); an explicit codec name forces it on
                  every fractal-scheduled bucket (no other lowering has a
                  wire-codec path — non-fractal buckets stay uncompressed).
    link        : cost-model link parameters the autotuner prices with;
                  None → the analytic TPU_V5E_ICI defaults.  Pass fitted
                  params from ``core.calibrate.fit_link_params`` (the train
                  CLI's ``--calibrate``) to tune against measured platform
                  numbers.
    """

    sync_axes: Tuple[str, ...] = ("data",)
    schedule: str = "fractal"
    compression: str = "none"
    fsync_level: Optional[int] = None
    pad_align: int = 128
    bucket_mb: Union[float, str, None] = None
    overlap: bool = True
    bucket_codec: Optional[str] = None
    link: Optional[LinkParams] = None

    def __post_init__(self):
        if self.schedule != "auto" and \
                self.schedule not in collectives.SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if isinstance(self.bucket_mb, str):
            if self.bucket_mb != "auto":
                raise ValueError(f"bucket_mb must be a positive size in MB, "
                                 f"None, or 'auto'; got {self.bucket_mb!r}")
        elif self.bucket_mb is not None and self.bucket_mb <= 0:
            raise ValueError(f"bucket_mb must be positive, "
                             f"got {self.bucket_mb}")
        if self.bucket_codec not in (None, "auto", "none", "bf16", "int8"):
            raise ValueError(f"unknown bucket_codec {self.bucket_codec!r}")


def _world(sizes: Sequence[int]) -> int:
    return math.prod(sizes)


def make_codec(name: str):
    if name in (None, "none"):
        return None
    from repro.optim.compression import Bf16Codec, Int8Codec
    if name == "bf16":
        return Bf16Codec()
    if name == "int8":
        return Int8Codec()
    raise ValueError(f"unknown compression {name!r}")


def resolve_schedule(cfg: BSPConfig, sizes: Sequence[int],
                     payload_bytes: float) -> str:
    """Concrete schedule name for this config: "auto" → autotuner pick.

    Everything involved is host-static (mesh shape, padded flat length), so
    this is safe to call at trace/build time.
    """
    if cfg.schedule != "auto":
        return cfg.schedule
    from .autotune import pick_schedule
    if cfg.link is not None:
        return pick_schedule(tuple(sizes), payload_bytes, link=cfg.link)
    return pick_schedule(tuple(sizes), payload_bytes)


def sync_gradients(grads, cfg: BSPConfig, sizes: Sequence[int],
                   mean: bool = True):
    """All-reduce a gradient pytree with the configured schedule.

    Must be called inside ``shard_map`` over ``cfg.sync_axes``.  Returns the
    synchronized pytree (mean over the BSP world by default).

    Routed through the SuperstepEngine (``core.superstep``): with
    ``cfg.bucket_mb`` unset this is one monolithic bucket (the historical
    behavior); with it set, one pipelined collective per size-bounded
    bucket, schedule autotuned per bucket when ``schedule="auto"``.
    """
    world = _world(sizes)
    if world == 1:
        return grads
    from .superstep import engine_for
    return engine_for(grads, cfg, sizes).sync(grads, mean=mean)


def superstep(compute: Callable, communicate: Callable, cfg: BSPConfig,
              sizes: Sequence[int]):
    """Build one BSP superstep: local compute → communicate → fsync barrier.

    ``compute(*args)`` runs rank-local work; ``communicate(result)`` runs the
    communication phase (e.g. ``sync_gradients``); the returned callable ties
    the fsync token into every output leaf so the barrier orders supersteps.
    """

    def step(*args):
        local = compute(*args)
        exchanged = communicate(local)
        token = fractal_barrier(cfg.sync_axes, sizes, level=cfg.fsync_level)
        return jax.tree.map(lambda leaf: barrier_tie(leaf, token), exchanged)

    return step


def bsp_shard_map(fn: Callable, mesh: jax.sharding.Mesh,
                  in_specs, out_specs, sync_axes: Tuple[str, ...],
                  auto_axes: Tuple[str, ...] = ("model",)):
    """shard_map over the sync axes with the remaining axes left to GSPMD.

    This is the composition that lets the paper's explicit synchronization
    schedule coexist with XLA-managed tensor parallelism inside each rank.
    In jax 0.8 ``axis_names`` lists the axes shard_map handles *manually*;
    every other mesh axis (e.g. "model") stays auto (GSPMD).
    """
    del auto_axes  # everything not in sync_axes is auto by construction
    return compat.shard_map(fn, mesh, in_specs, out_specs,
                            check_vma=False,
                            axis_names=frozenset(sync_axes))
