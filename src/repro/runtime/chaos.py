"""Deterministic fault injection for soak runs (serve AND train).

A ``FaultPlan`` is a seedable, fully deterministic schedule of faults on
the virtual step clock, parsed from a compact spec string (the
``--fault-plan`` CLI surface) or generated randomly from a seed.  Both
loops consume the same plan object:

  * the TRAIN soak (``runtime/soak.py``) asks it which rank dies at which
    step (heartbeats stop → ``HostMonitor`` timeout → ``WorkerFailure``),
    which ranks run slow by what factor (fed into ``per_rank_step_s`` →
    ``StragglerTracker`` → actuated micro-batch rebalance), and which
    heartbeats to drop/duplicate;
  * the SERVE soak (``serve/soak.py``) asks it when admission stalls
    (``ServeEngine.hold_admission``) and when the block pool comes under
    external pressure (a fraction of blocks held hostage).

Spec grammar — ';'-separated events, each ``kind:key=value,...``:

  kill:rank=R,step=S            rank R's heartbeats stop at step S
  slow:rank=R,factor=F,steps=A..B   rank R runs F× slower for steps [A,B)
  drop_hb:host=H,steps=A..B     host H's heartbeats are lost in [A,B)
  dup_hb:host=H,step=S          host H heartbeats twice at step S
  stall:steps=A..B              serve admission stalls for steps [A,B)
  blocks:frac=F,steps=A..B      F of the KV block pool held in [A,B)

``StepClock`` is the train-side virtual clock: ``tick()`` advances one
virtual step, ``now()`` reads it — injected into ``HostMonitor`` so
heartbeat-timeout failure detection is deterministic in CI (no
``time.monotonic()`` anywhere in a soak run).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

KINDS = ("kill", "slow", "drop_hb", "dup_hb", "stall", "blocks")


@dataclass
class StepClock:
    """Virtual step clock: one ``tick()`` per superstep/engine step."""

    step_s: float = 1.0
    t: float = 0.0

    def tick(self, n: int = 1) -> None:
        self.t += n * self.step_s

    def now(self) -> float:
        return self.t

    # HostMonitor takes any zero-arg callable
    def __call__(self) -> float:
        return self.now()


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``step``/``step_end`` bound the half-open
    window [step, step_end); point events have ``step_end == step + 1``."""

    kind: str
    step: int
    step_end: int
    rank: int = -1          # rank/host the event targets (-1: n/a)
    factor: float = 1.0     # slow: slowdown ×; blocks: pool fraction

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.step < 0 or self.step_end <= self.step:
            raise ValueError(
                f"{self.kind}: bad window [{self.step},{self.step_end})")
        if self.kind in ("kill", "slow", "drop_hb", "dup_hb") \
                and self.rank < 0:
            raise ValueError(f"{self.kind}: needs a rank/host")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slow: factor must be > 1, got {self.factor}")
        if self.kind == "blocks" and not 0.0 < self.factor <= 1.0:
            raise ValueError(f"blocks: frac must be in (0,1], "
                             f"got {self.factor}")

    def spec(self) -> str:
        """Round-trippable spec string for this event."""
        win = (f"steps={self.step}..{self.step_end}"
               if self.step_end != self.step + 1 else f"step={self.step}")
        if self.kind == "kill":
            return f"kill:rank={self.rank},step={self.step}"
        if self.kind == "slow":
            return (f"slow:rank={self.rank},factor={self.factor:g},"
                    f"steps={self.step}..{self.step_end}")
        if self.kind == "drop_hb":
            return f"drop_hb:host={self.rank},steps={self.step}.." \
                   f"{self.step_end}"
        if self.kind == "dup_hb":
            return f"dup_hb:host={self.rank},step={self.step}"
        if self.kind == "stall":
            return f"stall:steps={self.step}..{self.step_end}"
        return f"blocks:frac={self.factor:g},steps={self.step}.." \
               f"{self.step_end}"


def _parse_window(kv: Dict[str, str], kind: str) -> Tuple[int, int]:
    if "steps" in kv:
        a, _, b = kv["steps"].partition("..")
        if not b:
            raise ValueError(f"{kind}: steps needs A..B, got {kv['steps']!r}")
        return int(a), int(b)
    if "step" in kv:
        s = int(kv["step"])
        return s, s + 1
    raise ValueError(f"{kind}: needs step=S or steps=A..B")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, queryable schedule of fault events."""

    events: Tuple[FaultEvent, ...] = ()

    # -- construction -----------------------------------------------------
    @staticmethod
    def parse(spec: str) -> "FaultPlan":
        """Parse ';'-separated event specs (empty string → empty plan)."""
        events: List[FaultEvent] = []
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            kind, _, body = raw.partition(":")
            kind = kind.strip()
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in {raw!r} "
                                 f"(one of {', '.join(KINDS)})")
            kv: Dict[str, str] = {}
            for item in body.split(","):
                item = item.strip()
                if not item:
                    continue
                k, eq, v = item.partition("=")
                if not eq:
                    raise ValueError(f"{raw!r}: expected key=value, "
                                     f"got {item!r}")
                kv[k.strip()] = v.strip()
            step, step_end = _parse_window(kv, kind)
            rank = int(kv.get("rank", kv.get("host", -1)))
            factor = float(kv.get("factor", kv.get("frac", 1.0)))
            events.append(FaultEvent(kind=kind, step=step, step_end=step_end,
                                     rank=rank, factor=factor))
        return FaultPlan(tuple(events))

    @staticmethod
    def random(seed: int, steps: int, ranks: int,
               n_events: int = 3) -> "FaultPlan":
        """A seedable random plan: same (seed, steps, ranks) → same plan.
        Draws slow/stall/blocks windows plus at most one kill, all inside
        [steps//4, 3·steps//4) so the soak keeps pre-fault baseline and
        post-fault recovery room."""
        rng = np.random.default_rng(seed)
        lo, hi = max(1, steps // 4), max(2, 3 * steps // 4)
        events: List[FaultEvent] = []
        kinds = ["slow", "stall", "blocks", "kill"]
        for i in range(n_events):
            kind = kinds[int(rng.integers(0, len(kinds)))] if i else "slow"
            a = int(rng.integers(lo, hi))
            b = min(hi, a + int(rng.integers(2, max(3, steps // 8))))
            if kind == "kill":
                events.append(FaultEvent("kill", a, a + 1,
                                         rank=int(rng.integers(0, ranks))))
            elif kind == "slow":
                events.append(FaultEvent(
                    "slow", a, b, rank=int(rng.integers(0, ranks)),
                    factor=float(1.5 + 2.0 * rng.random())))
            elif kind == "stall":
                events.append(FaultEvent("stall", a, b))
            else:
                events.append(FaultEvent(
                    "blocks", a, b,
                    factor=float(0.25 + 0.5 * rng.random())))
        return FaultPlan(tuple(events))

    def spec(self) -> str:
        return ";".join(e.spec() for e in self.events)

    # -- train-side queries -----------------------------------------------
    def kills_at(self, step: int) -> Set[int]:
        return {e.rank for e in self.events
                if e.kind == "kill" and e.step == step}

    def killed_by(self, step: int) -> Set[int]:
        """Ranks whose kill step is ≤ ``step`` (dead from then on)."""
        return {e.rank for e in self.events
                if e.kind == "kill" and e.step <= step}

    def slow_factor(self, rank: int, step: int) -> float:
        f = 1.0
        for e in self.events:
            if e.kind == "slow" and e.rank == rank \
                    and e.step <= step < e.step_end:
                f = max(f, e.factor)
        return f

    def heartbeat_dropped(self, host: int, step: int) -> bool:
        return any(e.kind == "drop_hb" and e.rank == host
                   and e.step <= step < e.step_end for e in self.events)

    def heartbeat_duplicated(self, host: int, step: int) -> bool:
        return any(e.kind == "dup_hb" and e.rank == host
                   and e.step <= step < e.step_end for e in self.events)

    # -- serve-side queries -----------------------------------------------
    def admission_stalled(self, step: int) -> bool:
        return any(e.kind == "stall" and e.step <= step < e.step_end
                   for e in self.events)

    def block_pressure(self, step: int) -> float:
        """Fraction of the block pool under external pressure at ``step``
        (0.0 when no ``blocks`` window covers it)."""
        f = 0.0
        for e in self.events:
            if e.kind == "blocks" and e.step <= step < e.step_end:
                f = max(f, e.factor)
        return f

    # -- window accounting (SLO recovery asserts on these) ----------------
    def fault_windows(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted((e.step, e.step_end) for e in self.events))

    def first_fault_start(self) -> Optional[int]:
        return min((e.step for e in self.events), default=None)

    def last_fault_end(self) -> Optional[int]:
        return max((e.step_end for e in self.events), default=None)

    def __bool__(self) -> bool:
        return bool(self.events)
