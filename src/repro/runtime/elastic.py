"""Elastic re-meshing: resume on the largest surviving fsync domain.

Recovery flow (exercised end-to-end in tests/test_elastic.py on host
devices):

  1. ``HostMonitor`` reports failed hosts → failed mesh tiles.
  2. ``surviving_domain`` (fault_tolerance) picks the largest complete
     synchronization subtree with no failed member — the paper's fsync
     domains make this a *structural* choice, not an ad-hoc one: the domain
     is exactly a node of the H-tree, so the surviving collective schedule
     is the same fractal schedule at a lower level.
  3. A new (smaller, power-of-two) mesh is built from the surviving devices;
     parameters are restored from the latest checkpoint into the new
     shardings; the data pipeline is re-sharded (global batch preserved by
     raising per-rank accumulation).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.compat import HAS_AXIS_TYPE, AxisType
from repro.core.tree import FractalTree
from repro.runtime.fault_tolerance import surviving_domain

Coord = Tuple[int, ...]


@dataclass(frozen=True)
class ElasticPlan:
    level: int                    # fsync level of the surviving domain
    tiles: Tuple[Coord, ...]      # surviving mesh coordinates
    mesh_shape: Tuple[int, ...]
    grad_accum_scale: int         # × gradient accumulation to keep batch

    @property
    def world(self) -> int:
        return len(self.tiles)


def plan_recovery(tree: FractalTree, failed: Iterable[Coord],
                  old_world: Optional[int] = None) -> ElasticPlan:
    level, tiles = surviving_domain(tree, failed)
    world = len(tiles)
    old_world = old_world or tree.num_tiles
    # keep global batch: each survivor takes old_world/world × the work
    scale = max(1, old_world // max(world, 1))
    # shape the new mesh as square-ish powers of two (data × model kept by
    # caller; here we only report the domain geometry)
    rows = 1 << (int(math.log2(world)) // 2)
    cols = world // rows
    return ElasticPlan(level=level, tiles=tiles, mesh_shape=(rows, cols),
                       grad_accum_scale=scale)


def build_mesh_from_tiles(tree: FractalTree, tiles: Sequence[Coord],
                          axis_names: Tuple[str, ...] = ("data", "model"),
                          devices=None,
                          mesh_shape: Optional[Tuple[int, ...]] = None):
    """Mesh over the surviving devices (device order follows tile order).

    ``mesh_shape`` overrides the square-ish default — e.g. ``(world, 1)``
    keeps all survivors on the data axis so the BSP sync domain stays the
    whole surviving fsync subtree (the train-soak recovery path).
    """
    devices = list(devices if devices is not None else jax.devices())
    flat_ids = []
    shape = tree.shape
    for t in tiles:
        flat = 0
        for c, d in zip(t, shape):
            flat = flat * d + c
        flat_ids.append(flat)
    world = len(tiles)
    if mesh_shape is None:
        plan = plan_recovery(tree,
                             [t for t in tree.tiles() if t not in set(tiles)])
        mesh_shape = plan.mesh_shape
    if math.prod(mesh_shape) != world:
        raise ValueError(f"mesh_shape {mesh_shape} does not cover "
                         f"{world} surviving tiles")
    if len(mesh_shape) != len(axis_names):
        raise ValueError(f"mesh_shape {mesh_shape} needs one entry per axis "
                         f"name {axis_names}")
    dev = np.array([devices[i] for i in flat_ids]).reshape(mesh_shape)
    if HAS_AXIS_TYPE:
        return jax.sharding.Mesh(dev, axis_names=axis_names,
                                 axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.sharding.Mesh(dev, axis_names=axis_names)


def reshard_state(state, mesh, spec_tree):
    """Re-place a (restored) host-side state onto the new mesh."""
    from repro.models.sharding import named
    shardings = named(mesh, spec_tree)
    return jax.device_put(state, shardings)
