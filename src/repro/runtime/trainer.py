"""BSP training step builders.

Two tiers (DESIGN.md §4):

  * ``make_gspmd_train_step`` — jit + GSPMD: parameters FSDP×TP sharded
    (ZeRO-3 style), gradient reduction scheduled by XLA.  This is the
    baseline every (arch × shape) dry-run cell uses.

  * ``make_bsp_train_step`` — the paper's technique as a first-class feature:
    the whole step runs inside ``shard_map`` with the DP axes *manual* and the
    model axis auto (TP stays GSPMD).  Parameters are DP-replicated; gradients
    are partitioned by the SuperstepEngine into reverse-layer buckets and
    pipelined through explicit FractalSync-family schedules — one collective
    per bucket, autotuned per bucket under ``schedule="auto"``, ± payload
    compression; optimizer moments are ZeRO-1 sharded per bucket — each BSP
    rank updates 1/world of every bucket between its reduce-scatter and
    all-gather (the bandwidth-optimal H-tree form), then a single fsync
    barrier closes the superstep.  ``grad_accum`` splits the rank batch into
    micro-batches (the knob elastic re-meshing scales to preserve the global
    batch).
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import collectives as C
from repro.core import superstep
from repro.core.barrier import barrier_tie
from repro.core.bsp import BSPConfig, bsp_shard_map
from repro.models import act_sharding as ACT
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import error_feedback_step


# ---------------------------------------------------------------------------
# Tier A: GSPMD (baseline for all dry-run cells)
# ---------------------------------------------------------------------------


def make_gspmd_train_step(cfg: ArchConfig, mesh: Mesh,
                          acfg: adamw.AdamWConfig):
    """jit'd (params, opt_state, batch) → (params, opt_state, metrics)."""
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = False

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, om = adamw.apply_updates(params, grads, opt_state,
                                                    acfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh)
    oshape = jax.eval_shape(lambda: adamw.init(pshape, acfg))
    ospec = adamw.AdamWState(step=P(), mu=pspec, nu=pspec)
    bspec_all = SH.batch_spec(mesh)
    bspec = {"tokens": bspec_all["tokens"], "labels": bspec_all["labels"]}
    if cfg.frontend:
        bspec["frontend"] = bspec_all["frontend"]

    n = lambda s: SH.named(mesh, s)
    step = jax.jit(
        train_step,
        in_shardings=(n(pspec), n(ospec), n(bspec)),
        out_shardings=(n(pspec), n(ospec), None),
        donate_argnums=(0, 1),
    )
    return step, (pspec, ospec, bspec)


# ---------------------------------------------------------------------------
# Tier A: serving steps (prefill / decode)
# ---------------------------------------------------------------------------


def _serve_mode(cfg: ArchConfig) -> str:
    """MoE archs serve with pinned weights (TP+EP: tokens move, weights
    stay) — 35-41× on the big-MoE cells; small dense archs keep the FSDP
    layout whose per-layer weight gather is cheaper than 16× the HBM reads
    (measured: musicgen/granite serve_layout variants, EXPERIMENTS §Perf)."""
    return "serve" if cfg.moe else "train"


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = cfg.moe is not None

    def prefill_step(params, tokens, cache, frontend=None):
        return T.prefill(params, cfg, tokens, cache, frontend)

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh, mode=_serve_mode(cfg))
    cshape = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    cspec = SH.cache_specs(cfg, cshape, mesh)
    dp = SH.fsdp_axes(mesh)
    if batch % SH.axis_size(mesh, dp):
        dp = ()
    n = lambda s: SH.named(mesh, s)
    in_sh = [n(pspec), NamedSharding(mesh, P(dp or None, None)), n(cspec)]
    if cfg.frontend:
        in_sh.append(NamedSharding(mesh, P(dp, None, None)))
    step = jax.jit(prefill_step, in_shardings=tuple(in_sh),
                   out_shardings=(None, n(cspec), None),
                   donate_argnums=(2,))
    return step, (pspec, cspec)


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = cfg.moe is not None

    def serve_step(params, token, cache, offset):
        logits, cache = T.decode_step(params, cfg, token, cache, offset)
        return logits, cache

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh, mode=_serve_mode(cfg))
    cshape = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    cspec = SH.cache_specs(cfg, cshape, mesh)
    dp = SH.fsdp_axes(mesh)
    if batch % SH.axis_size(mesh, dp):
        dp = ()                      # long_500k: global batch 1
    n = lambda s: SH.named(mesh, s)
    step = jax.jit(
        serve_step,
        in_shardings=(n(pspec), NamedSharding(mesh, P(dp or None, None)),
                      n(cspec), NamedSharding(mesh, P())),
        out_shardings=(None, n(cspec)),
        donate_argnums=(2,),
    )
    return step, (pspec, cspec)


# ---------------------------------------------------------------------------
# Tier B: explicit BSP superstep (the paper's technique, first-class)
# ---------------------------------------------------------------------------


@dataclass
class BSPTrainState:
    params: Any                # DP-replicated pytree (TP-sharded on "model")
    flat_mu: jax.Array         # ZeRO-1: this rank's shard of flat moments
    flat_nu: jax.Array
    ef_residual: Optional[jax.Array]   # error-feedback state (compression)
    step: jax.Array


def make_bsp_train_step(cfg: ArchConfig, mesh: Mesh, acfg: adamw.AdamWConfig,
                        bsp: BSPConfig, grad_accum: int = 1,
                        shares: Optional[Sequence[int]] = None):
    """Explicit-schedule BSP superstep, pipelined over gradient buckets:

      compute:     local fwd/bwd on this rank's micro-batch(es) —
                   ``grad_accum`` > 1 splits the rank batch and accumulates
                   (the knob ElasticPlan.grad_accum_scale raises to keep the
                   global batch after re-meshing)
      communicate: per SuperstepEngine bucket (reverse-layer order, schedule
                   autotuned per bucket under ``schedule="auto"``):
                   flat bucket grads → [EF] → reduce-scatter
      update:      AdamW on this rank's 1/world shard of each bucket (ZeRO-1)
      publish:     all-gather of the updated shards, bucket by bucket
      barrier:     one fsync(level) token closes the whole superstep

    The per-bucket collectives are data-independent, so XLA may overlap
    bucket i's communication with the compute that feeds bucket j>i — the
    structural overlap the monolithic path (one bucket) cannot express.

    ``shares`` (length-world, each ≥ 1) actuates a straggler rebalance:
    rank r runs ``shares[r]`` micro-batches instead of an even split —
    slow ranks genuinely do less work, flattening barrier arrival.  The
    batch must arrive in the padded per-rank layout of
    ``data.pipeline.reshard_for_shares`` (``max(shares)`` micro-batch
    rows per rank; only the first ``shares[r]`` are real).  The global
    gradient is the mean over ``sum(shares)`` micro-batches, weighted
    correctly by construction — AND bit-identical in f32 across every
    share partition of the same micro-batch set: each rank accumulates
    its micro-gradients as a Neumaier compensated pair (value + running
    error), both halves are all-gathered, and every rank sums all
    ``2·world`` components in one fixed canonical order.  The result is
    partition-independent to O(eps²), so uneven and even splits of
    identical data produce byte-identical parameter updates (asserted in
    tests/train_soak_checks.py).  The downstream reduce-scatter then sums
    ``world`` identical copies — exactly ``world × shard`` in floats
    (power-of-two doubling) — and the ``/world`` recovers the combined
    gradient unchanged, so the whole superstep pipeline needs no other
    modification.
    """
    ACT.clear_policy()   # manual-DP body: no data-axis GSPMD constraints
    if grad_accum < 1:
        raise ValueError(f"grad_accum must be >= 1, got {grad_accum}")
    sizes = tuple(mesh.shape[a] for a in bsp.sync_axes)
    world = math.prod(sizes)
    if shares is not None:
        if grad_accum != 1:
            raise ValueError(
                "shares= and grad_accum>1 are mutually exclusive: shares IS "
                "the per-rank micro-batch count")
        shares = tuple(int(s) for s in shares)
        if len(shares) != world:
            raise ValueError(
                f"shares has {len(shares)} entries for world size {world}")
        if any(s < 1 for s in shares):
            raise ValueError(f"every rank needs >= 1 micro-batch: {shares}")

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    # the engine's flat layout is f32 (grads/moments are f32 regardless of
    # param dtype); plan once at build time and log the bucket decisions
    engine = superstep.engine_for(pshape, bsp, sizes,
                                  force_dtype=jnp.float32, zero1=True)
    flat_total = engine.total_padded
    # Per-bucket codec plan: uniform `compression` under bucket_codec=None
    # (the historical EF-then-f32-wire path, bit-for-bit); an explicit
    # bucket_codec additionally wire-compresses the fractal reduce-scatter
    # exchanges of codec'd buckets (per-hop quantization, EF-corrected).
    bucket_codecs = engine.bucket_codecs
    has_codec = any(c is not None for c in bucket_codecs)
    wire_codecs = bucket_codecs if bsp.bucket_codec is not None \
        else (None,) * engine.n_buckets
    print(f"superstep: {engine.describe()} (link={engine.link.name})")
    # fingerprint of the flat moment layout (bucket boundaries × world):
    # checkpoints carry it so a resume under a different --bucket-mb (or a
    # pre-engine moment ordering) fails loudly instead of silently binding
    # moments to the wrong parameter slices (same shape, different layout)
    layout = ",".join(f"{b.offset}+{b.length}" for b in engine.buckets)
    layout_tag = "zero1:" + hashlib.sha1(
        f"w{world}:{layout}".encode()).hexdigest()[:12]
    shard_lens = [engine.shard_len(b) for b in engine.buckets]
    shard_offs = engine.shard_offsets()

    def local_grads(params, batch):
        """loss/metrics/grads for this rank, with optional accumulation.

        Accumulation runs as a ``lax.scan`` over micro-batches so the
        compiled program holds ONE forward/backward regardless of
        ``grad_accum`` — an elastic re-mesh that raises the factor must
        not also inflate recompile time linearly.
        """
        vag = jax.value_and_grad(T.loss_fn, has_aux=True)
        if grad_accum == 1:
            (loss, metrics), grads = vag(params, cfg, batch)
            return loss, metrics, grads
        b_local = jax.tree.leaves(batch)[0].shape[0]
        if b_local % grad_accum:
            raise ValueError(f"per-rank batch {b_local} not divisible by "
                             f"grad_accum {grad_accum}")
        micro = jax.tree.map(
            lambda v: v.reshape((grad_accum, v.shape[0] // grad_accum)
                                + v.shape[1:]), batch)
        first = jax.tree.map(lambda v: v[0], micro)
        rest = jax.tree.map(lambda v: v[1:], micro)
        (loss, metrics), grads = vag(params, cfg, first)

        def body(carry, mb):
            l_a, m_a, g_a = carry
            (l, m), g = vag(params, cfg, mb)
            return (l_a + l, jax.tree.map(jnp.add, m_a, m),
                    jax.tree.map(jnp.add, g_a, g)), None

        (loss, metrics, grads), _ = jax.lax.scan(
            body, (loss, metrics, grads), rest)
        inv = 1.0 / grad_accum
        return (loss * inv, jax.tree.map(lambda v: v * inv, metrics),
                jax.tree.map(lambda v: v * inv, grads))

    def _pair_add(s, e, t):
        """One Neumaier step on the compensated pair (s, e): s' = fl(s+t)
        with the rounding error folded into e — (s'+e') carries the exact
        sum to O(eps²)."""
        x = s + t
        e = e + jnp.where(jnp.abs(s) >= jnp.abs(t),
                          (s - x) + t, (t - x) + s)
        return x, e

    def _tree_pair_add(s_tree, e_tree, t_tree):
        x_tree = jax.tree.map(jnp.add, s_tree, t_tree)
        e_tree = jax.tree.map(
            lambda s, t, x, e: e + jnp.where(jnp.abs(s) >= jnp.abs(t),
                                             (s - x) + t, (t - x) + s),
            s_tree, t_tree, x_tree, e_tree)
        return x_tree, e_tree

    def local_grads_shares(params, batch):
        """Uneven micro-batch accumulation, partition-independent in f32.

        This rank's batch slice is ``max(shares)`` micro-batch rows; a
        ``fori_loop`` with DYNAMIC trip count ``shares[rank]`` runs only
        the real ones (padding rows are never computed), pair-accumulating
        (loss, metrics, grads) in f32.  Both pair halves are all-gathered
        over the sync axes and every rank reduces all ``2·world``
        components in the same canonical order, so the returned global
        means are replicated AND independent of how the micro-batches
        were partitioned.  The cross-rank combine is unrolled over world
        (fine at fsync-domain scale; a fixed-order segmented tree would
        serve thousands of ranks).
        """
        vag = jax.value_and_grad(T.loss_fn, has_aux=True)
        rows = jax.tree.leaves(batch)[0].shape[0]
        n_max, m_total = max(shares), sum(shares)
        if rows % n_max:
            raise ValueError(f"per-rank batch {rows} rows not divisible by "
                             f"max(shares) = {n_max} — re-shard the batch "
                             "with data.pipeline.reshard_for_shares")
        mb = rows // n_max
        micro = jax.tree.map(
            lambda v: v.reshape((n_max, mb) + v.shape[1:]), batch)
        idx = 0                       # linear BSP rank, row-major sync axes
        for ax, sz in zip(bsp.sync_axes, sizes):
            idx = idx * sz + jax.lax.axis_index(ax)
        n_r = jnp.asarray(shares, jnp.int32)[idx]

        out_sd = jax.eval_shape(lambda p, b: vag(p, cfg, b), params,
                                jax.tree.map(lambda v: v[0], micro))
        zeros = jax.tree.map(lambda sd: jnp.zeros(sd.shape, jnp.float32),
                             out_sd)

        def body(i, carry):
            mb_i = jax.tree.map(
                lambda v: jax.lax.dynamic_index_in_dim(v, i, keepdims=False),
                micro)
            t = jax.tree.map(lambda v: v.astype(jnp.float32),
                             vag(params, cfg, mb_i))
            return _tree_pair_add(carry[0], carry[1], t)

        s_tree, e_tree = jax.lax.fori_loop(0, n_r, body, (zeros, zeros))

        def combine(s, e):
            ag_s = jax.lax.all_gather(s, bsp.sync_axes, tiled=False)
            ag_s = ag_s.reshape((world,) + s.shape)
            ag_e = jax.lax.all_gather(e, bsp.sync_axes, tiled=False)
            ag_e = ag_e.reshape((world,) + e.shape)
            ts, te = jnp.zeros_like(s), jnp.zeros_like(s)
            for rr in range(world):
                ts, te = _pair_add(ts, te, ag_s[rr])
            for rr in range(world):
                ts, te = _pair_add(ts, te, ag_e[rr])
            return (ts + te) / m_total

        (loss, metrics), grads = jax.tree.map(combine, s_tree, e_tree)
        return loss, metrics, grads

    def local_step(params, flat_mu, flat_nu, ef, step, batch):
        if shares is not None:
            # shares path: loss/metrics/grads come back as GLOBAL means,
            # replicated on every rank (fixed-order compensated combine) —
            # the reduce-scatter below sums world identical copies, which
            # its /world recovers exactly (power-of-two doubling)
            loss, metrics, grads = local_grads_shares(params, batch)
        else:
            loss, metrics, grads = local_grads(params, batch)
            # report the GLOBAL mean loss (each rank saw its own micro-batch)
            loss = jax.lax.psum(loss, bsp.sync_axes) / world
            metrics = jax.tree.map(
                lambda v: jax.lax.psum(v, bsp.sync_axes) / world, metrics)

        g_parts = engine.pack(jax.tree.leaves(grads), dtype=jnp.float32)
        if has_codec and ef is not None:
            # per-rank EF residual, bucket-ordered like the flat layout.
            # The wire payload is the QUANTIZED corrected gradient —
            # corrected − residual ≡ dequant(quant(corrected)) — so the
            # residual compensates a quantization that actually reached the
            # reduction (classic EF-SGD), not a hypothetical one.  Buckets
            # whose policy skips compression pass through untouched (their
            # residual slice stays zero).
            new_ef = []
            for bkt, part, c in zip(engine.buckets, g_parts, bucket_codecs):
                res = jax.lax.dynamic_slice_in_dim(
                    ef, bkt.offset, bkt.length)
                if c is not None:
                    corrected, res = error_feedback_step(part, res, c)
                    g_parts[bkt.index] = corrected - res
                new_ef.append(res)
            ef = jnp.concatenate(new_ef)

        rev = C.bit_reversed_index(bsp.sync_axes, sizes)
        p_parts = engine.pack(jax.tree.leaves(params), dtype=jnp.float32)

        # --- pipelined communicate/update/publish, one bucket at a time ----
        new_p_parts, new_mu_parts, new_nu_parts, om = [], [], [], {}
        for bkt, schedule, wc, g_part, p_part, s_len, s_off in zip(
                engine.buckets, engine.schedules, wire_codecs, g_parts,
                p_parts, shard_lens, shard_offs):
            g_shard = engine.reduce_scatter_bucket(
                g_part, schedule, codec=wc) / world
            p_shard = jax.lax.dynamic_slice_in_dim(
                p_part, rev * s_len, s_len)
            mu_b = jax.lax.dynamic_slice_in_dim(flat_mu, s_off, s_len)
            nu_b = jax.lax.dynamic_slice_in_dim(flat_nu, s_off, s_len)
            new_p, new_mu, new_nu, om = _adamw_flat(
                p_shard, g_shard, mu_b, nu_b, step, acfg)
            # publish: the all-gather inverts the bit-reversed scatter, so
            # the bucket's flat layout comes back in original order
            new_p_parts.append(engine.all_gather_bucket(new_p))
            new_mu_parts.append(new_mu)
            new_nu_parts.append(new_nu)

        leaves = engine.unpack(new_p_parts, jax.tree.leaves(params))
        params = jax.tree.unflatten(jax.tree.structure(params), leaves)
        flat_mu = jnp.concatenate(new_mu_parts)
        flat_nu = jnp.concatenate(new_nu_parts)

        # --- fsync barrier closes the superstep ONCE ------------------------
        token = C.fractal_barrier(bsp.sync_axes, sizes, level=bsp.fsync_level)
        params = jax.tree.map(lambda x: barrier_tie(x, token), params)
        metrics = dict(metrics, loss=loss, **om)
        return params, flat_mu, flat_nu, ef, step + 1, metrics

    # --- shard_map plumbing: DP manual, model auto ---------------------------
    rep = jax.tree.map(lambda _: P(), pshape)       # DP-replicated params
    shard_spec = P(bsp.sync_axes)
    bspec = {"tokens": P(bsp.sync_axes, None),
             "labels": P(bsp.sync_axes, None)}
    if cfg.frontend:
        bspec["frontend"] = P(bsp.sync_axes, None, None)

    in_specs = (rep, shard_spec, shard_spec,
                shard_spec if has_codec else P(),
                P(), bspec)
    out_specs = (rep, shard_spec, shard_spec,
                 shard_spec if has_codec else P(),
                 P(), P())

    def wrapped(params, flat_mu, flat_nu, ef, step, batch):
        return local_step(params, flat_mu, flat_nu, ef, step, batch)

    fn = bsp_shard_map(wrapped, mesh, in_specs=in_specs, out_specs=out_specs,
                       sync_axes=bsp.sync_axes)
    # donating the pass-through ef placeholder trips XLA aliasing when the
    # codec is off (output aliases a deleted input on the next call) — donate
    # only the genuinely-consumed moment shards
    step_fn = jax.jit(fn, donate_argnums=(1, 2))

    def init_state(params) -> Tuple:
        mu = jnp.zeros((flat_total,), jnp.float32)  # sharded by in_specs
        nu = jnp.zeros((flat_total,), jnp.float32)
        # EF residual is PER-RANK state of full bucket-ordered length:
        # global (world × flat_total) sharded over the sync axes
        ef = jnp.zeros((world * flat_total,), jnp.float32) \
            if has_codec \
            else jnp.zeros((world,), jnp.float32)   # placeholder
        return params, mu, nu, ef, jnp.zeros((), jnp.int32)

    init_state.superstep_layout = layout_tag
    return step_fn, init_state


def _adamw_flat(p, g, mu, nu, step, acfg: adamw.AdamWConfig):
    """AdamW on a flat f32 shard (global-norm clip is per-shard-approx here;
    exact global clipping would add one scalar psum — left to the schedule)."""
    b1, b2 = acfg.beta1, acfg.beta2
    stepf = (step + 1).astype(jnp.float32)
    lr = adamw.schedule(step, acfg)
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * jnp.square(g)
    mhat = mu / (1 - b1 ** stepf)
    nhat = nu / (1 - b2 ** stepf)
    upd = mhat / (jnp.sqrt(nhat) + acfg.eps) + acfg.weight_decay * p
    return p - lr * upd, mu, nu, {"lr": lr}
