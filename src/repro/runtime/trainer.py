"""BSP training step builders.

Two tiers (DESIGN.md §4):

  * ``make_gspmd_train_step`` — jit + GSPMD: parameters FSDP×TP sharded
    (ZeRO-3 style), gradient reduction scheduled by XLA.  This is the
    baseline every (arch × shape) dry-run cell uses.

  * ``make_bsp_train_step`` — the paper's technique as a first-class feature:
    the whole step runs inside ``shard_map`` with the DP axes *manual* and the
    model axis auto (TP stays GSPMD).  Parameters are DP-replicated; gradients
    are flattened and pushed through the explicit FractalSync-family schedule
    (fractal | ring | xy | naive | hierarchical, ± payload compression);
    optimizer moments are ZeRO-1 sharded over the flat vector — each BSP rank
    updates 1/world of the parameters between the fractal reduce-scatter and
    all-gather (the bandwidth-optimal H-tree form), then the fsync barrier
    closes the superstep.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import collectives as C
from repro.core.barrier import barrier_tie
from repro.core.bsp import (BSPConfig, bsp_shard_map, make_codec,
                            resolve_schedule)
from repro.models import act_sharding as ACT
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import error_feedback_step


# ---------------------------------------------------------------------------
# Tier A: GSPMD (baseline for all dry-run cells)
# ---------------------------------------------------------------------------


def make_gspmd_train_step(cfg: ArchConfig, mesh: Mesh,
                          acfg: adamw.AdamWConfig):
    """jit'd (params, opt_state, batch) → (params, opt_state, metrics)."""
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = False

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, om = adamw.apply_updates(params, grads, opt_state,
                                                    acfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh)
    oshape = jax.eval_shape(lambda: adamw.init(pshape, acfg))
    ospec = adamw.AdamWState(step=P(), mu=pspec, nu=pspec)
    bspec_all = SH.batch_spec(mesh)
    bspec = {"tokens": bspec_all["tokens"], "labels": bspec_all["labels"]}
    if cfg.frontend:
        bspec["frontend"] = bspec_all["frontend"]

    n = lambda s: SH.named(mesh, s)
    step = jax.jit(
        train_step,
        in_shardings=(n(pspec), n(ospec), n(bspec)),
        out_shardings=(n(pspec), n(ospec), None),
        donate_argnums=(0, 1),
    )
    return step, (pspec, ospec, bspec)


# ---------------------------------------------------------------------------
# Tier A: serving steps (prefill / decode)
# ---------------------------------------------------------------------------


def _serve_mode(cfg: ArchConfig) -> str:
    """MoE archs serve with pinned weights (TP+EP: tokens move, weights
    stay) — 35-41× on the big-MoE cells; small dense archs keep the FSDP
    layout whose per-layer weight gather is cheaper than 16× the HBM reads
    (measured: musicgen/granite serve_layout variants, EXPERIMENTS §Perf)."""
    return "serve" if cfg.moe else "train"


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = cfg.moe is not None

    def prefill_step(params, tokens, cache, frontend=None):
        return T.prefill(params, cfg, tokens, cache, frontend)

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh, mode=_serve_mode(cfg))
    cshape = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    cspec = SH.cache_specs(cfg, cshape, mesh)
    dp = SH.fsdp_axes(mesh)
    if batch % SH.axis_size(mesh, dp):
        dp = ()
    n = lambda s: SH.named(mesh, s)
    in_sh = [n(pspec), NamedSharding(mesh, P(dp or None, None)), n(cspec)]
    if cfg.frontend:
        in_sh.append(NamedSharding(mesh, P(dp, None, None)))
    step = jax.jit(prefill_step, in_shardings=tuple(in_sh),
                   out_shardings=(None, n(cspec), None),
                   donate_argnums=(2,))
    return step, (pspec, cspec)


def make_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    ACT.set_policy(mesh, SH.fsdp_axes(mesh))
    ACT.SERVE_EP = cfg.moe is not None

    def serve_step(params, token, cache, offset):
        logits, cache = T.decode_step(params, cfg, token, cache, offset)
        return logits, cache

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    pspec = SH.param_specs(cfg, pshape, mesh, mode=_serve_mode(cfg))
    cshape = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    cspec = SH.cache_specs(cfg, cshape, mesh)
    dp = SH.fsdp_axes(mesh)
    if batch % SH.axis_size(mesh, dp):
        dp = ()                      # long_500k: global batch 1
    n = lambda s: SH.named(mesh, s)
    step = jax.jit(
        serve_step,
        in_shardings=(n(pspec), NamedSharding(mesh, P(dp or None, None)),
                      n(cspec), NamedSharding(mesh, P())),
        out_shardings=(None, n(cspec)),
        donate_argnums=(2,),
    )
    return step, (pspec, cspec)


# ---------------------------------------------------------------------------
# Tier B: explicit BSP superstep (the paper's technique, first-class)
# ---------------------------------------------------------------------------


@dataclass
class BSPTrainState:
    params: Any                # DP-replicated pytree (TP-sharded on "model")
    flat_mu: jax.Array         # ZeRO-1: this rank's shard of flat moments
    flat_nu: jax.Array
    ef_residual: Optional[jax.Array]   # error-feedback state (compression)
    step: jax.Array


def _flat_len(pshape, world: int, align: int) -> int:
    n = sum(int(math.prod(l.shape)) for l in jax.tree.leaves(pshape))
    unit = world * align
    return ((n + unit - 1) // unit) * unit


def make_bsp_train_step(cfg: ArchConfig, mesh: Mesh, acfg: adamw.AdamWConfig,
                        bsp: BSPConfig):
    """Explicit-schedule BSP superstep:

      compute:     local fwd/bwd on this rank's micro-batch
      communicate: flat grads → [EF] → fractal reduce-scatter (or full
                   schedule) with optional payload compression
      update:      AdamW on this rank's 1/world flat shard (ZeRO-1)
      publish:     fractal all-gather of updated params
      barrier:     fsync(level) token tied into outputs
    """
    ACT.clear_policy()   # manual-DP body: no data-axis GSPMD constraints
    sizes = tuple(mesh.shape[a] for a in bsp.sync_axes)
    world = math.prod(sizes)
    codec = make_codec(bsp.compression)

    pshape = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))
    flat_total = _flat_len(pshape, world, bsp.pad_align)
    # "auto": one cost-model query against the flat f32 gradient payload,
    # resolved once here so the traced step uses a concrete schedule
    schedule = resolve_schedule(bsp, sizes, flat_total * 4)
    if schedule != bsp.schedule:
        print(f"autotune: schedule=auto → {schedule!r} "
              f"(world={world}, payload={flat_total * 4 / 1e6:.1f} MB)")
        bsp = dataclasses.replace(bsp, schedule=schedule)

    def local_step(params, flat_mu, flat_nu, ef, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        # report the GLOBAL mean loss (each rank saw its own micro-batch)
        loss = jax.lax.psum(loss, bsp.sync_axes) / world
        metrics = jax.tree.map(
            lambda v: jax.lax.psum(v, bsp.sync_axes) / world, metrics)
        flat_g, unravel = ravel_pytree(
            jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        n = flat_g.shape[0]
        padded = _flat_len(grads, world, bsp.pad_align)
        flat_g = jnp.concatenate(
            [flat_g, jnp.zeros((padded - n,), jnp.float32)])

        if codec is not None and ef is not None:
            flat_g, ef = error_feedback_step(flat_g, ef, codec)

        # After recursive-halving RS, rank i holds the CONTIGUOUS chunk at
        # bit-reversed position rev(i) (coarsest split decided by bit 0).
        idx = C.flat_index(bsp.sync_axes)
        L = int(math.log2(world))
        rev = jnp.zeros((), jnp.int32)
        for b in range(L):
            rev = rev | (((idx >> b) & 1) << (L - 1 - b))
        shard_len = padded // world

        # --- communicate: fractal reduce-scatter (H-tree, halving) ---------
        if bsp.schedule == "fractal":
            g_shard = C.fractal_reduce_scatter(flat_g, bsp.sync_axes, sizes,)
        else:
            full = C.all_reduce(flat_g, bsp.schedule, bsp.sync_axes, sizes)
            g_shard = jax.lax.dynamic_slice_in_dim(
                full, rev * shard_len, shard_len)
        g_shard = g_shard / world

        # --- ZeRO-1 update on this rank's flat shard ------------------------
        flat_p, _ = ravel_pytree(
            jax.tree.map(lambda p: p.astype(jnp.float32), params))
        flat_p = jnp.concatenate(
            [flat_p, jnp.zeros((padded - n,), jnp.float32)])
        p_shard = jax.lax.dynamic_slice_in_dim(flat_p, rev * shard_len,
                                               shard_len)
        new_p, new_mu, new_nu, om = _adamw_flat(
            p_shard, g_shard, flat_mu, flat_nu, step, acfg)

        # --- publish: fractal all-gather of the updated shards -------------
        # all-gather inverts the reduce-scatter placement, so the flat layout
        # comes back in original order
        flat_new = C.fractal_all_gather(new_p, bsp.sync_axes, sizes)
        params = jax.tree.map(lambda x, ref: x.astype(ref.dtype),
                              unravel(flat_new[:n]), params)

        # --- fsync barrier closes the superstep -----------------------------
        token = C.fractal_barrier(bsp.sync_axes, sizes, level=bsp.fsync_level)
        params = jax.tree.map(lambda x: barrier_tie(x, token), params)
        metrics = dict(metrics, loss=loss, **om)
        return params, new_mu, new_nu, ef, step + 1, metrics

    # --- shard_map plumbing: DP manual, model auto ---------------------------
    rep = jax.tree.map(lambda _: P(), pshape)       # DP-replicated params
    shard_spec = P(bsp.sync_axes)
    bspec = {"tokens": P(bsp.sync_axes, None),
             "labels": P(bsp.sync_axes, None)}
    if cfg.frontend:
        bspec["frontend"] = P(bsp.sync_axes, None, None)
    ef_spec = shard_spec if codec is not None else None

    in_specs = (rep, shard_spec, shard_spec,
                shard_spec if codec is not None else P(),
                P(), bspec)
    out_specs = (rep, shard_spec, shard_spec,
                 shard_spec if codec is not None else P(),
                 P(), P())

    def wrapped(params, flat_mu, flat_nu, ef, step, batch):
        return local_step(params, flat_mu, flat_nu, ef, step, batch)

    fn = bsp_shard_map(wrapped, mesh, in_specs=in_specs, out_specs=out_specs,
                       sync_axes=bsp.sync_axes)
    # donating the pass-through ef placeholder trips XLA aliasing when the
    # codec is off (output aliases a deleted input on the next call) — donate
    # only the genuinely-consumed moment shards
    step_fn = jax.jit(fn, donate_argnums=(1, 2))

    def init_state(params) -> Tuple:
        shard_len = flat_total // world
        mu = jnp.zeros((flat_total,), jnp.float32)  # sharded by in_specs
        nu = jnp.zeros((flat_total,), jnp.float32)
        ef = jnp.zeros((flat_total,), jnp.float32) if codec is not None \
            else jnp.zeros((world,), jnp.float32)   # placeholder
        return params, mu, nu, ef, jnp.zeros((), jnp.int32)

    return step_fn, init_state


def _adamw_flat(p, g, mu, nu, step, acfg: adamw.AdamWConfig):
    """AdamW on a flat f32 shard (global-norm clip is per-shard-approx here;
    exact global clipping would add one scalar psum — left to the schedule)."""
    b1, b2 = acfg.beta1, acfg.beta2
    stepf = (step + 1).astype(jnp.float32)
    lr = adamw.schedule(step, acfg)
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * jnp.square(g)
    mhat = mu / (1 - b1 ** stepf)
    nhat = nu / (1 - b2 ** stepf)
    upd = mhat / (jnp.sqrt(nhat) + acfg.eps) + acfg.weight_decay * p
    return p - lr * upd, mu, nu, {"lr": lr}
