"""The BSP training loop: data → superstep → checkpoint → monitor.

Glues the substrate together for real (CPU-device) runs: examples/train_lm.py
drives ~100M-param models for hundreds of steps through this loop.  The same
loop shape runs at pod scale — the pieces that change (mesh size, per-host
data sharding, real heartbeats) are injected.

Responsibilities per step:
  1. pull a prefetched host batch; device_put with batch shardings,
  2. run the jit'd superstep (gradient sync via the configured schedule),
  3. record per-rank durations → straggler tracker,
  4. periodic async checkpoint (exact-resume metadata: data step, RNG),
  5. on monitor-reported failure: raise ``WorkerFailure`` for the elastic
     driver (examples/fault_tolerance_demo.py shows the recover path).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointing import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.runtime.fault_tolerance import HostMonitor, StragglerTracker


class WorkerFailure(RuntimeError):
    def __init__(self, failed_hosts):
        super().__init__(f"failed hosts: {sorted(failed_hosts)}")
        self.failed_hosts = failed_hosts


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    # when > 0 and stragglers are detected, compute a proportional
    # micro-batch rebalance over this many micro-batches per superstep and
    # surface it (printed + appended to TrainLoop.rebalance_history)
    rebalance_microbatches: int = 0


@dataclass
class TrainLoop:
    step_fn: Callable                     # (state..., batch) -> (state..., metrics)
    state: tuple                          # step-fn carry (params, opt, ...)
    data: SyntheticLM
    cfg: LoopConfig
    batch_shardings: Any = None
    monitor: Optional[HostMonitor] = None
    stragglers: StragglerTracker = field(default_factory=StragglerTracker)
    start_step: int = 0
    history: list = field(default_factory=list)
    rebalance_history: list = field(default_factory=list)
    # this host's BSP rank for the wall-clock fallback (multi-host runners
    # pass jax.process_index(); single-process runs default to rank 0)
    host_rank: int = 0
    # extra metadata stamped into every checkpoint (e.g. the trainer's
    # superstep_layout fingerprint, validated on resume)
    ckpt_meta: Dict[str, Any] = field(default_factory=dict)
    # host-side transform applied to every prefetched batch before
    # device placement (e.g. data.pipeline.reshard_for_shares under an
    # actuated rebalance)
    batch_transform: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] \
        = None
    # closes the straggler loop: called with the rebalanced shares dict
    # the first time it CHANGES; returns (step_fn, batch_transform) —
    # typically a trainer rebuilt with shares= plus the matching
    # reshard_for_shares — or None to keep the current pair
    rebalance_actuator: Optional[Callable[[Dict[int, int]],
                                          Optional[tuple]]] = None
    _active_shares: Optional[Dict[int, int]] = None

    def _record_durations(self, metrics, dt: float) -> None:
        """Per-rank superstep durations → straggler tracker.

        Preferred source: a ``per_rank_step_s`` entry in the step metrics
        (a length-world vector of measured rank durations, e.g. from a
        pod-scale runner's per-host timers).  Fallback: this host's
        wall-clock under its own rank — on >1 process every host records
        its own row, so the tracker sees real per-rank data either way.
        """
        per_rank = metrics.get("per_rank_step_s") \
            if isinstance(metrics, dict) else None
        if per_rank is not None:
            for r, v in enumerate(np.asarray(per_rank).reshape(-1)):
                self.stragglers.record(int(r), float(v))
        else:
            self.stragglers.record(self.host_rank, dt)

    def _maybe_rebalance(self, step: int) -> None:
        if not self.cfg.rebalance_microbatches:
            return
        slow = self.stragglers.stragglers()
        if not slow:
            return
        ranks = sorted(self.stragglers.durations)
        shares = self.stragglers.rebalanced_shares(
            ranks, self.cfg.rebalance_microbatches)
        self.rebalance_history.append(
            {"step": step, "stragglers": sorted(slow), "shares": shares})
        print(f"step {step:5d} stragglers {sorted(slow)} "
              f"-> micro-batch shares {shares}", flush=True)
        if self.rebalance_actuator is not None \
                and shares != self._active_shares:
            # actuate only on CHANGE: rebuilding the step_fn recompiles,
            # so a stable straggler pattern pays that cost once
            out = self.rebalance_actuator(shares)
            if out is not None:
                self.step_fn, self.batch_transform = out
                self._active_shares = shares

    def run(self) -> Dict[str, Any]:
        ckpt = (CheckpointManager(self.cfg.checkpoint_dir,
                                  keep=self.cfg.keep_checkpoints)
                if self.cfg.checkpoint_dir else None)
        prefetch = Prefetcher(self.data, start_step=self.start_step)
        state = self.state
        step = self.start_step
        try:
            while step < self.cfg.total_steps:
                data_step, host_batch = prefetch.next()
                assert data_step == step, (data_step, step)
                if self.batch_transform is not None:
                    host_batch = self.batch_transform(host_batch)
                batch = self._place(host_batch)
                t0 = time.monotonic()
                *state_parts, metrics = self.step_fn(*state, batch)
                state = tuple(state_parts)
                jax.block_until_ready(state[0])
                dt = time.monotonic() - t0
                self._record_durations(metrics, dt)
                self._maybe_rebalance(step)

                if self.monitor is not None:
                    failed = self.monitor.failed_hosts()
                    if failed:
                        raise WorkerFailure(failed)

                loss = float(np.asarray(metrics.get("loss", np.nan)))
                self.history.append({"step": step, "loss": loss, "sec": dt})
                if self.cfg.log_every and step % self.cfg.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} {dt*1e3:7.1f} ms",
                          flush=True)
                step += 1
                if ckpt and step % self.cfg.checkpoint_every == 0:
                    ckpt.save(step, state,
                              meta={**self.ckpt_meta, "data_step": step})
        finally:
            prefetch.close()
            if ckpt:
                ckpt.wait()
        if ckpt and step % self.cfg.checkpoint_every != 0:
            ckpt.save(step, state,
                      meta={**self.ckpt_meta, "data_step": step},
                      blocking=True)
        self.state = state
        return {"final_step": step, "history": self.history,
                "rebalance": self.rebalance_history}

    def _place(self, host_batch):
        if self.batch_shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
        return {
            k: jax.device_put(v, self.batch_shardings[k])
            for k, v in host_batch.items()
        }


def resume_or_init(ckpt_dir: Optional[str], like_state, expect_meta=None):
    """(state, start_step) — restored from the latest checkpoint if any.

    ``expect_meta`` entries are validated against the stored metadata and
    a mismatch OR absence raises: the flat moment vectors restore
    shape-compatibly under a different superstep bucket layout (or the
    pre-engine forward leaf order, which stamped no tag at all) but bind
    every moment to the wrong parameter slice — silent corruption, so the
    resume must fail loudly instead.
    """
    if not ckpt_dir:
        return like_state, 0
    mgr = CheckpointManager(ckpt_dir)
    out = mgr.restore(like_state)
    if out is None:
        return like_state, 0
    state, meta = out
    for key, want in (expect_meta or {}).items():
        got = meta.get(key)
        if got != want:
            raise RuntimeError(
                f"checkpoint {key!r} mismatch: stored {got!r} vs expected "
                f"{want!r} — the flat state layout differs (different "
                f"--bucket-mb, or a checkpoint from before the bucketed "
                f"engine); restart from scratch or re-mesh explicitly")
    return state, int(meta.get("data_step", meta.get("step", 0)))
