"""The BSP training loop: data → superstep → checkpoint → monitor.

Glues the substrate together for real (CPU-device) runs: examples/train_lm.py
drives ~100M-param models for hundreds of steps through this loop.  The same
loop shape runs at pod scale — the pieces that change (mesh size, per-host
data sharding, real heartbeats) are injected.

Responsibilities per step:
  1. pull a prefetched host batch; device_put with batch shardings,
  2. run the jit'd superstep (gradient sync via the configured schedule),
  3. record per-rank durations → straggler tracker,
  4. periodic async checkpoint (exact-resume metadata: data step, RNG),
  5. on monitor-reported failure: raise ``WorkerFailure`` for the elastic
     driver (examples/fault_tolerance_demo.py shows the recover path).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointing import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.runtime.fault_tolerance import HostMonitor, StragglerTracker


class WorkerFailure(RuntimeError):
    def __init__(self, failed_hosts):
        super().__init__(f"failed hosts: {sorted(failed_hosts)}")
        self.failed_hosts = failed_hosts


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3


@dataclass
class TrainLoop:
    step_fn: Callable                     # (state..., batch) -> (state..., metrics)
    state: tuple                          # step-fn carry (params, opt, ...)
    data: SyntheticLM
    cfg: LoopConfig
    batch_shardings: Any = None
    monitor: Optional[HostMonitor] = None
    stragglers: StragglerTracker = field(default_factory=StragglerTracker)
    start_step: int = 0
    history: list = field(default_factory=list)

    def run(self) -> Dict[str, Any]:
        ckpt = (CheckpointManager(self.cfg.checkpoint_dir,
                                  keep=self.cfg.keep_checkpoints)
                if self.cfg.checkpoint_dir else None)
        prefetch = Prefetcher(self.data, start_step=self.start_step)
        state = self.state
        step = self.start_step
        try:
            while step < self.cfg.total_steps:
                data_step, host_batch = prefetch.next()
                assert data_step == step, (data_step, step)
                batch = self._place(host_batch)
                t0 = time.monotonic()
                *state_parts, metrics = self.step_fn(*state, batch)
                state = tuple(state_parts)
                jax.block_until_ready(state[0])
                dt = time.monotonic() - t0
                self.stragglers.record(0, dt)

                if self.monitor is not None:
                    failed = self.monitor.failed_hosts()
                    if failed:
                        raise WorkerFailure(failed)

                loss = float(np.asarray(metrics.get("loss", np.nan)))
                self.history.append({"step": step, "loss": loss, "sec": dt})
                if self.cfg.log_every and step % self.cfg.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} {dt*1e3:7.1f} ms",
                          flush=True)
                step += 1
                if ckpt and step % self.cfg.checkpoint_every == 0:
                    ckpt.save(step, state, meta={"data_step": step})
        finally:
            prefetch.close()
            if ckpt:
                ckpt.wait()
        if ckpt and step % self.cfg.checkpoint_every != 0:
            ckpt.save(step, state, meta={"data_step": step}, blocking=True)
        self.state = state
        return {"final_step": step, "history": self.history}

    def _place(self, host_batch):
        if self.batch_shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
        return {
            k: jax.device_put(v, self.batch_shardings[k])
            for k, v in host_batch.items()
        }


def resume_or_init(ckpt_dir: Optional[str], like_state):
    """(state, start_step) — restored from the latest checkpoint if any."""
    if not ckpt_dir:
        return like_state, 0
    mgr = CheckpointManager(ckpt_dir)
    out = mgr.restore(like_state)
    if out is None:
        return like_state, 0
    state, meta = out
    return state, int(meta.get("data_step", meta.get("step", 0)))
