"""Fault-injected training soak: actuated rebalance + elastic recovery.

The serving soak (``repro.serve.soak``) stresses the engine; this module
closes the two open control loops on the TRAINING side, end to end, on
one process with host devices:

  1. **Straggler actuation.**  A ``runtime.chaos.FaultPlan`` ``slow``
     window inflates one rank's simulated superstep duration; the
     ``StragglerTracker`` flags it; the loop's ``rebalance_actuator``
     rebuilds the BSP step with UNEVEN per-rank micro-batch ``shares=``
     and swaps in the matching ``reshard_for_shares`` batch transform.
     Because the shares path is bit-consistent across partitions
     (compensated-pair accumulation — see ``trainer.make_bsp_train_step``),
     actuation changes WHO computes each micro-batch without perturbing
     the loss trajectory by a single bit.

  2. **Elastic recovery.**  A ``kill`` event silences one host's
     heartbeats on the virtual ``StepClock``; the ``HostMonitor`` times
     out; ``TrainLoop`` raises ``WorkerFailure``; the harness re-meshes
     onto the largest surviving complete fsync domain
     (``plan_recovery`` — the paper's programmable sync-domain feature
     doing elastic scaling), restores parameters from the latest
     checkpoint, and continues with even shares that PRESERVE the global
     micro-batch count (each survivor takes ``grad_accum_scale`` × the
     work).  Optimizer moments are ZeRO-1 sharded in a world-dependent
     flat layout, so cross-world restore would bind them to the wrong
     slices — they are deliberately re-initialized (recorded in the
     result; exact cross-world moment resharding is a ROADMAP item).

``check_train_soak`` asserts the robustness claims: the rebalance
actually actuated (slow rank got the smallest share), the survivors form
a complete fsync subtree, the first replayed loss matches the pre-fault
recording at that step (parameters round-tripped through the checkpoint
exactly; loss precedes any moment-dependent update), and the loss keeps
descending after recovery.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.checkpointing import CheckpointManager
from repro.core.bsp import BSPConfig
from repro.core.tree import FractalTree
from repro.data.pipeline import DataConfig, SyntheticLM, reshard_for_shares
from repro.runtime.chaos import FaultPlan, StepClock
from repro.runtime.elastic import build_mesh_from_tiles, plan_recovery
from repro.runtime.fault_tolerance import HostMonitor, StragglerTracker
from repro.runtime.loop import LoopConfig, TrainLoop, WorkerFailure


@dataclass(frozen=True)
class TrainSoakConfig:
    arch: str = "qwen2.5-3b-smoke"
    tree_shape: Tuple[int, ...] = (2, 4)    # hosts = prod(tree_shape)
    total_steps: int = 22
    microbatches: int = 16                  # global per step, preserved
    micro_rows: int = 1                     # rows per micro-batch
    seq_len: int = 16
    seed: int = 3
    lr: float = 1e-3
    checkpoint_every: int = 4
    hb_timeout_s: float = 3.0               # steps on the virtual clock
    straggler_window: int = 4
    straggler_threshold: float = 1.5
    # default plan: rank 3 runs 3× slow for steps [4, 10), rank 5 dies at
    # step 12 — exercises actuation THEN recovery in one run
    fault_spec: str = "slow:rank=3,factor=3.0,steps=4..10;kill:rank=5,step=12"
    base_step_s: float = 1.0                # simulated healthy superstep


@dataclass
class TrainSoakResult:
    history: List[Dict[str, Any]]           # pre-fault rows then replayed
    rebalance: List[Dict[str, Any]]
    actuated_shares: Optional[Dict[int, int]]
    recovery: Optional[Dict[str, Any]]      # level/tiles/worlds/step
    replay_pairs: List[Tuple[float, float]]  # (recorded, replayed) losses
    failures: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures


class _ChaosStep:
    """Wraps the jitted step: injects per-rank durations from the plan,
    ticks the virtual clock, emits heartbeats for every un-killed host
    (honoring drop/duplicate events).  ``inner`` is swapped in place by
    the rebalance actuator so the chaos envelope survives actuation."""

    def __init__(self, inner, plan: FaultPlan, clock: StepClock,
                 monitor: HostMonitor, world: int, base_s: float,
                 start_step: int = 0):
        self.inner = inner
        self.plan, self.clock, self.monitor = plan, clock, monitor
        self.world, self.base_s = world, base_s
        self.step = start_step

    def __call__(self, *args):
        *state, metrics = self.inner(*args)
        s = self.step
        metrics = dict(metrics)
        metrics["per_rank_step_s"] = [
            self.base_s * self.plan.slow_factor(r, s)
            for r in range(self.world)]
        self.clock.tick()
        killed = self.plan.killed_by(s)
        for h in range(self.world):
            if h in killed or self.plan.heartbeat_dropped(h, s):
                continue
            self.monitor.heartbeat(h)
            if self.plan.heartbeat_duplicated(h, s):
                self.monitor.heartbeat(h)
        self.step += 1
        return (*state, metrics)


def _even_shares(m_total: int, world: int) -> Tuple[int, ...]:
    if m_total % world:
        raise ValueError(f"{m_total} micro-batches do not split evenly "
                         f"over {world} ranks")
    return (m_total // world,) * world


def run_train_soak(scfg: TrainSoakConfig, checkpoint_dir: str,
                   mesh_devices=None) -> TrainSoakResult:
    """One fault-injected training soak (requires ``prod(tree_shape)``
    jax devices, e.g. via --xla_force_host_platform_device_count)."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.optim import adamw
    from repro.runtime import trainer

    cfg = get_config(scfg.arch)
    tree = FractalTree(scfg.tree_shape)
    world = tree.num_tiles
    cols = scfg.tree_shape[-1]
    devices = list(mesh_devices if mesh_devices is not None
                   else jax.devices())
    if len(devices) < world:
        raise RuntimeError(f"train soak needs {world} devices, "
                           f"have {len(devices)}")
    m_total = scfg.microbatches
    plan = FaultPlan.parse(scfg.fault_spec)
    clock = StepClock(step_s=1.0)
    monitor = HostMonitor(num_hosts=world, timeout_s=scfg.hb_timeout_s,
                          clock=clock)
    for h in range(world):
        monitor.heartbeat(h)

    acfg = adamw.AdamWConfig(lr=scfg.lr, warmup_steps=1,
                             total_steps=scfg.total_steps, grad_clip=0.0)
    bsp = BSPConfig(sync_axes=("data",), schedule="fractal")
    data = SyntheticLM(cfg, DataConfig(
        global_batch=m_total * scfg.micro_rows, seq_len=scfg.seq_len,
        seed=scfg.seed))
    params0 = T.init_params(cfg, jax.random.key(0))

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((world, 1), ("data", "model"), devices=devices[:world])
    shares0 = _even_shares(m_total, world)
    step_fn, init_state = trainer.make_bsp_train_step(
        cfg, mesh, acfg, bsp, shares=shares0)
    state0 = init_state(params0)

    chaos = _ChaosStep(step_fn, plan, clock, monitor, world,
                       scfg.base_step_s)
    result = TrainSoakResult(history=[], rebalance=[], actuated_shares=None,
                             recovery=None, replay_pairs=[])

    def actuator(shares_dict: Dict[int, int]):
        if sorted(shares_dict) != list(range(world)):
            return None
        shares = tuple(shares_dict[r] for r in range(world))
        new_fn, _ = trainer.make_bsp_train_step(
            cfg, mesh, acfg, bsp, shares=shares)
        chaos.inner = new_fn
        result.actuated_shares = dict(shares_dict)
        return chaos, (lambda b: reshard_for_shares(b, shares))

    loop = TrainLoop(
        step_fn=chaos, state=state0, data=data,
        cfg=LoopConfig(total_steps=scfg.total_steps,
                       checkpoint_every=scfg.checkpoint_every,
                       log_every=1, checkpoint_dir=checkpoint_dir,
                       rebalance_microbatches=m_total),
        monitor=monitor,
        stragglers=StragglerTracker(window=scfg.straggler_window,
                                    threshold=scfg.straggler_threshold),
        batch_transform=lambda b: reshard_for_shares(b, shares0),
        rebalance_actuator=actuator,
        ckpt_meta={"superstep_layout": init_state.superstep_layout})

    try:
        loop.run()
        result.failures.append(
            "fault plan injected no fatal failure: the soak never "
            "exercised recovery")
        result.history = loop.history
        return result
    except WorkerFailure as wf:
        failed_hosts = set(wf.failed_hosts)
    result.history = list(loop.history)
    result.rebalance = list(loop.rebalance_history)

    # ---- elastic recovery on the surviving fsync domain ------------------
    failed_tiles = [divmod(h, cols) for h in sorted(failed_hosts)]
    eplan = plan_recovery(tree, failed_tiles, old_world=world)
    new_world = eplan.world
    mesh2 = build_mesh_from_tiles(tree, eplan.tiles, devices=devices[:world],
                                  mesh_shape=(new_world, 1))
    ckpt = CheckpointManager(checkpoint_dir)
    restored = ckpt.restore(state0)
    if restored is None:
        result.failures.append("no checkpoint to restore from")
        return result
    old_state, meta = restored
    restore_step = int(meta["data_step"])
    # even shares preserving the global micro-batch count: each survivor
    # takes grad_accum_scale × its old share
    shares2 = _even_shares(m_total, new_world)
    step_fn2, init_state2 = trainer.make_bsp_train_step(
        cfg, mesh2, acfg, bsp, shares=shares2)
    # params round-trip exactly; ZeRO-1 moments are world-layout-bound
    # (superstep_layout fingerprint differs) and restart from zero
    params_r = jax.tree.unflatten(
        jax.tree.structure(params0),
        [jnp.asarray(v) for v in jax.tree.leaves(old_state[0])])
    state2 = init_state2(params_r)
    state2 = (state2[0], state2[1], state2[2], state2[3],
              jnp.asarray(np.int32(restore_step)))
    result.recovery = {
        "failed_hosts": sorted(failed_hosts), "level": eplan.level,
        "tiles": list(eplan.tiles), "old_world": world,
        "new_world": new_world, "grad_accum_scale": eplan.grad_accum_scale,
        "restore_step": restore_step, "moments_reinitialized": True,
    }

    clock2 = StepClock(step_s=1.0)
    monitor2 = HostMonitor(num_hosts=new_world, timeout_s=scfg.hb_timeout_s,
                           clock=clock2)
    for h in range(new_world):
        monitor2.heartbeat(h)
    chaos2 = _ChaosStep(step_fn2, FaultPlan(), clock2, monitor2, new_world,
                        scfg.base_step_s, start_step=restore_step)
    loop2 = TrainLoop(
        step_fn=chaos2, state=state2, data=data,
        cfg=LoopConfig(total_steps=scfg.total_steps,
                       checkpoint_every=scfg.checkpoint_every,
                       log_every=1, checkpoint_dir=checkpoint_dir,
                       rebalance_microbatches=0),
        monitor=monitor2,
        start_step=restore_step,
        batch_transform=lambda b: reshard_for_shares(b, shares2),
        ckpt_meta={"superstep_layout": init_state2.superstep_layout})
    loop2.run()

    recorded = {row["step"]: row["loss"] for row in result.history}
    for row in loop2.history:
        if row["step"] in recorded:
            result.replay_pairs.append((recorded[row["step"]], row["loss"]))
    result.history += loop2.history
    return result


def check_train_soak(result: TrainSoakResult,
                     scfg: TrainSoakConfig) -> TrainSoakResult:
    """Populate ``result.failures`` with every violated robustness claim."""
    plan = FaultPlan.parse(scfg.fault_spec)
    slow_ranks = {e.rank for e in plan.events if e.kind == "slow"}
    if slow_ranks:
        if result.actuated_shares is None:
            result.failures.append("straggler rebalance never actuated")
        else:
            sh = result.actuated_shares
            for r in slow_ranks:
                if sh[r] != min(sh.values()):
                    result.failures.append(
                        f"slow rank {r} got share {sh[r]}, not the "
                        f"minimum of {sh}")
            if len(set(sh.values())) == 1:
                result.failures.append(
                    f"actuated shares {sh} are still even — no rebalance")
    if result.recovery is None:
        result.failures.append("elastic recovery never ran")
    else:
        tree = FractalTree(scfg.tree_shape)
        rec = result.recovery
        domains = list(tree.domains(rec["level"]))
        if tuple(rec["tiles"]) not in [tuple(d) for d in domains]:
            result.failures.append(
                f"surviving tiles {rec['tiles']} are not a complete "
                f"level-{rec['level']} fsync domain")
        if rec["new_world"] * rec["grad_accum_scale"] != rec["old_world"]:
            result.failures.append(
                f"grad_accum_scale {rec['grad_accum_scale']} × new world "
                f"{rec['new_world']} != old world {rec['old_world']}: "
                "global batch not preserved")
        if not result.replay_pairs:
            result.failures.append(
                "no overlap between pre-fault history and replayed steps "
                "(checkpoint cadence vs detection latency)")
        for rec_l, rep_l in result.replay_pairs[:1]:
            # first replayed loss: computed from checkpoint-restored params
            # BEFORE any moment-dependent update → must match the pre-fault
            # recording (cross-world combine order shifts O(eps) at most)
            if not math.isclose(rec_l, rep_l, rel_tol=1e-5, abs_tol=1e-5):
                result.failures.append(
                    f"replayed loss {rep_l!r} at restore step diverged from "
                    f"pre-fault recording {rec_l!r}")
    losses = [row["loss"] for row in result.history]
    if len(losses) >= 6 and not (np.mean(losses[-3:]) < np.mean(losses[:3])):
        result.failures.append(
            f"loss did not descend across the soak: first {losses[:3]} "
            f"vs last {losses[-3:]}")
    return result
