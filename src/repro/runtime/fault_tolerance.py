"""Fault tolerance + straggler mitigation for BSP training.

BSP's weakness at scale is that the barrier waits for the slowest rank; the
paper makes the barrier itself ~free, which moves the problem to (a) dead
hosts and (b) stragglers.  This module provides the control-plane pieces,
exercised in tests and by examples/fault_tolerance_demo.py:

  * ``HostMonitor``    — heartbeat registry with timeout-based failure
    detection (the NoC-level 'error' wire analogue at cluster scope).
  * ``StragglerTracker`` — per-rank superstep durations; flags ranks slower
    than ``threshold × median`` over a window and computes a proportional
    micro-batch rebalance (gradient contributions stay weighted-correct).
  * ``surviving_domain`` — the FractalSync-native recovery policy: after
    failures, find the LARGEST complete synchronization subtree (fsync
    level/domain) containing no failed leaf; training resumes scoped to that
    domain while replacements spin up.  This is the paper's programmable
    sync-domain feature doing elastic scaling (DESIGN.md §2).
"""

from __future__ import annotations

import math
import statistics
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

from repro.core.tree import FractalTree

Coord = Tuple[int, ...]


@dataclass
class HostMonitor:
    """Heartbeat registry with timeout-based failure detection.

    ``clock`` injects the time source: None → wall clock
    (``time.monotonic``), or any zero-arg callable — e.g. the virtual
    ``runtime.chaos.StepClock`` — so soak runs detect heartbeat timeouts
    deterministically on the step clock.  An explicit ``now=`` argument
    always wins (the existing test surface).
    """

    num_hosts: int
    timeout_s: float = 30.0
    last_seen: Dict[int, float] = field(default_factory=dict)
    clock: Optional[Callable[[], float]] = None

    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return now
        return self.clock() if self.clock is not None else time.monotonic()

    def heartbeat(self, host: int, now: Optional[float] = None) -> None:
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} outside 0..{self.num_hosts - 1}")
        self.last_seen[host] = self._now(now)

    def failed_hosts(self, now: Optional[float] = None) -> Set[int]:
        now = self._now(now)
        out = set()
        for h in range(self.num_hosts):
            seen = self.last_seen.get(h)
            if seen is None or now - seen > self.timeout_s:
                out.add(h)
        return out

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.failed_hosts(now)


@dataclass
class StragglerTracker:
    window: int = 16
    threshold: float = 1.5
    durations: Dict[int, deque] = field(
        default_factory=lambda: defaultdict(lambda: deque(maxlen=16)))

    def record(self, rank: int, superstep_s: float) -> None:
        d = self.durations[rank]
        if d.maxlen != self.window:
            d = deque(d, maxlen=self.window)
            self.durations[rank] = d
        d.append(superstep_s)

    def rank_speed(self, rank: int) -> Optional[float]:
        d = self.durations.get(rank)
        return statistics.median(d) if d else None

    def stragglers(self) -> Set[int]:
        speeds = {r: statistics.median(d)
                  for r, d in self.durations.items() if d}
        if len(speeds) < 2:
            return set()
        med = statistics.median(speeds.values())
        return {r for r, s in speeds.items() if s > self.threshold * med}

    def rebalanced_shares(self, ranks: Sequence[int],
                          total_microbatches: int) -> Dict[int, int]:
        """Micro-batches ∝ 1/median-duration, ≥1 each, summing to total.

        In BSP the superstep ends at max(rank time); giving slow ranks fewer
        micro-batches flattens the barrier-arrival distribution — the same
        Ŝ = max(F) − max(R) metric the paper optimizes, attacked from the
        arrival side.

        Every share is ≥ 1 (a rank with zero micro-batches would
        desynchronize the collective), so the rebalance needs at least one
        micro-batch per rank — fewer raises instead of spinning forever in
        the drift-correction loop (every share would already be clamped at
        1 with the sum still above the target).
        """
        if not ranks:
            raise ValueError("rebalanced_shares needs at least one rank")
        if total_microbatches < len(ranks):
            raise ValueError(
                f"cannot split {total_microbatches} micro-batches over "
                f"{len(ranks)} ranks: every rank needs >= 1 (raise the "
                "micro-batch count or shrink the sync domain)")
        speeds = {}
        for r in ranks:
            m = self.rank_speed(r)
            speeds[r] = 1.0 / m if m else 1.0
        total_speed = sum(speeds.values())
        shares = {r: max(1, int(round(total_microbatches * s / total_speed)))
                  for r, s in speeds.items()}
        # Fix rounding drift deterministically, preserving monotonicity in
        # measured speed: excess comes off the SLOWEST ranks first (their
        # shares can only move toward the faster ranks'), shortfall goes to
        # the FASTEST first.  Ties break by rank id.
        fastest_first = sorted(ranks, key=lambda r: (-speeds[r], r))
        slowest_first = list(reversed(fastest_first))
        i = 0
        while sum(shares.values()) > total_microbatches:
            r = slowest_first[i % len(slowest_first)]
            if shares[r] > 1:
                shares[r] -= 1
            i += 1
        i = 0
        while sum(shares.values()) < total_microbatches:
            shares[fastest_first[i % len(fastest_first)]] += 1
            i += 1
        return shares


def surviving_domain(tree: FractalTree, failed: Iterable[Coord]
                     ) -> Tuple[int, Tuple[Coord, ...]]:
    """Largest complete sync subtree (fsync level + member tiles) avoiding
    every failed leaf.  Returns (level, tiles); level 0 = a single tile."""
    failed = set(failed)
    alive = [t for t in tree.tiles() if t not in failed]
    if not alive:
        raise RuntimeError("no surviving tiles")
    best: Tuple[int, Tuple[Coord, ...]] = (0, (alive[0],))
    for level in range(tree.num_levels, 0, -1):
        for domain in tree.domains(level):
            if not failed.intersection(domain):
                return level, domain
    return best
