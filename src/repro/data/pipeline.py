"""Deterministic synthetic data pipeline (host-sharded, prefetching).

Real pretraining corpora are out of scope for this container; the pipeline
generates reproducible synthetic token streams with realistic properties:

  * Zipfian unigram distribution (vocab-scaled) + short-range Markov
    structure, so losses are non-degenerate and compressible;
  * deterministic per-(host, step) seeding — restart-safe: the sequence of
    batches after checkpoint restore is identical (tested);
  * host sharding: host h of H serves global-batch rows [h·B/H, (h+1)·B/H) —
    the multi-host layout jax.make_array_from_process_local_data expects;
  * frontend stubs: paligemma gets unit-norm SigLIP-like patch embeddings,
    musicgen a conditioning prefix — same ShapeDtypeStructs as the dry-run;
  * background prefetch (thread + queue) to overlap host data generation
    with device steps.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig


def reshard_for_shares(batch: Dict[str, np.ndarray],
                       shares: Sequence[int]) -> Dict[str, np.ndarray]:
    """Re-shard a host batch for UNEVEN per-rank micro-batch shares.

    Input layout: ``sum(shares) × mb`` rows of true data — micro-batch j
    occupies rows ``[j*mb, (j+1)*mb)``.  Output layout: the padded
    per-rank grid the trainer's ``shares=`` path consumes — rank r owns
    rows ``[r*n_max*mb, (r+1)*n_max*mb)`` with its ``shares[r]`` assigned
    micro-batches first (contiguous from the global sequence, so every
    micro-batch is computed exactly once across ranks) and zero padding
    after (never touched: the trainer's ``fori_loop`` trip count stops at
    ``shares[r]``).  Even shares are the identity layout, so this
    transform composes freely with the straggler-rebalance actuator.
    """
    shares = [int(s) for s in shares]
    if not shares or any(s < 1 for s in shares):
        raise ValueError(f"shares must be >= 1 each, got {shares}")
    m_total, n_max = sum(shares), max(shares)
    rows = next(iter(batch.values())).shape[0]
    if rows % m_total:
        raise ValueError(f"batch rows {rows} not divisible by "
                         f"sum(shares) = {m_total}")
    mb = rows // m_total
    out = {}
    for k, v in batch.items():
        padded = np.zeros((len(shares) * n_max * mb,) + v.shape[1:], v.dtype)
        off = 0
        for r, s_r in enumerate(shares):
            padded[r * n_max * mb:(r * n_max + s_r) * mb] = \
                v[off * mb:(off + s_r) * mb]
            off += s_r
        out[k] = padded
    return out


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    markov_order: int = 1
    zipf_a: float = 1.2


class SyntheticLM:
    """Zipf + Markov synthetic token stream."""

    def __init__(self, cfg: ArchConfig, dcfg: DataConfig):
        if dcfg.global_batch % dcfg.num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.dcfg = dcfg
        self.local_batch = dcfg.global_batch // dcfg.num_hosts
        v = cfg.vocab_size
        base = np.random.default_rng(dcfg.seed)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-dcfg.zipf_a)
        self._probs = probs / probs.sum()
        # a fixed random "grammar": each token biases its successor window
        self._shift = base.integers(1, max(2, v // 7))

    def _rng(self, step: int) -> np.random.Generator:
        # independent of host count: seed by (step, global row block)
        return np.random.default_rng(
            (self.dcfg.seed, step, self.dcfg.host_id))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, T, v = self.local_batch, self.dcfg.seq_len, self.cfg.vocab_size
        base = rng.choice(v, size=(B, T + 1), p=self._probs)
        # Markov-ify: half the tokens continue their predecessor's window
        cont = rng.random((B, T)) < 0.5
        nxt = (base[:, :-1] + self._shift) % v
        base[:, 1:][cont] = nxt[cont]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.frontend:
            emb = rng.standard_normal(
                (B, self.cfg.frontend_tokens, self.cfg.frontend_dim)
            ).astype(np.float32)
            emb /= np.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6
            out["frontend"] = emb
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Thread-backed prefetch queue over any step-indexed source."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
