"""Training entry point.

Single-process usage (CPU devices; multi-host launch wires the same pieces
with per-host data sharding):

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b-smoke \
      --steps 50 --batch 8 --seq 128 --schedule fractal [--devices 8]

``--schedule xla`` uses the GSPMD tier; anything else uses the explicit BSP
superstep (fractal | ring | xy | naive | hierarchical | tree | auto) with
optional ``--compression {bf16,int8}`` — the paper's technique end to end.
``auto`` asks the cost-model autotuner (core.autotune) to pick the schedule
for the mesh/payload at build time.

``--bucket-mb N`` partitions the gradients into ~N MB reverse-layer buckets
and pipelines one collective per bucket (SuperstepEngine); with
``--schedule auto`` the autotuner picks a schedule *per bucket*.
``--bucket-mb auto`` searches the bucket boundaries themselves (dynamic
program over leaf prefix sums against the overlap-aware cost model), and
``--bucket-codec auto`` lets the tuner pick a wire codec per bucket.
``--calibrate`` times a grid of real collectives on the launch devices
first and fits the cost model's link parameters to the measurements, so
every "auto" pick is priced with platform numbers instead of defaults.
``--no-overlap`` is the A/B switch back to the monolithic single-collective
superstep; ``--grad-accum K`` accumulates over K micro-batches per rank.
"""

import argparse
import os
import sys


def _bucket_mb_arg(v):
    return "auto" if v == "auto" else float(v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--schedule", default="fractal")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--fsync-level", type=int, default=None)
    ap.add_argument("--bucket-mb", type=_bucket_mb_arg, default=None,
                    help="pipeline gradient sync over ~N MB buckets "
                         "(reverse-layer order; default: monolithic), or "
                         "'auto' for the DP bucket-boundary search")
    ap.add_argument("--bucket-codec", default=None,
                    choices=["auto", "none", "bf16", "int8"],
                    help="per-bucket wire codec: 'auto' lets the tuner "
                         "pick per bucket (default: uniform --compression)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit cost-model link params from measured "
                         "collectives on the launch devices before tuning")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--no-overlap collapses bucketing back to the "
                         "monolithic superstep (A/B baseline)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="micro-batches accumulated per rank per superstep")
    ap.add_argument("--devices", type=int, default=0,
                    help="host-device override (set before jax init)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import ArchConfig
    from repro.core.bsp import BSPConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.optim import adamw
    from repro.runtime import trainer
    from repro.runtime.loop import LoopConfig, TrainLoop, resume_or_init

    cfg = get_config(args.arch)
    n_dev = len(jax.devices())
    dp = n_dev
    mesh = make_mesh((dp, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                             warmup_steps=max(1, args.steps // 10))

    params = T.init_params(cfg, jax.random.key(args.seed))
    print(f"arch={cfg.name} devices={n_dev} params="
          f"{sum(x.size for x in jax.tree.leaves(params)):,}")

    ckpt_meta = {}
    if args.schedule == "xla":
        step_fn, (pspec, ospec, bspec) = trainer.make_gspmd_train_step(
            cfg, mesh, acfg)
        from repro.models.sharding import named
        params = jax.device_put(params, named(mesh, pspec))
        opt = adamw.init(params, acfg)
        state = (params, opt)
        bshard = {k: NamedSharding(mesh, s) for k, s in bspec.items()}
    else:
        link = None
        if args.calibrate:
            # Fitted params are persisted next to the checkpoints and
            # RELOADED on resume: refitting from fresh (noisy) timings
            # could move the DP bucket boundaries and invalidate the
            # checkpointed moment layout with no way back.
            import dataclasses
            import json
            cal_path = (os.path.join(args.checkpoint_dir,
                                     "link_calibration.json")
                        if args.checkpoint_dir else None)
            if cal_path and os.path.exists(cal_path):
                from repro.core.cost_model import LinkParams
                with open(cal_path) as f:
                    link = LinkParams(**json.load(f)["link"])
                print(f"calibrate: reloaded {link.name} from {cal_path}")
            elif n_dev >= 2:
                from repro.core.calibrate import fit_link_params
                # fit on the largest power-of-two sub-mesh the devices allow
                fit = fit_link_params(min_devices=2)
                print(fit.describe())
                link = fit.link
                if cal_path:
                    os.makedirs(args.checkpoint_dir, exist_ok=True)
                    with open(cal_path, "w") as f:
                        json.dump({"link": dataclasses.asdict(link)}, f,
                                  indent=2)
            else:
                print("calibrate: skipped (needs ≥2 devices; "
                      "pass --devices 8)")
        bsp = BSPConfig(sync_axes=("data",), schedule=args.schedule,
                        compression=args.compression,
                        fsync_level=args.fsync_level,
                        bucket_mb=args.bucket_mb,
                        overlap=args.overlap,
                        bucket_codec=args.bucket_codec,
                        link=link)
        step_fn, init_state = trainer.make_bsp_train_step(
            cfg, mesh, acfg, bsp, grad_accum=args.grad_accum)
        state = init_state(params)
        ckpt_meta = {"superstep_layout": init_state.superstep_layout}
        bshard = {k: NamedSharding(mesh, P("data", *([None] * pad)))
                  for k, pad in (("tokens", 1), ("labels", 1),
                                 ("frontend", 2))}
        if not cfg.frontend:
            bshard.pop("frontend")

    state, start = resume_or_init(args.checkpoint_dir, state,
                                  expect_meta=ckpt_meta)
    data = SyntheticLM(cfg, DataConfig(global_batch=args.batch,
                                       seq_len=args.seq, seed=args.seed))
    loop = TrainLoop(
        step_fn=step_fn, state=state, data=data,
        cfg=LoopConfig(total_steps=args.steps,
                       checkpoint_every=args.checkpoint_every,
                       checkpoint_dir=args.checkpoint_dir),
        batch_shardings=bshard, start_step=start, ckpt_meta=ckpt_meta)
    out = loop.run()
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    return out


if __name__ == "__main__":
    main()
