"""Roofline-term extraction from a compiled XLA executable (deliverable g).

``compiled.cost_analysis()`` undercounts scanned programs: XLA's
HloCostAnalysis counts a While body ONCE, ignoring the trip count (verified
by probe in ``benchmarks/probes.py``) — for a 61-layer scanned model that is
a ~61× error, and every collective inside the layer scan is likewise counted
once.  This module therefore re-derives the roofline terms directly from the
optimized (post-SPMD) HLO text:

  1. split the module into computations; map instruction → result type;
  2. build the call-graph multiplier: ENTRY ×1, While bodies × their
     ``known_trip_count`` backend config, fusion/conditional/call edges ×1;
  3. FLOPs     = Σ dot ops: 2 · |result| · |contracted dims| · multiplier
     (CPU XLA keeps dots as ``dot`` ops with printed dimension numbers);
  4. HBM bytes = Σ top-level (non-fusion-body) instructions:
     (operand + result bytes) · multiplier — fusions are the memory-visible
     unit, their internals are register traffic;
  5. collective wire bytes per network tier (ici intra-pod / dcn cross-pod,
     pod = device id // 256), × multiplier, with ring-equivalent factors:

       all-reduce 2·V·(n−1)/n | all-gather/reduce-scatter/all-to-all
       V·(n−1)/n (V = full logical payload) | collective-permute V.

Shapes in partitioned HLO are per-device, so no further division by chips.
"""

from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", re.M)
# type may be a tuple "(f32[..], /*index=5*/ bf16[..], ...)" — comments
# contain '=' but never ')', so "anything but ')'" is the right class
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{}\s/]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)(?P<attrs>.*)$", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,{}\s]*\})\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "all-reduce-start", "all-gather-start",
                  "collective-permute-start", "ragged-all-to-all"}
SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
                  "constant", "after-all", "copy-start", "copy-done",
                  "while", "conditional", "call"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    total_b = 0
    total_e = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total_e += n
        total_b += n * DTYPE_BYTES[dtype]
    return total_e, total_b


def _type_bytes(type_str: str) -> int:
    return _shape_elems_bytes(type_str)[1]


def _dims_of(type_str: str) -> Optional[List[int]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclass
class Computation:
    name: str
    text: str
    is_entry: bool = False
    fusion_body: bool = False
    instrs: list = field(default_factory=list)   # _INSTR_RE matches
    defs: Dict[str, str] = field(default_factory=dict)  # name -> type str


def _split_computations(hlo: str) -> Dict[str, Computation]:
    """Split module text into computation blocks (headers at column 0)."""
    comps: Dict[str, Computation] = {}
    headers = []
    for m in re.finditer(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*?\)\s*->.*\{",
                         hlo, re.M):
        headers.append((m.start(), m.group(2), bool(m.group(1))))
    headers.sort()
    for i, (start, name, is_entry) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo)
        text = hlo[start:end]
        comp = Computation(name=name, text=text, is_entry=is_entry)
        for im in _INSTR_RE.finditer(text):
            comp.instrs.append(im)
            comp.defs[im.group("name")] = im.group("type")
        comps[name] = comp
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation via the call graph."""
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for c in comps.values():
        for im in c.instrs:
            op = im.group("op")
            attrs = im.group("attrs")
            if op == "while":
                trip = 1.0
                tm = _TRIP_RE.search(attrs)
                if tm:
                    trip = float(tm.group(1))
                bm = re.search(r"body=%?([\w.\-]+)", attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", attrs)
                if bm:
                    edges[c.name].append((bm.group(1), trip))
                if cm:
                    edges[c.name].append((cm.group(1), trip + 1))
            elif op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", attrs)
                if fm:
                    edges[c.name].append((fm.group(1), 1.0))
                    if fm.group(1) in comps:
                        comps[fm.group(1)].fusion_body = True
            elif op == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", attrs)
                if bm:
                    for bn in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        edges[c.name].append((bn, 1.0))
                for key in ("true_computation", "false_computation"):
                    km = re.search(key + r"=%?([\w.\-]+)", attrs)
                    if km:
                        edges[c.name].append((km.group(1), 1.0))
            elif op in ("call", "custom-call", "reduce", "sort", "scatter",
                        "map", "reduce-window", "select-and-scatter",
                        "all-reduce", "reduce-scatter"):
                am = re.search(r"to_apply=%?([\w.\-]+)", attrs)
                if am:
                    edges[c.name].append((am.group(1), 1.0))

    mult = {name: (1.0 if c.is_entry else 0.0) for name, c in comps.items()}
    for _ in range(len(comps) + 2):     # call graph is a DAG; fixed point
        changed = False
        new = {name: (1.0 if comps[name].is_entry else 0.0)
               for name in comps}
        for caller, outs in edges.items():
            for callee, w in outs:
                if callee in new:
                    new[callee] += mult.get(caller, 0.0) * w
        for name in comps:
            if not comps[name].is_entry and abs(new[name] - mult[name]) > 1e-9:
                changed = True
        if comps and not changed:
            break
        for name in comps:
            if not comps[name].is_entry:
                mult[name] = new[name]
    return mult


# ---------------------------------------------------------------------------
# analysis passes
# ---------------------------------------------------------------------------


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    dot_count: int = 0
    instr_count: int = 0
    unknown_trip_whiles: int = 0
    # XLA CPU has no native bf16 GEMM: it materializes f32 copies of every
    # bf16 dot operand (hoisted out of loops → f32 copies of all weights
    # live at entry).  Pure CPU-backend artifact — the TPU MXU consumes
    # bf16 natively — so we measure it and report TPU-adjusted memory.
    f32_upcast_copy_bytes: float = 0.0
    ops: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    wire_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    by_kind: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "dot_count": self.dot_count, "instr_count": self.instr_count,
                "unknown_trip_whiles": self.unknown_trip_whiles,
                "f32_upcast_copy_bytes": self.f32_upcast_copy_bytes,
                "collective_ops": dict(self.ops),
                "wire_bytes": dict(self.wire_bytes),
                "by_kind": dict(self.by_kind),
                "total_collective_bytes": self.total_collective_bytes}


def _parse_groups(attrs: str) -> Optional[List[List[int]]]:
    m = _IOTA_GROUPS_RE.search(attrs)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        arr = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        return arr.reshape(n_groups, group_size).tolist()
    m = _GROUPS_RE.search(attrs)
    if m:
        groups = []
        for grp in re.findall(r"\{([\d,\s]*)\}", m.group(1)):
            if grp.strip():
                groups.append([int(x) for x in grp.split(",")])
        return groups or None
    return None


def analyze_hlo(hlo: str, chips_per_pod: int = 256) -> HloStats:
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    st = HloStats()
    st.unknown_trip_whiles = len(
        [1 for c in comps.values() for im in c.instrs
         if im.group("op") == "while" and not _TRIP_RE.search(im.group("attrs"))])

    for c in comps.values():
        w = mult.get(c.name, 0.0)
        if w == 0.0:
            continue
        for im in c.instrs:
            op = im.group("op")
            st.instr_count += 1
            # ---- FLOPs: dots everywhere (fusion bodies included) ----------
            if op in ("dot", "dot_general") or op == "dot":
                res_dims = _dims_of(im.group("type")) or []
                lhs_name = re.findall(r"%([\w.\-]+)", im.group("operands"))
                kdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  im.group("attrs"))
                k = 1
                if kdims and lhs_name:
                    lhs_type = c.defs.get(lhs_name[0])
                    ldims = _dims_of(lhs_type) if lhs_type else None
                    if ldims:
                        for ci in kdims.group(1).split(","):
                            if ci.strip():
                                k *= ldims[int(ci)]
                flops = 2.0 * float(np.prod(res_dims or [0])) * k
                st.flops += flops * w
                st.dot_count += 1
            elif op == "convolution":
                # rare here; approximate 2·|result|·(window·in_ch)
                res = _dims_of(im.group("type")) or [0]
                st.flops += 2.0 * float(np.prod(res)) * w

            # ---- collectives ----------------------------------------------
            if op in COLLECTIVE_OPS:
                base = op.replace("-start", "")
                result_b = _type_bytes(im.group("type"))
                if op.endswith("-start"):
                    result_b /= 2          # start results carry (in, out)
                operand_b = sum(
                    _type_bytes(c.defs.get(nm, ""))
                    for nm in re.findall(r"%([\w.\-]+)", im.group("operands")))
                attrs = im.group("attrs")
                if base == "collective-permute":
                    tier = "ici"
                    pairs = _SRC_TGT_RE.search(attrs)
                    if pairs:
                        ids = [int(x) for x in
                               re.findall(r"\d+", pairs.group(1))]
                        if any(a // chips_per_pod != b // chips_per_pod
                               for a, b in zip(ids[::2], ids[1::2])):
                            tier = "dcn"
                    wire = operand_b or result_b
                else:
                    groups = _parse_groups(attrs)
                    if groups:
                        n = len(groups[0])
                        tier = "dcn" if any(
                            len({d // chips_per_pod for d in g}) > 1
                            for g in groups) else "ici"
                    else:
                        n, tier = 2, "ici"
                    frac = (n - 1) / n if n > 1 else 0.0
                    if base == "all-reduce":
                        wire = 2 * (operand_b or result_b) * frac
                    elif base == "all-gather":
                        wire = result_b * frac
                    elif base == "reduce-scatter":
                        wire = (operand_b * frac) if operand_b \
                            else result_b * max(n - 1, 0)
                    else:   # all-to-all / ragged
                        wire = (operand_b or result_b) * frac
                st.ops[base] += int(w) if w >= 1 else 1
                st.wire_bytes[tier] += wire * w
                st.by_kind[base] += wire * w

            # ---- HBM bytes: memory-visible (non-fusion-body) ops ----------
            if not c.fusion_body and op not in SKIP_BYTES_OPS:
                b = _type_bytes(im.group("type"))
                for nm in re.findall(r"%([\w.\-]+)", im.group("operands")):
                    b += _type_bytes(c.defs.get(nm, ""))
                st.hbm_bytes += b * w

            # ---- CPU bf16→f32 dot-operand upcast artifact ------------------
            if (not c.fusion_body and op == "fusion"
                    and im.group("type").lstrip().startswith("f32")):
                fm = re.search(r"calls=%?([\w.\-]+)", im.group("attrs"))
                if fm and fm.group(1) in comps:
                    body_ops = {i.group("op")
                                for i in comps[fm.group(1)].instrs}
                    if body_ops <= {"parameter", "copy", "convert",
                                    "bitcast", "transpose", "reshape"}:
                        st.f32_upcast_copy_bytes += \
                            _type_bytes(im.group("type")) * w
    return st


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (≈ per-chip injection)
DCN_BW = 25e9                # B/s / chip inter-pod (conservative)


def roofline_terms(st: HloStats) -> dict:
    t_compute = st.flops / PEAK_FLOPS
    t_memory = st.hbm_bytes / HBM_BW
    t_ici = st.wire_bytes.get("ici", 0.0) / ICI_BW
    t_dcn = st.wire_bytes.get("dcn", 0.0) / DCN_BW
    t_coll = t_ici + t_dcn
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "collective_ici_s": t_ici,
             "collective_dcn_s": t_dcn}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    terms["bound_s"] = max(t_compute, t_memory, t_coll)
    return terms
