import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the real step function (train_step for
train shapes, prefill/serve_step for inference shapes) with production
in/out shardings, then::

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective-bytes (hlo_analysis)

and records everything in results/dryrun/<mesh>/<arch>__<shape>.json.
Successful compilation at 256 and 512 devices is the proof that the sharding
configuration is coherent; the JSON feeds EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --cell gemma2-2b:train_4k \
      --mesh single [--opt remat=dots ...]
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs.base import SHAPES, SHAPE_BY_NAME, cell_applicable  # noqa: E402
from repro.launch import hlo_analysis as H     # noqa: E402
from repro.launch import specs as SP           # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import registry, transformer as T  # noqa: E402
from repro.optim import adamw                  # noqa: E402
from repro.runtime import trainer              # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# bf16 optimizer moments above this size, else f32 (EXPERIMENTS.md §Dry-run)
BF16_MOMENT_THRESHOLD = 30e9


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "multi"))


def _adamw_cfg(cfg):
    n = registry.count_params(cfg)
    state = "bfloat16" if n > BF16_MOMENT_THRESHOLD else "float32"
    import jax.numpy as jnp
    return adamw.AdamWConfig(state_dtype=jnp.dtype(state))


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               opts: dict | None = None):
    """Build + lower + compile one cell; returns (record, compiled)."""
    opts = opts or {}
    cfg = registry.get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}, None

    # ---- hillclimb levers (EXPERIMENTS.md §Perf) ----
    from repro.models import act_sharding as ACT
    from repro.models import layers as LYR
    T.set_remat(opts.get("remat", "block"))
    T.LOSS_CHUNK = int(opts.get("loss_chunk", 512))
    LYR.QUERY_CHUNK = int(opts.get("query_chunk", 512))
    ACT.SEQ_SHARD = opts.get("seq_shard", "0") in ("1", "true")
    mesh = _mesh(mesh_kind)
    t0 = time.time()

    if shape.kind == "train":
        step, _ = trainer.make_gspmd_train_step(cfg, mesh, _adamw_cfg(cfg))
        pshape = SP.params_specs(cfg)
        oshape = jax.eval_shape(lambda: adamw.init(pshape, _adamw_cfg(cfg)))
        args = (pshape, oshape, SP.batch_specs(cfg, shape))
    elif shape.kind == "prefill":
        step, _ = trainer.make_prefill_step(
            cfg, mesh, shape.global_batch,
            shape.seq_len + cfg.frontend_tokens)
        sp = SP.input_specs(cfg, shape)
        pshape = SP.params_specs(cfg)
        args = (pshape, sp["tokens"], sp["cache"]) + (
            (sp["frontend"],) if cfg.frontend else ())
    else:  # decode
        step, _ = trainer.make_decode_step(cfg, mesh, shape.global_batch,
                                           shape.seq_len)
        sp = SP.input_specs(cfg, shape)
        pshape = SP.params_specs(cfg)
        args = (pshape, sp["token"], sp["cache"], sp["offset"])

    with mesh:
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "status": "ok", "lower_s": round(t_lower, 1),
              "compile_s": round(t_compile, 1),
              "devices": int(math.prod(mesh.devices.shape)),
              "opts": opts}

    # ---- memory ----
    try:
        ma = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        if "argument_size_in_bytes" in record["memory"]:
            m = record["memory"]
            record["memory"]["total_per_device_gib"] = round(
                (m.get("argument_size_in_bytes", 0)
                 + m.get("temp_size_in_bytes", 0)) / 2**30, 3)
    except Exception as e:  # CPU backend may not support it
        record["memory"] = {"error": str(e)[:200]}

    # ---- cost / flops ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        record["cost"] = {k: float(v) for k, v in ca.items()
                          if isinstance(v, (int, float)) and
                          k in ("flops", "transcendentals", "bytes accessed")}
    except Exception as e:
        record["cost"] = {"error": str(e)[:200]}

    # ---- trip-count-corrected HLO analysis + roofline ----
    # (cost_analysis counts While bodies once — see hlo_analysis docstring)
    try:
        hlo = compiled.as_text()
        record["hlo_bytes"] = len(hlo)
        st = H.analyze_hlo(hlo)
        record["hlo_stats"] = st.as_dict()
        record["roofline"] = H.roofline_terms(st)
        # TPU-adjusted memory: strip the CPU bf16→f32 dot-operand copies
        # (MXU consumes bf16 natively; see hlo_analysis.HloStats)
        mem = record.get("memory", {})
        if "temp_size_in_bytes" in mem:
            adj = max(0.0, mem["temp_size_in_bytes"]
                      - st.f32_upcast_copy_bytes)
            mem["tpu_adjusted_total_gib"] = round(
                (mem.get("argument_size_in_bytes", 0) + adj) / 2**30, 3)
    except Exception as e:
        record["hlo_stats"] = {"error": str(e)[:300]}

    # ---- model flops (useful-compute ratio) ----
    n_total = registry.count_params(cfg)
    n_active = registry.count_params(cfg, active_only=True)
    record["params_total"] = n_total
    record["params_active"] = n_active
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    record["model_flops_global"] = float(mult * n_active * toks)
    record["model_flops_per_device"] = (record["model_flops_global"]
                                        / record["devices"])
    flops = record.get("hlo_stats", {}).get("flops")
    if flops:
        record["useful_flops_ratio"] = round(
            record["model_flops_per_device"] / flops, 4)
        rf = record.get("roofline", {})
        if rf.get("bound_s"):
            record["roofline_fraction"] = round(
                (record["model_flops_per_device"] / H.PEAK_FLOPS)
                / rf["bound_s"], 4)
    return record, compiled


def cell_path(arch, shape_name, mesh_kind, tag="") -> Path:
    d = RESULTS / mesh_kind
    d.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return d / f"{arch}__{shape_name}{suffix}.json"


def run_cell(arch, shape_name, mesh_kind, opts=None, tag="", force=False):
    out = cell_path(arch, shape_name, mesh_kind, tag)
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"cached  {arch:24s} {shape_name:12s} {mesh_kind:6s} "
              f"{rec.get('status')}")
        return rec
    try:
        rec, _ = lower_cell(arch, shape_name, mesh_kind, opts)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}"[:1500],
               "trace": traceback.format_exc()[-2000:], "opts": opts or {}}
    out.write_text(json.dumps(rec, indent=2))
    status = rec.get("status")
    extra = ""
    if status == "ok":
        extra = (f"compile={rec.get('compile_s', 0):.0f}s "
                 f"dom={rec.get('roofline', {}).get('dominant', '?')}")
    print(f"{status:7s} {arch:24s} {shape_name:12s} {mesh_kind:6s} {extra}",
          flush=True)
    return rec


def parse_opts(pairs):
    out = {}
    for p in pairs or []:
        k, _, v = p.partition("=")
        out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--cell", type=str, default=None,
                    help="arch:shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--opt", action="append", default=[],
                    help="k=v hillclimb option (e.g. remat=dots)")
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = registry.ARCH_IDS
    shapes = [s.name for s in SHAPES]
    if args.cell:
        a, _, s = args.cell.partition(":")
        archs, shapes = [a], [s]
    if args.arch:
        archs = [args.arch]
    if args.shape:
        shapes = [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    opts = parse_opts(args.opt)
    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                results.append(run_cell(arch, shape, mk, opts,
                                        tag=args.tag, force=args.force))
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    n_err = sum(r.get("status") == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
