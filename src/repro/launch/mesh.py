"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh as _compat_make_mesh


import math


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16×16 = 256 chips ("data","model"); the multi-pod
    variant stacks 2 pods on a leading "pod" axis (512 chips).

    The dry-run process exposes 512 host devices; the single-pod mesh uses
    the first 256 (device id // 256 == pod id, which the HLO collective
    analysis relies on)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)")
    return _compat_make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)


def make_mesh(shape, axes, devices=None):
    return _compat_make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)


def describe(mesh) -> str:
    return " × ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
