"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape)`` returns the abstract inputs for the step function
that shape exercises:

  * train_*    → train_step(params, opt_state, batch)
  * prefill_*  → prefill_step(params, tokens[, frontend], cache)
  * decode_* / long_* → serve_step(params, token, cache, offset)
    (one new token against a KV/state cache of seq_len)

Modality frontends are STUBS per the brief: paligemma gets 256 precomputed
SigLIP patch embeddings (1152-d), musicgen a 64-token conditioning prefix
(768-d) — ShapeDtypeStructs here, synthetic tensors in the data pipeline.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32),
             "labels": sds((B, S), jnp.int32)}
    if cfg.frontend:
        batch["frontend"] = sds((B, cfg.frontend_tokens, cfg.frontend_dim),
                                jnp.bfloat16)
    return batch


def params_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.key(0))


def cache_specs_abstract(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs keyed by step-function argument name."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32),
               "cache": cache_specs_abstract(cfg, B, S + cfg.frontend_tokens)}
        if cfg.frontend:
            out["frontend"] = sds((B, cfg.frontend_tokens, cfg.frontend_dim),
                                  jnp.bfloat16)
        return out
    if shape.kind == "decode":
        return {"token": sds((B, 1), jnp.int32),
                "cache": cache_specs_abstract(cfg, B, S),
                "offset": sds((), jnp.int32)}
    raise ValueError(shape.kind)
