"""Serving entry point: batched prefill + decode with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b-smoke \
      --requests 8 --prompt-len 32 --gen 16 [--devices 8]

Implements a minimal production serving core:
  * batched prefill (one jit'd call per admission wave),
  * decode loop with a shared ring KV cache,
  * greedy or temperature sampling,
  * per-request completion bookkeeping (a finished request's slot keeps
    decoding padding tokens until the wave drains — slot reuse/continuous
    admission is the documented extension point).
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = get_config(args.arch)
    key = jax.random.key(args.seed)
    params = T.init_params(cfg, key)
    B = args.requests
    max_len = args.prompt_len + args.gen + cfg.frontend_tokens

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(B, args.prompt_len),
                           dtype=np.int32)
    frontend = None
    if cfg.frontend:
        frontend = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32))

    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lambda p, t, c, f: T.prefill(p, cfg, t, c, f))
    decode = jax.jit(lambda p, t, c, o: T.decode_step(p, cfg, t, c, o))

    t0 = time.monotonic()
    logits, cache, offset = prefill(params, jnp.asarray(prompts), cache,
                                    frontend)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    def sample(key, logits):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / args.temperature).astype(jnp.int32)

    toks = []
    tok = sample(key, logits)[:, None]
    t0 = time.monotonic()
    for i in range(args.gen):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, tok, cache, offset + i)
        key, sub = jax.random.split(key)
        tok = sample(sub, logits)[:, None]
    jax.block_until_ready(logits)
    t_decode = time.monotonic() - t0

    gen = np.concatenate(toks, axis=1)
    print(f"arch={cfg.name} requests={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms "
          f"({B*args.prompt_len/max(t_prefill,1e-9):9.0f} tok/s)")
    print(f"decode : {t_decode*1e3:8.1f} ms "
          f"({B*args.gen/max(t_decode,1e-9):9.0f} tok/s)")
    print("sample outputs:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
