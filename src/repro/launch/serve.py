"""Serving entry point: continuous-batching engine over a slot pool.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b-smoke \
      --requests 8 --prompt-len 32 --gen 16 --max-slots 4 \
      [--kv-mode paged --block-size 16 --kv-blocks 64] \
      [--arrival poisson:50] [--eos-id 2] [--devices 8] [--mode wave]

Built on ``repro.serve``: a fixed pool of ``--max-slots`` decode slots over
one shared cache; queued requests are admitted the moment EOS (or the
per-request budget) frees capacity, with chunked prefill interleaved
between decode steps.  Per-layer decode state goes through the SlotState
protocol, so every token-only architecture serves — pure attention, pure
recurrent (mamba / xLSTM), and hybrids (Jamba) mixing KV and recurrent
backends in one run.  Reports per-request TTFT, per-step throughput and
slot occupancy.  ``--mode wave`` runs the old wave-at-a-time loop — the
token-identity test oracle — for A/B comparison (see
``benchmarks/serve_bench.py``).

  --arrival immediate | poisson:RATE | trace:SPEC   synthetic arrivals
  --gen-spread K        ragged output budgets: gen drawn from [gen-K, gen]
  --max-slots S         decode slot pool size (shards over --devices)
  --kv-mode M           contiguous (one max_len row per slot) or paged
                        (pooled blocks + block tables: admission gated on
                        free blocks, prefix-cache sharing, preemption)
  --block-size B        paged: positions per physical block
  --kv-blocks N         paged: pool size (0 = match contiguous capacity)
  --paged-kernel K      paged decode attention lowering: auto (fused Pallas
                        kernel on TPU, gather oracle elsewhere) | pallas
                        (force the fused kernel; interpret mode off-TPU) |
                        ref (force the gather-then-attend oracle)
  --slot-state M        KV-layer backend override: auto (follow --kv-mode) |
                        contiguous | paged; recurrent layers always use the
                        recurrent-row backend
  --rec-slots R         recurrent-state rows (0 = match --max-slots); fewer
                        rows than slots makes rows the scarce admission
                        resource
  --clock C             step (virtual, deterministic; idle gaps jump) |
                        wall (measured seconds; idle gaps really sleep)
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16,
                    help="per-request generation budget (first token incl.)")
    ap.add_argument("--gen-spread", type=int, default=0,
                    help="ragged budgets: draw from [gen-K, gen] per request")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that completes a request and frees its "
                         "slot for the next admission")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--kv-mode", choices=("contiguous", "paged"),
                    default="contiguous")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV: cache positions per physical block")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged KV: physical blocks in the pool "
                         "(0 = match contiguous capacity)")
    ap.add_argument("--paged-kernel", choices=("auto", "pallas", "ref"),
                    default="auto",
                    help="paged decode attention lowering (auto: fused "
                         "Pallas kernel on TPU, gather oracle elsewhere)")
    ap.add_argument("--slot-state", choices=("auto", "contiguous", "paged"),
                    default="auto",
                    help="KV-layer backend override (auto: follow "
                         "--kv-mode); recurrent layers always use the "
                         "recurrent-row backend")
    ap.add_argument("--rec-slots", type=int, default=0,
                    help="recurrent-state rows (0 = match --max-slots)")
    ap.add_argument("--clock", choices=("step", "wall"), default="step",
                    help="serve clock: step (virtual, deterministic) or "
                         "wall (measured seconds, idle gaps sleep)")
    ap.add_argument("--arrival", default="immediate",
                    help="immediate | poisson:RATE | trace:SPEC")
    ap.add_argument("--mode", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "wave" and args.kv_mode == "paged":
        ap.error("--mode wave serves from the contiguous cache only")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             parse_arrival_spec, serve_waves)

    cfg = get_config(args.arch)
    params = T.init_params(cfg, jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    arrivals = parse_arrival_spec(args.arrival, args.requests, args.seed)
    requests = []
    for i in range(args.requests):
        gen = args.gen if args.gen_spread <= 0 else int(
            rng.integers(max(1, args.gen - args.gen_spread), args.gen + 1))
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.prompt_len,)).tolist()
        requests.append(Request(req_id=i, prompt=prompt, max_new_tokens=gen,
                                arrival_s=arrivals[i]))

    max_len = args.prompt_len + args.gen + 1
    if args.kv_mode == "paged":
        # the paged backend needs block_size | max_len (virtual view shape
        # == contiguous row shape, the token-identity invariant)
        max_len = -(-max_len // args.block_size) * args.block_size
    ecfg = EngineConfig(
        max_slots=args.max_slots,
        max_len=max_len,
        prefill_chunk=args.prefill_chunk,
        temperature=args.temperature,
        eos_id=args.eos_id,
        seed=args.seed,
        kv_mode=args.kv_mode,
        slot_state=args.slot_state,
        rec_slots=args.rec_slots,
        block_size=args.block_size,
        kv_blocks=args.kv_blocks,
        paged_kernel=args.paged_kernel,
        clock=args.clock)

    mesh = None
    if args.devices:
        if args.mode == "wave":
            print(f"note: --devices {args.devices} ignored in wave mode "
                  "(the baseline runs unsharded)")
        else:
            mesh = make_mesh((args.devices,), ("data",))

    print(f"arch={cfg.name} mode={args.mode} kv={args.kv_mode} "
          f"requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}"
          f"{f'±{args.gen_spread}' if args.gen_spread else ''} "
          f"slots={args.max_slots} arrival={args.arrival}"
          + (f" block_size={args.block_size}" if args.kv_mode == "paged"
             else "")
          + (f" devices={args.devices}" if args.devices else ""))

    if args.mode == "wave":
        results, metrics = serve_waves(cfg, params, ecfg, requests)
    else:
        engine = ServeEngine(cfg, params, ecfg, mesh=mesh)
        print(f"slot-state plan: {engine.plan.describe()}"
              + (f" ({engine.rec.capacity} recurrent rows)"
                 if engine.rec is not None else ""))
        results = engine.run(requests)
        metrics = engine.metrics

    print(metrics.report())
    shown = sorted(results)[:2]
    print("sample outputs:", [results[i][:8] for i in shown])
    return results, metrics


if __name__ == "__main__":
    main()
