"""Serving entry point: batched prefill + decode with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b-smoke \
      --requests 8 --prompt-len 32 --gen 16 [--eos-id 2] [--devices 8]

Implements a minimal production serving core:
  * batched prefill (one jit'd call per admission wave),
  * decode loop with a shared ring KV cache,
  * greedy or temperature sampling,
  * per-request completion bookkeeping with early wave exit: once every
    request has emitted ``--eos-id`` (or hit ``--gen`` tokens) the decode
    loop stops instead of decoding padding until the wave drains — slot
    reuse/continuous admission is the documented extension point.
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that completes a request; the decode "
                         "loop exits early once every request emitted it")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = get_config(args.arch)
    key = jax.random.key(args.seed)
    params = T.init_params(cfg, key)
    B = args.requests
    max_len = args.prompt_len + args.gen + cfg.frontend_tokens

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(B, args.prompt_len),
                           dtype=np.int32)
    frontend = None
    if cfg.frontend:
        frontend = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32))

    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lambda p, t, c, f: T.prefill(p, cfg, t, c, f))
    decode = jax.jit(lambda p, t, c, o: T.decode_step(p, cfg, t, c, o))

    t0 = time.monotonic()
    logits, cache, offset = prefill(params, jnp.asarray(prompts), cache,
                                    frontend)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    def sample(key, logits):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / args.temperature).astype(jnp.int32)

    toks = []
    tok = sample(key, logits)[:, None]
    done = np.zeros((B,), dtype=bool)      # requests that have emitted EOS
    n_decodes = 0                          # decode() calls actually made
    t0 = time.monotonic()
    for i in range(args.gen):
        host_tok = np.asarray(tok)
        toks.append(host_tok)
        if args.eos_id is not None:
            done |= host_tok[:, 0] == args.eos_id
            if done.all():
                # every request in the wave finished: stop decoding instead
                # of burning steps on padding until the wave drains
                break
        if i == args.gen - 1:
            break                          # last sampled token already kept
        logits, cache = decode(params, tok, cache, offset + i)
        n_decodes += 1
        key, sub = jax.random.split(key)
        tok = sample(sub, logits)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0

    gen = np.concatenate(toks, axis=1)
    n_steps = gen.shape[1]
    print(f"arch={cfg.name} requests={B} prompt={args.prompt_len} "
          f"gen={args.gen} decoded={n_steps}"
          + (f" (early exit: all {B} requests hit eos={args.eos_id})"
             if n_steps < args.gen else ""))
    print(f"prefill: {t_prefill*1e3:8.1f} ms "
          f"({B*args.prompt_len/max(t_prefill,1e-9):9.0f} tok/s)")
    # throughput over the decode calls that ran (the first token of the
    # wave comes from prefill's logits, not a decode step)
    dec_rate = B * n_decodes / max(t_decode, 1e-9) if n_decodes else 0.0
    print(f"decode : {t_decode*1e3:8.1f} ms "
          f"({dec_rate:9.0f} tok/s over {n_decodes} steps)")
    print("sample outputs:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
