"""Gemma-2 2B [arXiv:2408.00118; hf]. Local(4096-window)/global alternating,
logit softcaps, sandwich norms, GeGLU, tied + scaled embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    mlp="geglu",
    norm_style="sandwich",
    embed_scale=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    attn_softcap=50.0,
    sliding_window=4096,
    layer_pattern=("local", "global") * 13,
    rope_theta=10_000.0,
    max_seq=8_192,
    sub_quadratic=False,
    source="[arXiv:2408.00118; hf:google/gemma-2-2b]",
)
