"""Phi-4-mini 3.8B [arXiv:2412.08905; hf]. RoPE SwiGLU GQA, 200k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    head_dim=128,
    tie_embeddings=True,
    rope_theta=10_000.0,
    max_seq=131_072,
    sub_quadratic=False,
    source="[arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct]",
)
