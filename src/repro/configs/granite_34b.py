"""Granite-34B-Code [arXiv:2405.04324; hf]. Deep (88L) llama-style MQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,            # MQA
    d_ff=24_576,
    vocab_size=49_152,
    head_dim=128,
    mlp="gelu",
    rope_theta=10_000.0,
    max_seq=8_192,
    sub_quadratic=False,
    source="[arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base]",
)
