"""PaliGemma-3B [arXiv:2407.07726; hf]. SigLIP-So400m patch embeddings
(STUB: 256 precomputed 1152-d tokens) + Gemma-2B backbone, prefix-LM."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # Gemma-1 MQA
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    frontend="vision_stub",
    frontend_tokens=256,       # 224px / 14 patch → 16×16
    frontend_dim=1152,         # SigLIP-So400m width
    prefix_lm=True,
    max_seq=8_192,
    sub_quadratic=False,
    source="[arXiv:2407.07726; hf:google/paligemma-3b-pt-224]",
)
