"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (``src/repro/configs/<id>.py``),
each with a ``reduced()`` smoke-test variant (same family, tiny dims).  The
full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); smoke tests run the reduced configs on CPU.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    num_shared: int = 0              # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router: str = "softmax"          # softmax | sigmoid (DeepSeek v3)
    norm_topk: bool = True           # renormalize selected gates
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba (Jamba) / xLSTM cell parameters."""
    kind: str = "mamba"              # mamba | mlstm | slstm
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None    # default ceil(d_model/16)
    num_heads: int = 4               # xLSTM heads


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False            # Qwen3
    mlp: str = "swiglu"              # swiglu | geglu
    pos_embed: str = "rope"          # rope | sinusoidal
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    norm_style: str = "pre"          # pre | sandwich (Gemma-2)
    embed_scale: bool = False        # Gemma: embeddings scaled by sqrt(D)
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None   # Gemma-2 final softcap
    attn_softcap: Optional[float] = None    # Gemma-2 attention softcap
    sliding_window: Optional[int] = None    # local-attention window
    # per-layer block kinds; scanned in homogeneous segments. kinds:
    #   attn      - dense attention + MLP
    #   attn_moe  - dense attention + MoE
    #   local     - sliding-window attention + MLP
    #   global    - full attention + MLP (used with `local` for Gemma-2)
    #   mla_moe   - MLA attention + MoE (DeepSeek)
    #   mla       - MLA attention + dense MLP
    #   mamba     - Mamba SSM + MLP
    #   mamba_moe - Mamba SSM + MoE
    #   mlstm     - xLSTM mLSTM block (no separate FFN)
    #   slstm     - xLSTM sLSTM block (FFN inside)
    layer_pattern: Tuple[str, ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp_depth: int = 0               # DeepSeek multi-token prediction modules
    mtp_loss_weight: float = 0.3
    frontend: Optional[str] = None   # vision_stub | audio_stub
    frontend_tokens: int = 0         # prefix length provided by the frontend
    frontend_dim: int = 0            # raw frontend embedding dim (projected)
    prefix_lm: bool = False          # bidirectional attention over the prefix
    max_seq: int = 32_768
    sub_quadratic: bool = False      # eligible for long_500k decode
    param_dtype: str = "bfloat16"
    source: str = ""                 # provenance note [arXiv/hf; tier]

    def __post_init__(self):
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern",
                               ("attn",) * self.num_layers)
        if len(self.layer_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: layer_pattern has {len(self.layer_pattern)} "
                f"entries for {self.num_layers} layers")
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} not a "
                             f"multiple of kv heads {self.num_kv_heads}")

    # ------------------------------------------------------------------ #

    def segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Group layer_pattern into repeated homogeneous units for lax.scan.

        Returns ((unit_kinds, repeat), ...) where unit_kinds is the smallest
        repeating unit of a run, e.g. 26×(local,global) → (("local","global"), 13).
        """
        pattern = list(self.layer_pattern)
        # find a small period that tiles the whole pattern
        n = len(pattern)
        for period in range(1, n + 1):
            if n % period == 0 and pattern == pattern[:period] * (n // period):
                unit = tuple(pattern[:period])
                return ((unit, n // period),)
        # fall back: split into maximal uniform runs
        segs = []
        i = 0
        while i < n:
            j = i
            while j < n and pattern[j] == pattern[i]:
                j += 1
            segs.append(((pattern[i],), j - i))
            i = j
        return tuple(segs)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head); used for the
        roofline's MODEL_FLOPS = 6·N·D and the memory budget."""
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale = lambda v, lo, f: max(lo, v // f)
        pat = self.layer_pattern
        # keep one period of the pattern (≥2 layers when pattern alternates)
        unit, _reps = self.segments()[0]
        keep = len(unit) if len(unit) > 1 else min(2, self.num_layers)
        new_pat = (pat[:keep] if len(set(pat)) == 1
                   else unit)
        if self.name == "deepseek-v3-671b":
            # keep the dense→moe transition: 1 dense + 1 moe layer
            new_pat = ("mla", "mla_moe")
            keep = 2
        kw = dict(
            name=self.name + "-smoke",
            num_layers=len(new_pat),
            layer_pattern=new_pat,
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=16 if self.sliding_window else None,
            max_seq=128,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            frontend_dim=64 if self.frontend_dim else 0,
            mtp_depth=min(self.mtp_depth, 1),
            param_dtype="float32",
        )
        if self.moe:
            # dropless at smoke scale (capacity ≥ T·k) so decode ≡ forward
            # exactly; production capacity_factor stays GShard-style 1.25
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2), d_expert=64,
                capacity_factor=float(min(self.moe.num_experts, 8)))
        if self.mla:
            kw["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8, d_conv=4,
                                            num_heads=2)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM-family architecture
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k assigned to SSM/hybrid only"
    return True, ""
