"""DeepSeek-V3 671B [arXiv:2412.19437; hf]. MLA + 1 shared + 256 routed
top-8 (sigmoid aux-loss-free router) + MTP; first 3 layers dense."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: per-head latents, kv=128 per assignment
    d_ff=18432,                # dense layers (brief's 2048 = routed expert size)
    vocab_size=129280,
    head_dim=128,
    rope_theta=10_000.0,
    layer_pattern=("mla",) * 3 + ("mla_moe",) * 58,
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  router="sigmoid", norm_topk=True, capacity_factor=1.25),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    max_seq=131_072,
    sub_quadratic=False,
    source="[arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3]",
)
