"""Jamba-v0.1 52B [arXiv:2403.19887; hf]. Mamba+attention 1:7 interleave
(attn at layer i%8==4), MoE 16e top-2 every other layer; hybrid → runs
long_500k. No explicit positional embeddings (Mamba supplies order)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

_UNIT = ("mamba", "mamba_moe", "mamba", "mamba_moe",
         "attn", "mamba_moe", "mamba", "mamba_moe")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    head_dim=128,
    pos_embed="none",
    layer_pattern=_UNIT * 4,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14_336, num_shared=0,
                  router="softmax", norm_topk=True, capacity_factor=1.25),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    max_seq=524_288,
    sub_quadratic=True,
    source="[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]",
)
