"""Qwen3-235B-A22B [hf:Qwen/Qwen3-235B-A22B]. 128 experts top-8, GQA kv=4,
head_dim 128 with QK-norm."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,                # unused: every layer is MoE (d_expert=1536)
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    layer_pattern=("attn_moe",) * 94,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536, num_shared=0,
                  router="softmax", norm_topk=True, capacity_factor=1.25),
    max_seq=40_960,
    sub_quadratic=False,
    source="[hf:Qwen/Qwen3-235B-A22B]",
)
