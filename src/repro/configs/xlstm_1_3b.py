"""xLSTM-1.3B [arXiv:2405.04517; unverified]. sLSTM + mLSTM blocks at 7:1,
no separate FFN on mLSTM blocks (d_ff=0); O(1) recurrent state → runs the
long_500k decode shape."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=512,
    pos_embed="none",
    layer_pattern=(("mlstm",) * 7 + ("slstm",)) * 6,
    ssm=SSMConfig(kind="mlstm", d_conv=4, expand=2, num_heads=4),
    max_seq=524_288,
    sub_quadratic=True,
    source="[arXiv:2405.04517; unverified]",
)
