"""MusicGen-medium [arXiv:2306.05284; hf]. Decoder-only over EnCodec tokens
(vocab 2048); conditioning frontend STUB provides a 64-token prefix of
T5-width embeddings (the paper uses cross-attention; we inject conditioning
as a projected prefix — noted in DESIGN.md). Sinusoidal positions, MHA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,           # full MHA
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    mlp="gelu",
    pos_embed="sinusoidal",
    frontend="audio_stub",
    frontend_tokens=64,
    frontend_dim=768,          # T5-base conditioning width
    max_seq=32_768,
    sub_quadratic=False,
    source="[arXiv:2306.05284; hf:facebook/musicgen-medium]",
)
