"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the jax 0.8-era API (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.sharding.AxisType``, Pallas
``CompilerParams``).  CI containers — and the baked-in toolchain here — carry
jax 0.4.x, where the same capabilities live under different names:

  =====================  ==========================================
  modern (0.6+)          0.4.x fallback
  =====================  ==========================================
  jax.shard_map          jax.experimental.shard_map.shard_map
    axis_names=manual      auto = mesh axes − manual
    check_vma=...          check_rep=...
  jax.sharding.AxisType  absent (meshes are implicitly Auto)
  jax.make_mesh(...,     jax.make_mesh without the kwarg
    axis_types=...)
  pltpu.CompilerParams   pltpu.TPUCompilerParams
  =====================  ==========================================

Import from here instead of branching at each call site.  Everything is
resolved once at import time; no jax device state is touched.
"""

from __future__ import annotations

import enum
from typing import Optional

import jax

# --------------------------------------------------------------------- AxisType

try:  # jax >= 0.5: explicit/auto/manual mesh axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x: all axes behave as Auto
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    HAS_AXIS_TYPE = False


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates jaxes without ``axis_types``."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(tuple(axis_names))
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=tuple(axis_types), **kwargs)
        except TypeError:
            pass  # make_mesh predates the kwarg even though AxisType exists
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across the 0.4.x→0.6 signature change
    (legacy wants one ``((name, size), ...)`` tuple, modern wants two)."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(axis_shapes, axis_names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_shapes)))


# -------------------------------------------------------------------- shard_map

HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")
if not HAS_JAX_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(fn, mesh, in_specs, out_specs, *, axis_names=None,
              check_vma: bool = False):
    """Modern-signature shard_map on any supported jax.

    ``axis_names`` is the set of mesh axes handled *manually* (collectives
    visible inside ``fn``); every other mesh axis stays auto (GSPMD).  On
    0.4.x this translates to the legacy ``auto=`` complement-set parameter.
    """
    if axis_names is None:
        axis_names = frozenset(mesh.axis_names)
    axis_names = frozenset(axis_names)
    if HAS_JAX_SHARD_MAP:
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             axis_names=axis_names)
    auto = frozenset(mesh.axis_names) - axis_names
    return _legacy_shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)


# ----------------------------------------------------------------------- Pallas

def pallas_tpu_compiler_params(**kwargs) -> Optional[object]:
    """``pltpu.CompilerParams`` / legacy ``TPUCompilerParams``, or None when
    the installed Pallas exposes neither (caller should drop the argument)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:  # pragma: no cover - pallas entirely absent
        return None
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        return cls(**kwargs)
    except TypeError:  # pragma: no cover - kwarg drift between versions
        return None


def pallas_supported() -> bool:
    """True when the installed Pallas exposes the API the kernels use.

    Checked by ``kernels/*/ops.py`` to decide between the Pallas kernel and
    the pure-jnp reference implementation (tests skip-or-pass either way).
    """
    try:
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu
    except Exception:
        return False
    return all((
        hasattr(pl, "pallas_call"),
        hasattr(pl, "BlockSpec"),
        hasattr(pl, "when"),
        hasattr(pltpu, "VMEM"),
        pallas_tpu_compiler_params() is not None
        or hasattr(pltpu, "CompilerParams")
        or hasattr(pltpu, "TPUCompilerParams"),
    ))


def on_tpu() -> bool:
    """True when jax's default backend is a real TPU — the kernels run
    natively; anywhere else they run in interpret mode (or not at all)."""
    return jax.default_backend() == "tpu"


def import_pallas_kernels(module: str, *names: str):
    """The one definition of the kernel-dispatch import guard every
    ``kernels/*/ops.py`` shares: import ``names`` from the sibling
    ``kernel`` module, gated on ``pallas_supported()``.

    Returns ``(*fns, ok)``: the kernel entry points (or ``None`` each) and
    the dispatch flag ``_PALLAS_OK``.  A kernel-module import can fail
    independently of the coarse API probe (old/backendless jax installs),
    so both are folded into one flag — ops fall back to the jnp reference
    whenever it is False.
    """
    if pallas_supported():
        try:
            import importlib
            mod = importlib.import_module(module)
            return tuple(getattr(mod, n) for n in names) + (True,)
        except Exception:  # pragma: no cover - broken installs only
            pass
    return (None,) * len(names) + (False,)
