"""Fault-tolerant checkpointing: atomic, async, keep-K, exact resume.

Production behaviors implemented (and tested in tests/test_checkpoint.py):

  * **atomic**: write to ``step_N.tmp-<nonce>`` then ``os.replace`` — a crash
    mid-save never corrupts the latest checkpoint;
  * **async**: device→host transfer happens on the caller thread (cheap),
    serialization + fsync on a background thread so the train loop keeps
    stepping (BSP supersteps are not blocked on the filesystem);
  * **keep-K** sliding retention + a permanent ``keep_every`` ladder;
  * **exact resume**: params, optimizer moments, data-pipeline step and RNG
    are restored so the continued loss curve is bit-identical (tested);
  * **integrity**: content checksum verified on load; partial/corrupt files
    are skipped and the previous step is used (crash-during-save recovery).

Format: one msgpack file per checkpoint holding flattened arrays + a pytree
structure descriptor (no pickle — robust across refactors and safe to load).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)\.ckpt$")


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _serialize(leaves: List[np.ndarray], meta: dict) -> bytes:
    payload = {
        "meta": meta,
        "arrays": [
            {"dtype": str(a.dtype), "shape": list(a.shape),
             "data": a.tobytes()} for a in leaves
        ],
    }
    blob = msgpack.packb(payload, use_bin_type=True)
    digest = hashlib.sha256(blob).hexdigest().encode()
    return digest + b"\n" + blob


def _deserialize(raw: bytes) -> Tuple[List[np.ndarray], dict]:
    digest, _, blob = raw.partition(b"\n")
    if hashlib.sha256(blob).hexdigest().encode() != digest:
        raise IOError("checkpoint checksum mismatch")
    payload = msgpack.unpackb(blob, raw=False)
    leaves = [
        np.frombuffer(a["data"], dtype=a["dtype"]).reshape(a["shape"]).copy()
        for a in payload["arrays"]
    ]
    return leaves, payload["meta"]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    keep_every: int = 0          # additionally keep every Nth step forever

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        self._errors: List[str] = []

    # ------------------------------------------------------------------ save

    def save(self, step: int, state, meta: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot ``state`` (device→host now, disk write async)."""
        leaves, treedef = _flatten(state)
        meta = dict(meta or {}, step=int(step), treedef=str(treedef),
                    time=time.time())
        raw = None

        def write():
            nonlocal raw
            try:
                raw = _serialize(leaves, meta)
                tmp = self.dir / f"step_{step}.tmp-{os.getpid()}"
                with open(tmp, "wb") as f:
                    f.write(raw)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, self.dir / f"step_{step}.ckpt")
                self._gc()
            except Exception as e:   # pragma: no cover
                self._errors.append(f"save {step}: {e}")

        self.wait()
        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._errors:
            errs, self._errors = self._errors, []
            raise IOError("; ".join(errs))

    # ------------------------------------------------------------------ load

    def steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like, step: Optional[int] = None):
        """Restore into the structure/dtypes of ``like``; skips corrupt files
        (falls back to the previous step). Returns (state, meta) or None."""
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for s in reversed(candidates):
            try:
                raw = (self.dir / f"step_{s}.ckpt").read_bytes()
                leaves, meta = _deserialize(raw)
            except Exception:
                continue
            _, treedef = jax.tree_util.tree_flatten(like)
            ref_leaves = treedef.flatten_up_to(like)
            if len(ref_leaves) != len(leaves):
                continue
            cast = [np.asarray(l).astype(r.dtype) if hasattr(r, "dtype") else l
                    for l, r in zip(leaves, ref_leaves)]
            return jax.tree_util.tree_unflatten(treedef, cast), meta
        return None

    # ------------------------------------------------------------------ gc

    def _gc(self) -> None:
        with self._lock:
            steps = self.steps()
            protected = {s for s in steps
                         if self.keep_every and s % self.keep_every == 0}
            victims = [s for s in steps if s not in protected][:-self.keep] \
                if self.keep else []
            for s in victims:
                try:
                    (self.dir / f"step_{s}.ckpt").unlink()
                except OSError:
                    pass
