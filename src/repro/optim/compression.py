"""Gradient compression codecs for synchronization payloads (beyond-paper).

The paper's barriers are pure control; our BSP gradient sync moves real bytes.
On multi-pod meshes the inter-pod links are the collective bottleneck
(EXPERIMENTS.md §Roofline), so we let the fractal schedule compress every
point-to-point exchange:

  * ``Bf16Codec`` — 2× wire reduction; sums accumulate in f32 after decode.
  * ``Int8Codec`` — 4×; per-128-block symmetric scales (TPU lane-aligned).
  * ``error_feedback_step`` — classic EF-SGD residual correction so repeated
    quantization does not bias the update (Seide et al. 2014 / Karimireddy
    et al. 2019 style).

Codecs quantize the *wire* payload only; accumulation stays f32, so the
fractal all-reduce remains associative enough for BSP (validated against the
uncompressed schedule in tests with tolerance scaled to the codec).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


class Codec:
    name: str = "identity"
    wire_bytes_per_element: float = 4.0

    def encode(self, x: jax.Array):
        return {"x": x}

    def decode(self, wire, shape, dtype) -> jax.Array:
        return wire["x"]


@dataclass(frozen=True)
class Bf16Codec(Codec):
    name: str = "bf16"
    wire_bytes_per_element: float = 2.0

    def encode(self, x):
        return {"x": x.astype(jnp.bfloat16)}

    def decode(self, wire, shape, dtype):
        return wire["x"].astype(dtype)


@dataclass(frozen=True)
class Int8Codec(Codec):
    """Symmetric per-block int8: wire = int8 payload + one f32 scale / block."""
    block: int = 128
    name: str = "int8"

    @property
    def wire_bytes_per_element(self) -> float:
        return 1.0 + 4.0 / self.block

    def encode(self, x):
        n = x.shape[0]
        if n % self.block:
            raise ValueError(f"payload {n} not divisible by block {self.block}")
        rest = x.shape[1:]
        xb = x.reshape((n // self.block, self.block) + rest)
        scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
        safe = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(xb / safe), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}

    def decode(self, wire, shape, dtype):
        x = wire["q"].astype(dtype) * wire["scale"].astype(dtype)
        return x.reshape(shape)


def quantization_error(x: jax.Array, codec: Codec) -> jax.Array:
    """x − dequant(quant(x)): the residual EF carries to the next step."""
    return x - codec.decode(codec.encode(x), x.shape, x.dtype)


def error_feedback_step(flat_grads: jax.Array, residual: jax.Array,
                        codec: Codec) -> Tuple[jax.Array, jax.Array]:
    """EF-SGD: send quantize(g + residual); keep the quantization error.

    Returns (corrected payload to feed the collective, new residual)."""
    corrected = flat_grads + residual
    new_residual = quantization_error(corrected, codec)
    return corrected, new_residual


CODECS = {"none": None, "bf16": Bf16Codec(), "int8": Int8Codec()}
