"""AdamW optimizer (built in-repo: no optax in this container).

Production knobs used by the trainer and the dry-run memory budget:

  * ``state_dtype``  — f32 (default) or bf16 moments: at 671B parameters the
    moment dtype decides whether a pod fits (EXPERIMENTS.md §Dry-run).
  * global-norm clipping, decoupled weight decay, linear-warmup cosine decay.
  * The update is a pure function of (grads, state) — it runs inside the BSP
    superstep after ``sync_gradients`` so every rank applies identical math.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any      # first-moment pytree
    nu: Any      # second-moment pytree


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig
                  ) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step; returns (params', state', metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(state.step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, n):
        m32, n32 = m.astype(jnp.float32), n.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        n_new = b2 * n32 + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        nhat = n_new / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                n_new.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_n = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_n = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_n), metrics


def optimizer_bytes_per_param(cfg: AdamWConfig, param_dtype=jnp.bfloat16) -> int:
    """Dry-run memory budget helper: param + grad + 2 moments."""
    pb = jnp.dtype(param_dtype).itemsize
    sb = jnp.dtype(cfg.state_dtype).itemsize
    return pb + pb + 2 * sb
