"""Architecture registry: ``--arch <id>`` → ArchConfig, plus param counting.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` with the
exact published configuration; ``get_config(name)`` resolves either the full
config or its ``-smoke`` reduction.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

ARCH_IDS: List[str] = [
    "deepseek-v3-671b",
    "qwen3-moe-235b-a22b",
    "qwen2.5-3b",
    "granite-34b",
    "phi4-mini-3.8b",
    "gemma2-2b",
    "paligemma-3b",
    "musicgen-medium",
    "xlstm-1.3b",
    "jamba-v0.1-52b",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    smoke = name.endswith("-smoke")
    base = name[:-len("-smoke")] if smoke else name
    if base not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    cfg = importlib.import_module(_module_name(base)).CONFIG
    return cfg.reduced() if smoke else cfg


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS and memory budgets)
# ---------------------------------------------------------------------------


def param_shapes(cfg: ArchConfig):
    from repro.models.transformer import init_params
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.key(0))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Total (or per-token-active) parameter count.

    Active MoE params: routed expert weights count at top_k/num_experts;
    everything else (router, shared experts, attention, norms) is always on.
    """
    shapes = param_shapes(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    for path, leaf in leaves:
        n = int(np.prod(leaf.shape))
        if active_only and cfg.moe is not None and _is_routed_expert(
                path, leaf, cfg):
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def _is_routed_expert(path, leaf, cfg: ArchConfig) -> bool:
    keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    if "ffn" not in keys or "shared" in keys or "router" in keys:
        return False
    # routed expert tensors carry the expert dim: [..., E, D, F]-shaped
    return any(s == cfg.moe.num_experts for s in leaf.shape)


def embedding_params(cfg: ArchConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n


def non_embedding_params(cfg: ArchConfig, active_only=False) -> int:
    return count_params(cfg, active_only) - embedding_params(cfg)
