"""Activation sharding constraints (GSPMD guidance).

Without explicit constraints, GSPMD may propagate the FSDP *parameter*
sharding (d_model over data) into activations and silently drop batch
parallelism — observed as unsharded-batch [256,4096,·] buffers in the gemma2
dry-run HLO.  These hooks pin the canonical layout:

    hidden  [B, T, D]      → P(dp, None, None)
    logits  [B, T, V]      → P(dp, None, "model")
    moe_buf [B/G, E, C, D] → P(dp, "model", None, None)

The policy is process-global and set by the step builders (runtime/trainer);
when unset (unit tests, Tier-B manual-DP shard_map bodies) every hook is a
no-op.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: Optional[Tuple[Mesh, Tuple[str, ...]]] = None

# hillclimb lever: additionally shard the sequence dim of hidden states over
# "model" (sequence parallelism for norms/pointwise; GSPMD re-gathers where
# attention needs full T)
SEQ_SHARD = False


def set_policy(mesh: Mesh, dp_axes: Tuple[str, ...]) -> None:
    global _POLICY
    _POLICY = (mesh, tuple(dp_axes))


def clear_policy() -> None:
    global _POLICY
    _POLICY = None


def _constrain(x, *spec):
    if _POLICY is None:
        return x
    mesh, _ = _POLICY
    # drop axes missing from the mesh or not dividing the dim
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh.shape)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(axes if (axes and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def _dp():
    return _POLICY[1] if _POLICY else ("data",)


def hidden(x):
    """[B, T, D] (or [B, T, ...]): batch over the DP axes (+ optional
    sequence-parallel T over "model")."""
    t_axis = "model" if SEQ_SHARD else None
    return _constrain(x, _dp(), t_axis, *([None] * (x.ndim - 2)))


def logits(x):
    """[B, T, V]: batch over DP, vocab over model."""
    return _constrain(x, _dp(), None, "model")


SERVE_EP = False   # set by serving step builders: experts over ALL axes


def moe_buf(x):
    """[G, E, C, D]: groups over DP, experts over model (EP); in serving,
    experts span every axis when the expert count covers it (tokens
    all-to-all, weights pinned), else E-over-model with intra-expert TP."""
    if SERVE_EP and _POLICY is not None:
        mesh, dp = _POLICY
        ep = ("model",) + tuple(dp)
        size = 1
        for a in ep:
            size *= mesh.shape.get(a, 1)
        if x.shape[1] % size == 0:
            return _constrain(x, None, ep, None, None)
    return _constrain(x, _dp(), "model", None, None)


def scores_sshard(x):
    """[B, H, T, S] decode scores: keep S over "model" (flash-decode
    layout; heads replicate — tiny for T==1)."""
    return _constrain(x, _dp(), None, None, "model")


def kv(x):
    """[B, S, H, D] expanded keys/values: batch over DP, sequence over
    "model" (matches the decode-cache layout; flash-decode-style S-parallel
    attention).  Used by the MLA path whose K/V are recomputed from the
    latent cache."""
    return _constrain(x, _dp(), "model", *([None] * (x.ndim - 2)))
