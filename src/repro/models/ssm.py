"""State-space / recurrent blocks: Mamba (Jamba) and xLSTM (mLSTM + sLSTM).

All three expose (init, forward, step):

  * ``forward``  — full-sequence processing via lax.scan over time (exact
    recurrence; compiles to a compact While loop, which keeps the 512-device
    dry-run HLO small). Returns the final recurrent state as the decode cache.
  * ``step``     — single-token decode: O(1) state update, no KV cache —
    this is what makes the SSM/hybrid archs eligible for long_500k.

Shapes follow the papers: Mamba [arXiv:2312.00752] selective SSM with
d_inner = expand·d_model, depthwise causal conv (d_conv), Δ/B/C data-dependent;
xLSTM [arXiv:2405.04517] exponential gating with max-stabilizer state m,
matrix memory (mLSTM) and scalar memory with recurrent gates (sLSTM).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, SSMConfig
from .layers import _dtype, _init_dense, dense, init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _causal_conv(x, w, state=None, update_mask=None):
    """Depthwise causal 1D conv. x: [B,T,C], w: [K,C].

    state: [B,K-1,C] previous inputs (decode); returns (y, new_state).

    update_mask: optional [B,T] bool PREFIX mask — row b consumed only its
    first ``valid_b = mask.sum()`` tokens; the returned state is the last
    K-1 stream inputs as of token ``valid_b - 1`` (rows with valid_b == 0
    keep their incoming state).  Outputs at masked positions are garbage
    and must not be read."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)        # [B, T+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    if K == 1:
        new_state = pad
    elif update_mask is None:
        new_state = xp[:, -(K - 1):, :]
    else:
        # token t of row b sits at xp[b, K-1+t]; after valid_b tokens the
        # last K-1 stream inputs occupy xp[b, valid_b : valid_b+K-1]
        valid = jnp.sum(update_mask.astype(jnp.int32), axis=1)     # [B]
        idx = valid[:, None] + jnp.arange(K - 1, dtype=jnp.int32)[None, :]
        new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return y, new_state


def _gate_carry(mask_t, new, old):
    """Per-row scan-carry gate: keep ``new`` where mask_t [B] is True.
    Rows gated off retain their incoming recurrent state bit-for-bit —
    the primitive behind masked chunked prefill and batched decode with
    inactive slots."""
    return jax.tree.map(
        lambda a, b: jnp.where(
            mask_t.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), new, old)


def _softplus(x):
    return jax.nn.softplus(x)


TIME_CHUNK = 256


def chunked_scan(body, carry, xs, chunk: int = None):
    """lax.scan with chunked-BPTT memory: outer scan over time chunks, each
    chunk jax.checkpoint'ed — backward saves T/chunk boundary states and
    recomputes inside the chunk, instead of saving every per-step carry
    (naive BPTT stored 4096 × state for train_4k: ~TiB-scale on xLSTM)."""
    chunk = chunk or TIME_CHUNK
    T = jax.tree.leaves(xs)[0].shape[0]
    if T <= chunk or T % chunk:
        return lax.scan(body, carry, xs)
    n = T // chunk

    def chunk_body(c, xs_chunk):
        return lax.scan(body, c, xs_chunk)

    xs_chunks = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)
    carry, ys = lax.scan(jax.checkpoint(chunk_body), carry, xs_chunks)
    return carry, jax.tree.map(
        lambda a: a.reshape((T,) + a.shape[2:]), ys)


# ===========================================================================
# Mamba (selective SSM) — Jamba's recurrent layer
# ===========================================================================


def init_mamba(key, cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    dt = _dtype(cfg)
    D = cfg.d_model
    d_in = s.expand * D
    dt_rank = s.dt_rank or -(-D // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_in, 1))
    p = {
        "in_proj": _init_dense(ks[0], D, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": _init_dense(ks[2], d_in, dt_rank + 2 * s.d_state, dt),
        "dt_proj": _init_dense(ks[3], dt_rank, d_in, dt, bias=True),
        "A_log": jnp.log(A),                      # f32: dynamics stay f32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init_dense(ks[4], d_in, D, dt),
    }
    return p


def _mamba_scan_step(A, x_t, dt_t, B_t, C_t, h):
    """One selective-SSM step. h: [B,d_in,N]; returns (h', y_t [B,d_in])."""
    dA = jnp.exp(dt_t[..., None] * A[None])               # [B,d_in,N]
    dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t)
    return h, y


def mamba_forward(p, cfg: ArchConfig, u, state=None, update_mask=None):
    """u: [B,T,D] → (y [B,T,D], cache{conv,h}).

    update_mask: optional [B,T] bool prefix mask — state advances only over
    masked-True steps per row (masked-off outputs are garbage, never read).
    """
    s: SSMConfig = cfg.ssm
    B_, T, D = u.shape
    d_in = s.expand * D
    dt_rank = s.dt_rank or -(-D // 16)
    xz = dense(p["in_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    x, new_conv = _causal_conv(x, p["conv_w"], conv_state, update_mask)
    x = jax.nn.silu(x + p["conv_b"])

    proj = dense(p["x_proj"], x)
    dt_in = proj[..., :dt_rank]
    Bc = proj[..., dt_rank:dt_rank + s.d_state].astype(jnp.float32)
    Cc = proj[..., dt_rank + s.d_state:].astype(jnp.float32)
    dt_full = _softplus(dense(p["dt_proj"], dt_in).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    x32 = x.astype(jnp.float32)

    h0 = (jnp.zeros((B_, d_in, s.d_state), jnp.float32) if state is None
          else state["h"])

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt_full, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    if update_mask is None:
        def body(h, t_slice):
            x_t, dt_t, B_t, C_t = t_slice
            h, y = _mamba_scan_step(A, x_t, dt_t, B_t, C_t, h)
            return h, y
    else:
        xs = xs + (jnp.moveaxis(update_mask, 1, 0),)

        def body(h, t_slice):
            x_t, dt_t, B_t, C_t, m_t = t_slice
            h_new, y = _mamba_scan_step(A, x_t, dt_t, B_t, C_t, h)
            return _gate_carry(m_t, h_new, h), y
    h_final, ys = chunked_scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x32 * p["D"][None, None, :]
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, {"conv": new_conv, "h": h_final}


def mamba_step(p, cfg: ArchConfig, u_t, state):
    """u_t: [B,1,D] single token; state from forward/step."""
    out, new_state = mamba_forward(p, cfg, u_t, state)
    return out, new_state


# ===========================================================================
# mLSTM block (xLSTM) — parallelizable matrix-memory cell
# ===========================================================================


def init_mlstm(key, cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    dt = _dtype(cfg)
    D = cfg.d_model
    d_in = s.expand * D                    # up-projection factor 2 (paper)
    NH = s.num_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": init_rmsnorm(D, dt),
        "up_proj": _init_dense(ks[0], D, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        # headwise (block-diagonal) q/k/v, as in the official NX-AI blocks
        "wq": (jax.random.normal(ks[2], (NH, d_in // NH, d_in // NH),
                                 jnp.float32) / math.sqrt(d_in // NH)).astype(dt),
        "wk": (jax.random.normal(ks[3], (NH, d_in // NH, d_in // NH),
                                 jnp.float32) / math.sqrt(d_in // NH)).astype(dt),
        "wv": (jax.random.normal(ks[4], (NH, d_in // NH, d_in // NH),
                                 jnp.float32) / math.sqrt(d_in // NH)).astype(dt),
        "w_if": _init_dense(ks[5], d_in, 2 * NH, dt, bias=True),
        "out_norm": init_rmsnorm(d_in, dt),
        "down_proj": _init_dense(ks[6], d_in, D, dt),
        "skip": jnp.ones((d_in,), dt),
    }


def _mlstm_cell_step(q_t, k_t, v_t, i_t, f_t, state):
    """Stabilized mLSTM recurrence (paper eq. 19-27).

    q,k,v: [B,NH,dh]; i,f: [B,NH] pre-activations.
    state: C [B,NH,dh,dh], n [B,NH,dh], m [B,NH]."""
    C, n, m = state
    log_f = -_softplus(-f_t)                      # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_t)
    i_act = jnp.exp(i_t - m_new)
    f_act = jnp.exp(log_f + m - m_new)
    C = f_act[..., None, None] * C + i_act[..., None, None] \
        * (k_t[..., :, None] * v_t[..., None, :])
    n = f_act[..., None] * n + i_act[..., None] * k_t
    h_num = jnp.einsum("bhij,bhi->bhj", C, q_t)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q_t)), 1.0)
    h = h_num / h_den[..., None]
    return (C, n, m_new), h


def mlstm_forward(p, cfg: ArchConfig, u, state=None, update_mask=None):
    s: SSMConfig = cfg.ssm
    B_, T, D = u.shape
    d_in = s.expand * D
    NH = s.num_heads
    dh = d_in // NH
    x = rms_norm(p["norm"], u, cfg.norm_eps)
    xm, z = jnp.split(dense(p["up_proj"], x), 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xm, p["conv_w"], conv_state, update_mask)
    xc = jax.nn.silu(xc + p["conv_b"])
    xch = xc.reshape(B_, T, NH, dh)
    xmh = xm.reshape(B_, T, NH, dh)
    hw = lambda w, z: jnp.einsum("bthd,hdk->bthk", z, w)
    q = hw(p["wq"], xch) / math.sqrt(dh)
    k = hw(p["wk"], xch) / math.sqrt(dh)
    v = hw(p["wv"], xmh)
    gif = dense(p["w_if"], xm).astype(jnp.float32)     # [B,T,2NH]
    i_pre, f_pre = gif[..., :NH], gif[..., NH:]

    if state is None:
        C0 = jnp.zeros((B_, NH, dh, dh), jnp.float32)
        n0 = jnp.zeros((B_, NH, dh), jnp.float32)
        m0 = jnp.zeros((B_, NH), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_pre, f_pre))
    if update_mask is None:
        def body(carry, t_slice):
            q_t, k_t, v_t, i_t, f_t = t_slice
            carry, h = _mlstm_cell_step(q_t.astype(jnp.float32),
                                        k_t.astype(jnp.float32),
                                        v_t.astype(jnp.float32),
                                        i_t, f_t, carry)
            return carry, h
    else:
        xs = xs + (jnp.moveaxis(update_mask, 1, 0),)

        def body(carry, t_slice):
            q_t, k_t, v_t, i_t, f_t, m_t = t_slice
            new, h = _mlstm_cell_step(q_t.astype(jnp.float32),
                                      k_t.astype(jnp.float32),
                                      v_t.astype(jnp.float32),
                                      i_t, f_t, carry)
            return _gate_carry(m_t, new, carry), h
    (C, n, m), hs = chunked_scan(body, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, T, d_in).astype(u.dtype)
    h = rms_norm(p["out_norm"], h, cfg.norm_eps) + p["skip"] * xc
    h = h * jax.nn.silu(z)
    out = u + dense(p["down_proj"], h)
    return out, {"conv": new_conv, "C": C, "n": n, "m": m}


def mlstm_step(p, cfg, u_t, state):
    return mlstm_forward(p, cfg, u_t, state)


# ===========================================================================
# sLSTM block (xLSTM) — scalar memory, recurrent gates
# ===========================================================================


def init_slstm(key, cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    dt = _dtype(cfg)
    D = cfg.d_model
    NH = s.num_heads
    dh = D // NH
    ks = jax.random.split(key, 5)
    ffn = max(1, int(D * 4 / 3))
    return {
        "norm": init_rmsnorm(D, dt),
        "conv_w": (jax.random.normal(ks[0], (s.d_conv, D), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((D,), dt),
        "w_gates": _init_dense(ks[1], D, 4 * D, dt, bias=True),
        # per-head recurrent gate matrices (block-diagonal R, paper eq. 30)
        "r_gates": (jax.random.normal(ks[2], (NH, dh, 4 * dh), jnp.float32)
                    / math.sqrt(dh)).astype(dt),
        "group_norm": init_rmsnorm(D, dt),
        "ffn_up": _init_dense(ks[3], D, 2 * ffn, dt),
        "ffn_down": _init_dense(ks[4], ffn, D, dt),
    }


def _slstm_cell_step(p, cfg, wx_t, carry):
    """wx_t: [B,4D] input contribution; carry: (c,n,h,m) each [B,D]."""
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    NH = s.num_heads
    dh = D // NH
    c, n, h, m = carry
    B_ = wx_t.shape[0]
    hh = h.reshape(B_, NH, dh)
    rec = jnp.einsum("bhd,hdk->bhk", hh,
                     p["r_gates"].astype(jnp.float32)).reshape(B_, 4 * D)
    pre = wx_t + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = -_softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_act = jnp.exp(i_pre - m_new)
    f_act = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_act * c + i_act * z
    n = f_act * n + i_act
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def slstm_forward(p, cfg: ArchConfig, u, state=None, update_mask=None):
    B_, T, D = u.shape
    x = rms_norm(p["norm"], u, cfg.norm_eps)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(x, p["conv_w"], conv_state, update_mask)
    xc = jax.nn.silu(xc + p["conv_b"])
    wx = dense(p["w_gates"], xc).astype(jnp.float32)     # [B,T,4D]

    if state is None:
        zeros = jnp.zeros((B_, D), jnp.float32)
        carry = (zeros, zeros, zeros, zeros)
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    if update_mask is None:
        def body(carry, wx_t):
            return _slstm_cell_step(p, cfg, wx_t, carry)
        xs = jnp.moveaxis(wx, 1, 0)
    else:
        def body(carry, t_slice):
            wx_t, m_t = t_slice
            new, h = _slstm_cell_step(p, cfg, wx_t, carry)
            return _gate_carry(m_t, new, carry), h
        xs = (jnp.moveaxis(wx, 1, 0), jnp.moveaxis(update_mask, 1, 0))
    carry, hs = chunked_scan(body, carry, xs)
    c, n, h, m = carry
    y = jnp.moveaxis(hs, 0, 1).astype(u.dtype)
    y = rms_norm(p["group_norm"], y, cfg.norm_eps)
    u = u + y
    # gated FFN (projection factor 4/3, paper App. figure)
    gate, up = jnp.split(dense(p["ffn_up"], u), 2, axis=-1)
    u = u + dense(p["ffn_down"], jax.nn.gelu(gate, approximate=True) * up)
    return u, {"conv": new_conv, "c": c, "n": n, "h": h, "m": m}


def slstm_step(p, cfg, u_t, state):
    return slstm_forward(p, cfg, u_t, state)
