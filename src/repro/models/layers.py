"""Model building blocks shared by all ten assigned architectures.

Pure-functional JAX: parameters are nested dicts of arrays; every layer is
(init_fn, apply_fn).  Conventions:

  * activations bf16 (configurable), softmax/normalizers f32;
  * attention is GQA-grouped (no KV head replication in memory);
  * sequences ≥ ``CHUNKED_ATTN_THRESHOLD`` use a lax.scan online-softmax
    (flash-style) path so 32k/500k shapes never materialize T×S scores —
    this is also the pure-jnp oracle for the Pallas flash kernel;
  * MoE uses sort-based capacity dispatch (GShard capacity semantics without
    the O(T·E·C·d) one-hot einsum) and shards experts over the "model" axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_mla_attention)
from . import act_sharding as ACT

CHUNKED_ATTN_THRESHOLD = 8_192   # inference: online-softmax over KV chunks
ATTN_CHUNK = 1_024
QUERY_CHUNK_THRESHOLD = 2_048    # training: checkpointed query blocks
QUERY_CHUNK = 512


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def _init_dense(key, d_in, d_out, dtype, scale=None, bias=False):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms / positional encodings
# ---------------------------------------------------------------------------


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: [..., T] (broadcastable)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                          # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    half = d_model // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention core (GQA, masks, online-softmax chunking)
# ---------------------------------------------------------------------------


def _mask_bias(pos_q, pos_k, *, causal, window, prefix_len):
    """Additive f32 bias [..., Tq, Tk] built from position comparisons."""
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(pq.shape, pk.shape), bool)
    if causal:
        allowed = pk <= pq
        if prefix_len is not None:
            allowed = allowed | (pk < prefix_len)
        ok &= allowed
    if window is not None:
        ok &= (pq - pk) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def gqa_attention(q, k, v, *, pos_q, pos_k, causal=True, window=None,
                  prefix_len=None, attn_cap=None, scale=None,
                  chunk=None, chunk_q=None) -> jnp.ndarray:
    """q: [B,Tq,Hq,Dk]  k: [B,Tk,Hkv,Dk]  v: [B,Tk,Hkv,Dv] → [B,Tq,Hq,Dv].

    ``chunk``  : online-softmax over Tk blocks — memory-lean FORWARD
                 (inference prefill; scan-backward would save carries).
    ``chunk_q``: checkpointed query blocks — memory-lean fwd+bwd for
                 TRAINING: per-block scores recomputed in backward, scan
                 outputs (not carries) are the only per-block residuals.
    """
    B, Tq, Hq, Dk = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    Dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, Tq, Hkv, G, Dk) * scale
    # normalize positions to [B, T] so mask bias is [B, Tq, Tk]
    if pos_q.ndim == 1:
        pos_q = jnp.broadcast_to(pos_q[None, :], (B, Tq))
    if pos_k.ndim == 1:
        pos_k = jnp.broadcast_to(pos_k[None, :], (B, k.shape[1]))

    if chunk_q is not None and Tq > chunk_q:
        # Blocked attention with STATIC per-block KV extents (Python-unrolled
        # query blocks): block j only reads keys [lo_j, hi_j) where hi_j
        # follows the causal diagonal and lo_j the sliding window — the HLO
        # contains only the needed flops (≈½ for causal, ≈W/T for windowed)
        # instead of masked-but-computed full T×S scores.  Blocks are
        # jax.checkpoint'ed when grads flow (training=True callers), so the
        # backward recomputes one block's scores at a time.
        # Assumes pos_q/pos_k are arange-aligned (train/prefill from 0).
        C = chunk_q
        nq = -(-Tq // C)
        pad = nq * C - Tq
        if pad:
            qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            pos_q = jnp.pad(pos_q, ((0, 0), (0, pad)),
                            constant_values=-1)      # masked (pk<=pq fails)
        Tk = k.shape[1]

        def block(lo, hi, q_blk, pq_blk, k_full, v_full, pk_full):
            # slice INSIDE the checkpointed fn: residuals are the original
            # k/v buffers (saved once), not per-block slice copies
            k_j, v_j = k_full[:, lo:hi], v_full[:, lo:hi]
            pk_j = pk_full[:, lo:hi]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_j
                           ).astype(jnp.float32)
            s = softcap(s, attn_cap)
            s = s + _mask_bias(pq_blk, pk_j, causal=causal, window=window,
                               prefix_len=prefix_len)[:, None, None]
            s = jnp.where(s == -jnp.inf, -1e30, s)   # padded rows stay finite
            p = jax.nn.softmax(s, axis=-1).astype(v_j.dtype)
            return jnp.einsum("bhgqk,bkhd->bqhgd", p, v_j)

        blk = jax.checkpoint(block, static_argnums=(0, 1))
        pre_hi = (-(-prefix_len // C) * C) if prefix_len else 0
        outs = []
        for j in range(nq):
            hi = Tk if not causal else min(Tk, max((j + 1) * C, pre_hi))
            lo = 0 if window is None else max(0, (j * C - window) // C * C)
            outs.append(blk(lo, hi, qg[:, j * C:(j + 1) * C],
                            pos_q[:, j * C:(j + 1) * C], k, v, pos_k))
        o = jnp.concatenate(outs, axis=1).reshape(B, nq * C, Hq, Dv)
        return o[:, :Tq]

    if chunk is None or k.shape[1] <= chunk:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
        s = softcap(s, attn_cap)
        s = s + _mask_bias(pos_q, pos_k, causal=causal, window=window,
                           prefix_len=prefix_len)[:, None, None]
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return o.reshape(B, Tq, Hq, Dv)

    # ---- online-softmax over key chunks (flash-style, pure jnp oracle) ----
    Tk = k.shape[1]
    n_chunks = -(-Tk // chunk)
    pad = n_chunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, [(0, 0)] * (pos_k.ndim - 1) + [(0, pad)],
                        constant_values=jnp.iinfo(jnp.int32).max // 2)
    kc = k.reshape(B, n_chunks, chunk, Hkv, Dk)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv)
    pkc = pos_k.reshape(*pos_k.shape[:-1], n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry
        k_j, v_j, pk_j = xs                     # [B,chunk,Hkv,D], pk [B,chunk]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_j).astype(jnp.float32)
        s = softcap(s, attn_cap)
        bias = _mask_bias(pos_q, pk_j, causal=causal, window=window,
                          prefix_len=prefix_len)          # [B,Tq,chunk]
        s = s + bias[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # fully-masked-so-far rows keep m_new == -inf; use a finite proxy so
        # exp() never sees (-inf) − (-inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        corr = jnp.exp(m - m_safe)              # m == -inf → 0
        p = jnp.exp(s - m_safe[..., None])      # s == -inf → 0
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v_j).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Tq, Dv), jnp.float32)
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(pkc, -2, 0))
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), xs)
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, -2, 1).reshape(B, Tq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV cache primitives (pool [num_blocks, block_size, ...] + block table)
# ---------------------------------------------------------------------------
#
# The serve engine's paged backend replaces the per-slot contiguous cache row
# [B, S, ...] with one pooled tensor [num_blocks, block_size, ...] per leaf;
# each slot maps virtual positions onto physical blocks through a fixed-width
# block table [B, n_max] (jit-stable: unallocated entries padded with the
# SENTINEL block 0, whose contents are garbage by construction and causally
# masked everywhere).  With max_len % block_size == 0 the gathered virtual
# view has the SAME shape and values as the contiguous row, so attention
# over it agrees with the contiguous path — the token-identity invariant
# the serve benchmarks assert end to end.

PAGED_SENTINEL = 0


def paged_gather(pool, tables):
    """pool [N, bs, ...] + tables [B, n] -> virtual view [B, n*bs, ...].

    Virtual position p of row b lives at pool[tables[b, p // bs], p % bs].
    Sentinel-padded table entries gather garbage at virtual positions past
    the row's allocated length — positions the causal mask always hides.
    """
    N, bs = pool.shape[:2]
    B, n = tables.shape
    g = jnp.take(pool, tables.reshape(-1), axis=0)        # [B*n, bs, ...]
    return g.reshape((B, n * bs) + pool.shape[2:])


def paged_scatter(pool, new, tables, offset):
    """Write ``new`` [B,T,...] at virtual positions [offset, offset+T)
    through ``tables`` [B, n] into ``pool`` [N, bs, ...].

    ``offset`` is a scalar (chunked prefill; shared start) or a per-row
    [B] vector (slots at independent lengths) — ragged multi-token writes
    start each row's span at its own offset.  Positions beyond the
    table's span — end-padding of a short final prefill chunk, or the
    tail of a ragged row — are redirected to the SENTINEL block instead
    of clamping onto a live block.  Masked decode rows carry an
    all-sentinel table row, so their writes land in the sentinel block
    too.
    """
    N, bs = pool.shape[:2]
    B, T = new.shape[:2]
    n = tables.shape[1]
    off = jnp.asarray(offset)
    if off.ndim == 0:
        pos = off.astype(jnp.int32) + jnp.arange(T, dtype=jnp.int32)
        pos = jnp.broadcast_to(pos[None, :], (B, T))
    else:
        pos = off.astype(jnp.int32)[:, None] \
            + jnp.arange(T, dtype=jnp.int32)[None, :]
    bi = pos // bs
    blk = jnp.take_along_axis(tables, jnp.clip(bi, 0, n - 1), axis=1)
    blk = jnp.where(bi < n, blk, PAGED_SENTINEL)
    flat = new.reshape((B * T,) + new.shape[2:]).astype(pool.dtype)
    return pool.at[blk.reshape(-1), (pos % bs).reshape(-1)].set(flat)


def _cache_update(buf, new, offset):
    """Write ``new`` [B,T,...] into cache ``buf`` [B,S,...] at ``offset``.

    ``offset`` is a scalar (shared write position) or, for T == 1 decode, a
    per-row [B] vector — the serve engine's slots sit at independent
    sequence lengths inside one batched decode step.

    * T == S (prefill filling the whole cache): replace outright;
    * T == 1 (decode): one-hot select over S — shard-local under an
      S-over-"model" layout, unlike dynamic-update-slice whose GSPMD
      lowering materializes [S_local × S] masks;
    * general T: dynamic_update_slice (chunked prefill; scalar offset only).
    """
    S = buf.shape[1]
    T = new.shape[1]
    if T == S:
        return new.astype(buf.dtype)
    off = jnp.asarray(offset)
    if T == 1:
        if off.ndim == 1:      # per-slot write positions
            hit = jnp.arange(S, dtype=jnp.int32)[None, :] == off[:, None]
            hit = hit.reshape((off.shape[0], S) + (1,) * (buf.ndim - 2))
        else:
            hit = (jnp.arange(S, dtype=jnp.int32) == off)
            hit = hit.reshape((1, S) + (1,) * (buf.ndim - 2))
        return jnp.where(hit, new.astype(buf.dtype), buf)
    if off.ndim != 0:
        raise ValueError("multi-token cache writes need a scalar offset")
    return lax.dynamic_update_slice_in_dim(buf, new.astype(buf.dtype),
                                           offset, axis=1)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(ks[0], D, H * Dh, dt, bias=cfg.qkv_bias),
        "wk": _init_dense(ks[1], D, Hkv * Dh, dt, bias=cfg.qkv_bias),
        "wv": _init_dense(ks[2], D, Hkv * Dh, dt, bias=cfg.qkv_bias),
        "wo": _init_dense(ks[3], H * Dh, D, dt,
                          scale=1.0 / math.sqrt(H * Dh)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(Dh, dt)
        p["k_norm"] = init_rmsnorm(Dh, dt)
    return p


def apply_attention(p, cfg: ArchConfig, x, *, positions, kv_cache=None,
                    cache_offset=None, window=None, prefix_len=None,
                    block_tables=None, paged_kernel="ref"):
    """x: [B,T,D]. Returns (out [B,T,D], new_kv or None).

    kv_cache: dict(k=[B,S,Hkv,Dh], v=...) pre-allocated ring for decode;
    cache_offset: scalar current length (tokens already in cache).
    block_tables: paged mode — kv_cache leaves are pools [N, bs, Hkv, Dh]
    and [B, n] tables map virtual positions onto physical blocks.
    paged_kernel: "pallas" routes paged T==1 decode through the fused
    block-table kernel (no gathered [B, n*bs, ...] view); "ref" keeps the
    gather-then-attend oracle lowering."""
    B, T, D = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, T, H, Dh)
    k = dense(p["wk"], x).reshape(B, T, Hkv, Dh)
    v = dense(p["wv"], x).reshape(B, T, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        pos_k = positions
        chunk_q = QUERY_CHUNK if T >= QUERY_CHUNK_THRESHOLD else None
        o = gqa_attention(q, k, v, pos_q=positions, pos_k=pos_k,
                          causal=True, window=window, prefix_len=prefix_len,
                          attn_cap=cfg.attn_softcap, chunk_q=chunk_q)
        new_kv = {"k": k, "v": v}
    else:
        if block_tables is not None:
            k_pool = paged_scatter(kv_cache["k"], k, block_tables,
                                   cache_offset)
            v_pool = paged_scatter(kv_cache["v"], v, block_tables,
                                   cache_offset)
            new_kv = {"k": k_pool, "v": v_pool}
            if T == 1 and paged_kernel == "pallas" and prefix_len is None:
                # fused decode: the kernel walks block_tables directly and
                # streams pool blocks; the [B, n*bs, ...] gather never exists
                o = paged_attention(q, k_pool, v_pool, block_tables,
                                    cache_offset, window=window,
                                    softcap=cfg.attn_softcap)
                out = dense(p["wo"], o.reshape(B, T, H * Dh))
                return out, new_kv
            k_all = paged_gather(k_pool, block_tables)
            v_all = paged_gather(v_pool, block_tables)
        else:
            k_all = _cache_update(kv_cache["k"], k, cache_offset)
            v_all = _cache_update(kv_cache["v"], v, cache_offset)
            new_kv = {"k": k_all, "v": v_all}
        S = k_all.shape[1]
        pos_k = jnp.arange(S, dtype=jnp.int32)[None, :]
        pos_q = positions if positions.ndim > 1 else positions[None, :]
        # prefill (T>1): blocked attention with static causal extents;
        # single-query decode never blocks: scores are [B,H,1,S] (tiny) and
        # blocking would fight the model-axis sharding of S.
        chunk_q = QUERY_CHUNK * 2 if (T >= QUERY_CHUNK_THRESHOLD) else None
        o = gqa_attention(q, k_all, v_all, pos_q=pos_q, pos_k=pos_k,
                          causal=True, window=window, prefix_len=prefix_len,
                          attn_cap=cfg.attn_softcap, chunk_q=chunk_q)
    out = dense(p["wo"], o.reshape(B, T, H * Dh))
    return out, new_kv


# ---------------------------------------------------------------------------
# MLA: multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig):
    m: MLAConfig = cfg.mla
    dt = _dtype(cfg)
    D, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "q_down": _init_dense(ks[0], D, m.q_lora_rank, dt),
        "q_norm": init_rmsnorm(m.q_lora_rank, dt),
        "q_up": _init_dense(ks[1], m.q_lora_rank, H * qk_dim, dt),
        "kv_down": _init_dense(ks[2], D, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "kv_up": _init_dense(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim), dt),
        "wo": _init_dense(ks[4], H * m.v_head_dim, D, dt),
    }


def apply_mla(p, cfg: ArchConfig, x, *, positions, kv_cache=None,
              cache_offset=None, block_tables=None, paged_kernel="ref"):
    """Latent-cache MLA. Cache stores (c_kv, k_rope): [B,S,kv_lora(+rope)];
    paged mode pools them as [N, bs, ...] addressed via block_tables.
    paged_kernel="pallas" fuses paged T==1 absorbed decode (no gather)."""
    m: MLAConfig = cfg.mla
    B, T, D = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = dense(p["q_up"], rms_norm(p["q_norm"], dense(p["q_down"], x),
                                  cfg.norm_eps)).reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = dense(p["kv_down"], x)
    c_kv = rms_norm(p["kv_norm"], kv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)                       # [B,T,1,dr]

    if kv_cache is not None:
        if block_tables is not None:
            ckv_pool = paged_scatter(kv_cache["c_kv"], c_kv, block_tables,
                                     cache_offset)
            kr_pool = paged_scatter(kv_cache["k_rope"], k_rope, block_tables,
                                    cache_offset)
            new_cache = {"c_kv": ckv_pool, "k_rope": kr_pool}
            if T == 1 and paged_kernel == "pallas":
                # fused absorbed decode in latent space, straight off the
                # pools (the weight absorption of _mla_absorbed_decode with
                # the gather + [B,S] latent view fused away; scores_sshard
                # is a sharding hint only, skipped inside the kernel path)
                w_up = p["kv_up"]["w"].reshape(m.kv_lora_rank, H, dn + dv)
                w_k, w_v = w_up[..., :dn], w_up[..., dn:]
                q_eff = jnp.einsum("bthd,rhd->bthr", q_nope, w_k)
                o_lat = paged_mla_attention(
                    q_eff, q_rope, ckv_pool, kr_pool, block_tables,
                    cache_offset, scale=1.0 / math.sqrt(dn + dr))
                o = jnp.einsum("bthr,rhd->bthd", o_lat, w_v)
                out = dense(p["wo"], o.reshape(B, T, H * dv))
                return out, new_cache
            c_kv = paged_gather(ckv_pool, block_tables)
            k_rope = paged_gather(kr_pool, block_tables)
        else:
            c_kv = _cache_update(kv_cache["c_kv"], c_kv, cache_offset)
            k_rope = _cache_update(kv_cache["k_rope"], k_rope, cache_offset)
            new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        S = c_kv.shape[1]
        pos_k = jnp.arange(S, dtype=jnp.int32)[None, :]
        pos_q = positions if positions.ndim > 1 else positions[None, :]
    else:
        S = T
        pos_k = positions
        pos_q = positions
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    # decode uses the ABSORBED-WEIGHT form (DeepSeek inference trick): score
    # and output projections fold W_uk/W_uv into q/o so K/V are NEVER
    # materialized from the latent — attention runs in the 512-d latent
    # space directly against the S-sharded cache.
    if kv_cache is not None and T == 1:
        o = _mla_absorbed_decode(p, cfg, q_nope, q_rope, c_kv, k_rope,
                                 cache_offset)
        out = dense(p["wo"], o.reshape(B, T, H * dv))
        return out, new_cache

    up = dense(p["kv_up"], c_kv).reshape(B, S, H, dn + dv)
    k_nope, v = up[..., :dn], up[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    if kv_cache is None:        # training
        chunk_q = QUERY_CHUNK if T >= QUERY_CHUNK_THRESHOLD else None
    elif T > 1:                 # prefill
        chunk_q = QUERY_CHUNK * 2 if T >= QUERY_CHUNK_THRESHOLD else None
    else:                       # decode
        chunk_q = None
    o = gqa_attention(qf, k, v, pos_q=pos_q, pos_k=pos_k, causal=True,
                      attn_cap=None, scale=1.0 / math.sqrt(dn + dr),
                      chunk_q=chunk_q)
    out = dense(p["wo"], o.reshape(B, T, H * dv))
    return out, new_cache


def _mla_absorbed_decode(p, cfg, q_nope, q_rope, c_kv, k_rope, offset):
    """One-token MLA attention in latent space (weight absorption).

      scores = (q_nope·W_uk)·c_kv + q_rope·k_rope     [B,H,1,S]
      out    = (softmax·c_kv)·W_uv                    [B,1,H,dv]

    c_kv stays S-sharded over "model" end to end; the per-layer wire cost is
    the (small) absorbed weights + softmax partials instead of all-gathering
    a [B,S,H,192] materialized K (the 204 GiB/dev baseline pathology).
    """
    m = cfg.mla
    B, T, H, dn = q_nope.shape
    S = c_kv.shape[1]
    dv = m.v_head_dim
    w_up = p["kv_up"]["w"].reshape(m.kv_lora_rank, H, dn + dv)
    w_k, w_v = w_up[..., :dn], w_up[..., dn:]

    q_eff = jnp.einsum("bthd,rhd->bthr", q_nope, w_k)       # [B,1,H,r]
    s = jnp.einsum("bthr,bsr->bhts", q_eff, c_kv).astype(jnp.float32)
    s = s + jnp.einsum("bthd,bsd->bhts", q_rope,
                       k_rope[:, :, 0]).astype(jnp.float32)
    s = s / math.sqrt(dn + m.qk_rope_head_dim)
    s = ACT.scores_sshard(s)
    off = jnp.asarray(offset).reshape((-1, 1, 1, 1))   # scalar or per-slot [B]
    valid = jnp.arange(S, dtype=jnp.int32)[None, None, None, :] <= off
    s = jnp.where(valid, s, -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhts,bsr->bthr", prob.astype(c_kv.dtype), c_kv)
    return jnp.einsum("bthr,rhd->bthd", o_lat, w_v)         # [B,1,H,dv]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d_ff=None):
    """mlp styles: swiglu/geglu (gated, 3 matrices) or gelu (plain, 2)."""
    dt = _dtype(cfg)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": _init_dense(ks[1], D, F, dt),
        "w_down": _init_dense(ks[2], F, D, dt, scale=1.0 / math.sqrt(F)),
    }
    if cfg.mlp != "gelu":
        p["w_gate"] = _init_dense(ks[0], D, F, dt)
    return p


def apply_mlp(p, cfg: ArchConfig, x):
    if cfg.mlp == "gelu":
        return dense(p["w_down"],
                     jax.nn.gelu(dense(p["w_up"], x), approximate=True))
    act = jax.nn.silu if cfg.mlp == "swiglu" else \
        (lambda z: jax.nn.gelu(z, approximate=True))
    return dense(p["w_down"], act(dense(p["w_gate"], x)) * dense(p["w_up"], x))


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch, EP over "model")
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig):
    mo: MoEConfig = cfg.moe
    dt = _dtype(cfg)
    D, E, F = cfg.d_model, mo.num_experts, mo.d_expert
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(D)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "router": _init_dense(ks[0], D, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   * scale_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 * scale_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   * scale_out).astype(dt),
    }
    if mo.num_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=F * mo.num_shared)
    return p


def _router_gates(p, mo: MoEConfig, x2d):
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    if mo.router == "sigmoid":                      # DeepSeek-V3 aux-free
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(scores, mo.top_k)        # [T,k]
    if mo.norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, scores


def moe_load_balance_loss(scores, idx, num_experts):
    """Switch-style load-balance aux loss (mean prob × token fraction)."""
    T = scores.shape[0]
    frac_prob = scores.mean(0)
    counts = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_tok = counts / jnp.maximum(counts.sum(), 1.0)
    return num_experts * jnp.sum(frac_prob * frac_tok)


def apply_moe(p, cfg: ArchConfig, x):
    """x: [B,T,D] → (y, aux_loss). Group-wise sort-based capacity dispatch.

    Tokens are grouped by sequence (group = batch row), GShard-style, so the
    dispatch buffer is [B, E, C, D] with LOCAL capacity C = ceil(T·k/E·cf):
    the batch dim stays sharded over the data axes and experts shard over
    "model" (EP) — no tensor ever materializes global-capacity buffers.
    Per group:

      1. top-k routing → (token, expert, gate) triples
      2. stable sort by expert; position-in-expert via segment arithmetic
      3. scatter into [E, C, D]; batched expert GEMMs
      4. gather back with gate weighting; overflow tokens drop (GShard).
    """
    mo: MoEConfig = cfg.moe
    B, T, D = x.shape
    E, K = mo.num_experts, mo.top_k
    C = max(1, int(math.ceil(T * K / E * mo.capacity_factor)))

    gates, idx, scores = _router_gates(p, mo, x.reshape(B * T, D))
    gates = gates.reshape(B, T, K)
    idx = idx.reshape(B, T, K)

    def dispatch_group(xg, gate_g, idx_g):
        """xg: [T,D]; returns (buf [E,C,D], e_sorted, slot, t_sorted, w).

        The [E,C,D] buffer is built by GATHER (rows indexed by a tiny
        [E,C+1] int32 slot→token map built with a cheap scatter), never by
        scattering activations into the expert-sharded dim — GSPMD can keep
        an E-sharded gather fully local, whereas a data-dependent scatter
        into a sharded dim forces replication (observed: 3.1 TiB/device on
        deepseek train before this change)."""
        flat_e = idx_g.reshape(-1)                       # [T*K]
        flat_g = gate_g.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        order = jnp.argsort(flat_e, stable=True)
        e_s, t_s, g_s = flat_e[order], flat_t[order], flat_g[order]
        starts = jnp.searchsorted(e_s, jnp.arange(E, dtype=e_s.dtype))
        pos = jnp.arange(T * K, dtype=jnp.int32) - starts[e_s]
        keep = pos < C
        slot = jnp.where(keep, pos, C)                   # C = overflow bin
        slot_tok = jnp.full((E, C + 1), T, jnp.int32)    # T = "empty" row
        slot_tok = slot_tok.at[e_s, slot].set(
            jnp.where(keep, t_s, T))[:, :C]              # [E,C] tiny
        w = (g_s * keep.astype(jnp.float32))
        return slot_tok, e_s, slot, t_s, w

    slot_tok, e_s, slot, t_s, w = jax.vmap(dispatch_group)(x, gates, idx)
    # gather rows per expert slot: [B,E,C,D]; padded row T reads zeros
    x_pad = jnp.concatenate(
        [x, jnp.zeros((B, 1, D), x.dtype)], axis=1)      # [B,T+1,D]
    buf = jnp.take_along_axis(
        x_pad[:, :, None, :],
        slot_tok.reshape(B, E * C, 1, 1).astype(jnp.int32), axis=1
    ).reshape(B, E, C, D)
    # buf: [B,E,C,D] — B over data axes, E over "model" (EP)
    buf = ACT.moe_buf(buf)

    act = jax.nn.silu if cfg.mlp == "swiglu" else \
        (lambda z: jax.nn.gelu(z, approximate=True))
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    y_exp = ACT.moe_buf(
        jnp.einsum("becf,efd->becd", h, p["w_down"]))     # [B,E,C,D]

    def combine_group(y_g, e_s, slot, t_s, w):
        contrib = y_g[e_s, jnp.minimum(slot, C - 1)] \
            * w.astype(y_g.dtype)[:, None]
        return jnp.zeros((T, D), y_g.dtype).at[t_s].add(contrib)

    y = jax.vmap(combine_group)(y_exp, e_s, slot, t_s, w)

    if mo.num_shared:
        y = y + apply_mlp(p["shared"], cfg, x.reshape(B * T, D)
                          ).reshape(B, T, D)
    aux = moe_load_balance_loss(scores, idx.reshape(B * T, K), E)
    return y, aux
