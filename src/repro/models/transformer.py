"""LM assembler: builds any assigned architecture from its ArchConfig.

Layers are grouped into homogeneous *segments* (``ArchConfig.segments``) and
scanned with stacked parameters — HLO size stays O(distinct block kinds), not
O(num_layers), which keeps the 512-device dry-run compile tractable even for
the 94-layer / 88-layer configs.

API (all pure functions):

  init_params(cfg, key)                         -> params pytree
  forward(params, cfg, tokens, ...)             -> logits [B,T,V]
  loss_fn(params, cfg, batch)                   -> (loss, metrics)
  init_cache(cfg, batch, max_len)               -> decode cache pytree
  prefill(params, cfg, tokens, cache)           -> (last_logits, cache, offset)
  decode_step(params, cfg, token, cache, offset)-> (logits, cache)

The loss is sequence-chunked (logits for 512 tokens at a time under
jax.checkpoint) so a 129k-vocab train step never materializes [B,T,V].
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from . import layers as L
from . import ssm as S
from . import act_sharding as ACT

LOSS_CHUNK = 512

# Rematerialization policy for the layer scan: "block" checkpoints each
# scanned unit (classic layer-remat: activations recomputed in backward),
# "dots" saves matmul outputs only, "none" stores everything.  Set by the
# trainer / dry-run driver; a policy knob, not an architecture property.
_REMAT = "block"


def set_remat(mode: str) -> None:
    global _REMAT
    if mode not in ("none", "block", "dots"):
        raise ValueError(mode)
    _REMAT = mode


def _maybe_remat(fn):
    if _REMAT == "none":
        return fn
    if _REMAT == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)

ATTN_KINDS = ("attn", "attn_moe", "local", "global")
MLA_KINDS = ("mla", "mla_moe")
MAMBA_KINDS = ("mamba", "mamba_moe")
XLSTM_KINDS = ("mlstm", "slstm")
MOE_KINDS = ("attn_moe", "mla_moe", "mamba_moe")
# the SlotState split: positional (KV) caches are addressed by slot row /
# block table, recurrent caches by a pooled state row (``rec_rows``)
REC_KINDS = MAMBA_KINDS + XLSTM_KINDS


def has_recurrent(cfg: ArchConfig) -> bool:
    """True if any layer carries O(1) recurrent state (mamba / xLSTM)."""
    return any(k in REC_KINDS for unit, _ in cfg.segments() for k in unit)


def has_attention(cfg: ArchConfig) -> bool:
    """True if any layer carries a positional KV cache (attention / MLA)."""
    return any(k in ATTN_KINDS or k in MLA_KINDS
               for unit, _ in cfg.segments() for k in unit)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, kind: str):
    dt = L._dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if kind in ATTN_KINDS:
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["attn"] = L.init_attention(ks[0], cfg)
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
    elif kind in MLA_KINDS:
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["attn"] = L.init_mla(ks[0], cfg)
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
    elif kind in MAMBA_KINDS:
        p["norm1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["mamba"] = S.init_mamba(ks[0], cfg)
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dt)
    elif kind == "mlstm":
        return {"cell": S.init_mlstm(ks[0], cfg)}
    elif kind == "slstm":
        return {"cell": S.init_slstm(ks[0], cfg)}
    else:
        raise ValueError(f"unknown layer kind {kind!r}")

    if cfg.norm_style == "sandwich":
        p["post1"] = L.init_rmsnorm(cfg.d_model, dt)
        p["post2"] = L.init_rmsnorm(cfg.d_model, dt)

    if kind in MOE_KINDS:
        p["ffn"] = L.init_moe(ks[1], cfg)
    else:
        p["ffn"] = L.init_mlp(ks[1], cfg)
    return p


def _gather_rec(cache, rec_rows):
    """View of the pooled recurrent state at rows ``rec_rows`` [B]."""
    return jax.tree.map(lambda x: x[rec_rows], cache)


def _scatter_rec(cache, new_state, rec_rows):
    """Write per-row state back into the pool.  Rows gated off by the
    update mask carry their own gathered value, so duplicate sentinel
    indices (row 0 for every masked batch row) all write identical bits —
    the scatter stays deterministic."""
    return jax.tree.map(
        lambda full, ns: full.at[rec_rows].set(ns.astype(full.dtype)),
        cache, new_state)


def apply_block(p, cfg: ArchConfig, kind: str, h, *, positions,
                cache=None, offset=None, prefix_len=None, block_tables=None,
                paged_kernel="ref", rec_rows=None, update_mask=None):
    """Returns (h, new_cache, aux_loss).

    ``rec_rows`` [B] addresses pooled recurrent state (serve engine): the
    block gathers each batch row's state from the pool, advances it, and
    scatters it back.  ``update_mask`` [B,T] prefix-gates the advance per
    row (chunk padding / inactive decode slots); attention layers ignore
    it — their masked writes land on causally-hidden positions instead."""
    aux = jnp.zeros((), jnp.float32)
    if kind in XLSTM_KINDS:
        fwd = S.mlstm_forward if kind == "mlstm" else S.slstm_forward
        state = cache
        if cache is not None and rec_rows is not None:
            state = _gather_rec(cache, rec_rows)
        h, new_state = fwd(p["cell"], cfg, h, state, update_mask=update_mask)
        if cache is not None and rec_rows is not None:
            new_state = _scatter_rec(cache, new_state, rec_rows)
        return h, new_state, aux

    sandwich = cfg.norm_style == "sandwich"

    # --- mixer (attention / MLA / mamba) ---
    x = L.rms_norm(p["norm1"], h, cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind == "local" else None
        mix, new_cache = L.apply_attention(
            p["attn"], cfg, x, positions=positions, kv_cache=cache,
            cache_offset=offset, window=window, prefix_len=prefix_len,
            block_tables=block_tables, paged_kernel=paged_kernel)
    elif kind in MLA_KINDS:
        mix, new_cache = L.apply_mla(p["attn"], cfg, x, positions=positions,
                                     kv_cache=cache, cache_offset=offset,
                                     block_tables=block_tables,
                                     paged_kernel=paged_kernel)
    else:  # mamba
        state = cache
        if cache is not None and rec_rows is not None:
            state = _gather_rec(cache, rec_rows)
        mix, new_cache = S.mamba_forward(p["mamba"], cfg, x, state,
                                         update_mask=update_mask)
        if cache is not None and rec_rows is not None:
            new_cache = _scatter_rec(cache, new_cache, rec_rows)
    if sandwich:
        mix = L.rms_norm(p["post1"], mix, cfg.norm_eps)
    h = h + mix

    # --- FFN / MoE ---
    x = L.rms_norm(p["norm2"], h, cfg.norm_eps)
    if kind in MOE_KINDS:
        y, aux = L.apply_moe(p["ffn"], cfg, x)
    else:
        y = L.apply_mlp(p["ffn"], cfg, x)
    if sandwich:
        y = L.rms_norm(p["post2"], y, cfg.norm_eps)
    h = h + y
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    dt = L._dtype(cfg)
    if kind in ATTN_KINDS:
        hkv, dh = cfg.num_kv_heads, cfg.head_dim
        z = lambda *s: jnp.zeros(s, dt)
        return {"k": z(batch, max_len, hkv, dh), "v": z(batch, max_len, hkv, dh)}
    if kind in MLA_KINDS:
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), dt)}
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    if kind in MAMBA_KINDS:
        return {"conv": jnp.zeros((batch, s.d_conv - 1, d_in), dt),
                "h": jnp.zeros((batch, d_in, s.d_state), jnp.float32)}
    if kind == "mlstm":
        nh, dh = s.num_heads, d_in // s.num_heads
        return {"conv": jnp.zeros((batch, s.d_conv - 1, d_in), dt),
                "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, nh, dh), jnp.float32),
                "m": jnp.zeros((batch, nh), jnp.float32)}
    if kind == "slstm":
        D = cfg.d_model
        z = lambda: jnp.zeros((batch, D), jnp.float32)
        return {"conv": jnp.zeros((batch, s.d_conv - 1, D), dt),
                "c": z(), "n": z(), "h": z(), "m": z()}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Per-segment stacked caches: leading dim = segment repeat count."""
    caches = []
    for unit, reps in cfg.segments():
        unit_cache = {f"l{j}": _block_cache(cfg, kind, batch, max_len)
                      for j, kind in enumerate(unit)}
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape).copy(),
            unit_cache))
    return caches


def init_paged_cache(cfg: ArchConfig, num_blocks: int, block_size: int):
    """Pooled paged cache: every leaf is [reps, num_blocks, block_size, ...].

    Structurally this is ``init_cache`` with (batch=num_blocks,
    max_len=block_size) — axis 1 is the PHYSICAL BLOCK dim and axis 2 the
    position-in-block dim; block tables map each slot's virtual positions
    onto it.  Positional caches (attention / MLA) only: a recurrent state
    has no positions to page."""
    for unit, _reps in cfg.segments():
        for kind in unit:
            if kind not in ATTN_KINDS and kind not in MLA_KINDS:
                raise ValueError(
                    f"{cfg.name}: layer kind {kind!r} has a recurrent "
                    "cache; the paged backend supports attention/MLA only "
                    "— use init_hybrid_cache for mixed stacks")
    return init_cache(cfg, num_blocks, block_size)


def init_hybrid_cache(cfg: ArchConfig, *, kv_batch: int, kv_len: int,
                      rec_batch: int):
    """SlotState cache for mixed stacks: each layer's leaves sized by its
    backend.  Positional (attention / MLA) leaves get the KV geometry —
    ``(kv_batch, kv_len)`` is ``(max_slots, max_len)`` for the contiguous
    backend or ``(num_blocks, block_size)`` for the paged one.  Recurrent
    leaves get ``rec_batch`` pooled state rows (row 0 is the sentinel row
    masked decode slots address, so pass usable_rows + 1)."""
    caches = []
    for unit, reps in cfg.segments():
        unit_cache = {}
        for j, kind in enumerate(unit):
            if kind in REC_KINDS:
                unit_cache[f"l{j}"] = _block_cache(cfg, kind, rec_batch, 0)
            else:
                unit_cache[f"l{j}"] = _block_cache(cfg, kind, kv_batch,
                                                   kv_len)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape).copy(),
            unit_cache))
    return caches


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dt = L._dtype(cfg)
    keys = jax.random.split(key, 8)
    V, D = cfg.vocab_size, cfg.d_model
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (V, D), jnp.float32)
                  * 0.02).astype(dt),
        "final_norm": L.init_rmsnorm(D, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = L._init_dense(keys[1], D, V, dt,
                                       scale=1.0 / math.sqrt(D))
    if cfg.frontend:
        fd = cfg.frontend_dim or D
        params["frontend_proj"] = L._init_dense(keys[2], fd, D, dt)
    if cfg.pos_embed == "sinusoidal":
        pass  # non-learned

    segs = []
    seg_key = keys[3]
    for unit, reps in cfg.segments():
        seg_key, sub = jax.random.split(seg_key)
        unit_keys = jax.random.split(sub, reps)

        def init_unit(k, unit=unit):
            uks = jax.random.split(k, len(unit))
            return {f"l{j}": init_block(uks[j], cfg, kind)
                    for j, kind in enumerate(unit)}

        segs.append(jax.vmap(init_unit)(unit_keys))
    params["segments"] = segs

    if cfg.mtp_depth:
        mtp_keys = jax.random.split(keys[4], cfg.mtp_depth)
        params["mtp"] = [
            {"proj": L._init_dense(mtp_keys[i], 2 * D, D, dt),
             "block": init_block(jax.random.fold_in(mtp_keys[i], 7), cfg,
                                 "mla" if cfg.mla else "attn"),
             "norm": L.init_rmsnorm(D, dt)}
            for i in range(cfg.mtp_depth)]
    return params


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------


def _embed(params, cfg: ArchConfig, tokens, frontend_embeds=None,
           positions=None):
    h = params["embed"][tokens]
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    if cfg.frontend and frontend_embeds is not None:
        pre = L.dense(params["frontend_proj"], frontend_embeds.astype(h.dtype))
        h = jnp.concatenate([pre, h], axis=1)
    if cfg.pos_embed == "sinusoidal":
        if positions is None:
            positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        pe = L.sinusoidal_pos(positions, cfg.d_model).astype(h.dtype)
        h = h + (pe[None] if pe.ndim == 2 else pe)
    return ACT.hidden(h)


def _run_segments(params, cfg: ArchConfig, h, *, positions, caches=None,
                  offset=None, prefix_len=None, block_tables=None,
                  paged_kernel="ref", rec_rows=None, update_mask=None):
    """Scan each segment's stacked unit over its repeats."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (unit, reps) in enumerate(cfg.segments()):
        seg_params = params["segments"][si]
        seg_cache = None if caches is None else caches[si]

        def body(h, xs, unit=unit):
            p_unit, c_unit = xs
            aux_sum = jnp.zeros((), jnp.float32)
            new_c = {}
            for j, kind in enumerate(unit):
                c = None if c_unit is None else c_unit[f"l{j}"]
                h, nc, aux = apply_block(
                    p_unit[f"l{j}"], cfg, kind, h, positions=positions,
                    cache=c, offset=offset, prefix_len=prefix_len,
                    block_tables=block_tables, paged_kernel=paged_kernel,
                    rec_rows=rec_rows, update_mask=update_mask)
                new_c[f"l{j}"] = nc
                aux_sum = aux_sum + aux
            return ACT.hidden(h), (new_c, aux_sum)

        if seg_cache is None:
            # drop per-layer cache outputs to keep train HLO lean
            def body_nocache(h, p_unit, unit=unit):
                h, (_, aux_sum) = body(h, (p_unit, None), unit=unit)
                return h, aux_sum
            h, auxs = lax.scan(_maybe_remat(body_nocache), h, seg_params)
            new_caches.append(None)
        else:
            h, (ncache, auxs) = lax.scan(body, h, (seg_params, seg_cache))
            new_caches.append(ncache)
        aux_total = aux_total + jnp.sum(auxs)
    return h, new_caches, aux_total


def forward(params, cfg: ArchConfig, tokens, frontend_embeds=None,
            positions=None):
    """Full-sequence logits (small vocab / small T only — training uses
    ``loss_fn`` which chunks the head)."""
    h = _embed(params, cfg, tokens, frontend_embeds)
    T = h.shape[1]
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)
    prefix_len = cfg.frontend_tokens if cfg.prefix_lm else None
    h, _, aux = _run_segments(params, cfg, h, positions=positions,
                              prefix_len=prefix_len)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = _head(params, cfg, h)
    return logits


def _head(params, cfg: ArchConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = ACT.logits((h @ w).astype(jnp.float32))
    return L.softcap(logits, cfg.logit_softcap)


def _chunked_xent(params, cfg: ArchConfig, h, labels, mask):
    """Sequence-chunked cross-entropy: logits never exceed [B,chunk,V]."""
    B, T, D = h.shape
    chunk = min(LOSS_CHUNK, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(B, n_chunks, chunk, D)
    lc = labels.reshape(B, n_chunks, chunk)
    mc = mask.reshape(B, n_chunks, chunk)

    @jax.checkpoint
    def chunk_loss(h_j, l_j, m_j):
        logits = _head(params, cfg, h_j)               # [B,chunk,V] f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_j[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_j
        return jnp.sum(nll), jnp.sum(m_j)

    def body(carry, xs):
        tot, cnt = carry
        s, c = chunk_loss(*xs)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0),
         jnp.moveaxis(mc, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ArchConfig, batch) -> Tuple[jax.Array, Dict]:
    """batch: {tokens [B,T], labels [B,T], (frontend [B,Tf,Df])}.

    labels < 0 are masked. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    h = _embed(params, cfg, tokens, batch.get("frontend"))
    T = h.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    prefix_len = cfg.frontend_tokens if cfg.prefix_lm else None
    h, _, aux = _run_segments(params, cfg, h, positions=positions,
                              prefix_len=prefix_len)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)

    if cfg.frontend and batch.get("frontend") is not None:
        h_txt = h[:, cfg.frontend_tokens:]
    else:
        h_txt = h
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    loss = _chunked_xent(params, cfg, h_txt, labels_safe, mask)
    metrics = {"xent": loss, "aux": aux}

    if cfg.mtp_depth and "mtp" in params:
        # DeepSeek MTP: module i predicts token t+1+i from [h_t ; emb_{t+i}]
        mtp_loss = jnp.zeros((), jnp.float32)
        h_cur = h_txt
        for i, mod in enumerate(params["mtp"]):
            emb_next = params["embed"][tokens[:, 1 + i:]]
            h_in = jnp.concatenate(
                [h_cur[:, :emb_next.shape[1]],
                 emb_next.astype(h_cur.dtype)], axis=-1)
            h_i = L.dense(mod["proj"], h_in)
            kind = "mla" if cfg.mla else "attn"
            pos_i = jnp.arange(h_i.shape[1], dtype=jnp.int32)
            h_i, _, _ = apply_block(mod["block"], cfg, kind, h_i,
                                    positions=pos_i)
            h_i = L.rms_norm(mod["norm"], h_i, cfg.norm_eps)
            lbl_i = labels[:, 1 + i:]
            msk_i = (lbl_i >= 0).astype(jnp.float32)
            mtp_loss = mtp_loss + _chunked_xent(
                params, cfg, h_i, jnp.maximum(lbl_i, 0), msk_i)
            h_cur = h_i
        loss = loss + cfg.mtp_loss_weight * mtp_loss / cfg.mtp_depth
        metrics["mtp"] = mtp_loss

    if cfg.moe:
        loss = loss + 0.01 * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# inference: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, tokens, cache, frontend_embeds=None):
    """Fill the cache with the prompt; logits for the last position only."""
    h = _embed(params, cfg, tokens, frontend_embeds)
    T = h.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    prefix_len = cfg.frontend_tokens if cfg.prefix_lm else None
    offset = jnp.zeros((), jnp.int32)
    h, new_caches, _ = _run_segments(params, cfg, h, positions=positions,
                                     caches=cache, offset=offset,
                                     prefix_len=prefix_len)
    h_last = L.rms_norm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    return _head(params, cfg, h_last), new_caches, jnp.array(T, jnp.int32)


def decode_step(params, cfg: ArchConfig, token, cache, offset,
                block_tables=None, paged_kernel="ref", rec_rows=None,
                active=None):
    """token: [B,1] ints; offset: tokens-already-cached — a scalar shared by
    the batch, or a per-row [B] vector (serve slots at independent lengths
    inside one batched decode step).  ``block_tables`` [B, n] switches the
    cache to the paged layout (pooled leaves, see ``init_paged_cache``);
    ``paged_kernel="pallas"`` routes paged attention through the fused
    block-table decode kernel instead of gather-then-attend.

    Recurrent layers (SlotState "recurrent" backend): ``rec_rows`` [B]
    addresses each batch row's pooled state row, ``active`` [B] bool gates
    the state advance — inactive rows map to the sentinel row 0 and keep
    it unchanged, so masked decode rows never touch live state."""
    B = token.shape[0]
    off = jnp.asarray(offset)
    if off.ndim == 1:
        positions = off[:, None].astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(off[None, None], (B, 1)).astype(jnp.int32)
    update_mask = None
    if active is not None:
        update_mask = jnp.asarray(active).reshape(B, 1).astype(bool)
    h = _embed(params, cfg, token, positions=positions)
    h, new_caches, _ = _run_segments(params, cfg, h, positions=positions,
                                     caches=cache, offset=offset,
                                     block_tables=block_tables,
                                     paged_kernel=paged_kernel,
                                     rec_rows=rec_rows,
                                     update_mask=update_mask)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    return _head(params, cfg, h), new_caches


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, offset,
                  with_logits: bool = True, block_tables=None,
                  rec_rows=None, valid=None):
    """Write a prompt chunk at cache positions [offset, offset+T).

    The serve engine's chunked-admission primitive: a fixed-shape [B,T]
    chunk lands at a (traced) scalar ``offset``, so arbitrary prompt
    lengths stream through one compiled function.  Returns logits for the
    WHOLE chunk [B,T,V] (the engine picks the real last position — the tail
    chunk is right-padded) and the updated cache.  Interior chunks only
    feed the cache: pass ``with_logits=False`` (a Python-level switch —
    compile one variant per value) to skip the full-vocab head projection,
    the dominant FLOPs at production vocab sizes; logits come back None.

    Positional caches tolerate padding anywhere (garbage positions stay
    causally hidden until overwritten); recurrent caches would advance on
    it, so recurrent-bearing archs pass ``valid`` — the count of real
    tokens from the chunk start — and ``rec_rows`` [B] addressing the
    pooled state rows: state advances over exactly the first ``valid``
    positions and freezes on the padded tail.
    """
    B, T = tokens.shape
    if T >= L.QUERY_CHUNK_THRESHOLD:
        # the blocked-attention path (chunk_q) computes STATIC per-block key
        # extents assuming positions start at 0 — at a nonzero cache offset
        # it would silently mask out the causally-visible prefix
        raise ValueError(
            f"prefill chunk length {T} >= {L.QUERY_CHUNK_THRESHOLD}: "
            "offset prefill must stay below the blocked-attention "
            "threshold — use smaller chunks")
    off = jnp.asarray(offset, jnp.int32)
    positions = (off + jnp.arange(T, dtype=jnp.int32))[None, :]
    positions = jnp.broadcast_to(positions, (B, T))
    update_mask = None
    if valid is not None:
        v = jnp.asarray(valid, jnp.int32)
        update_mask = jnp.broadcast_to(
            (jnp.arange(T, dtype=jnp.int32) < v)[None, :], (B, T))
    h = _embed(params, cfg, tokens, positions=positions)
    h, new_caches, _ = _run_segments(params, cfg, h, positions=positions,
                                     caches=cache, offset=off,
                                     block_tables=block_tables,
                                     rec_rows=rec_rows,
                                     update_mask=update_mask)
    if not with_logits:
        return None, new_caches
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    return _head(params, cfg, h), new_caches


# ---------------------------------------------------------------------------
# per-slot cache surgery (serve engine)
# ---------------------------------------------------------------------------
#
# Cache leaves are stacked per segment as [reps, B, ...]: axis 1 is the
# batch/slot dim.  These three ops are the whole slot-reuse cache API —
# admission takes a slot view, prefills it, writes it back; completion
# resets the slot.  All accept a traced slot index (jit-stable).


def take_slot(cache, slot):
    """Extract one slot's cache as a batch-1 view (leaf [reps, 1, ...])."""
    return jax.tree.map(
        lambda x: lax.dynamic_slice_in_dim(x, slot, 1, axis=1), cache)


def write_slot(cache, sub, slot):
    """Write a batch-1 slot cache (from ``take_slot``) back at ``slot``."""
    return jax.tree.map(
        lambda x, s: lax.dynamic_update_slice_in_dim(
            x, s.astype(x.dtype), slot, axis=1), cache, sub)


def reset_slot(cache, slot):
    """Zero one slot's rows in every cache leaf, other slots untouched."""
    return jax.tree.map(lambda x: x.at[:, slot].set(jnp.zeros((), x.dtype)),
                        cache)


# Kind-aware variants (the SlotState protocol): in a hybrid cache, axis 1
# means "slot row" for contiguous-KV leaves, "physical block" for paged
# leaves, and "pooled state row" for recurrent leaves — so slot surgery
# must walk the config in parallel with the cache and touch only the
# leaves whose backend it addresses.


def _map_by_kind(cfg, cache, fn_for_kind):
    """Apply ``fn_for_kind(kind) -> leaf_fn | None`` over each layer's
    subtree (None = leave the layer's leaves untouched)."""
    out = []
    for si, (unit, _reps) in enumerate(cfg.segments()):
        seg = {}
        for j, kind in enumerate(unit):
            fn = fn_for_kind(kind)
            leaves = cache[si][f"l{j}"]
            seg[f"l{j}"] = leaves if fn is None else jax.tree.map(fn, leaves)
        out.append(seg)
    return out


def take_state(cfg, cache, slot):
    """Slice one contiguous-KV slot's rows as a batch-1 view; recurrent
    leaves pass through WHOLE (they are addressed by ``rec_rows`` inside
    the forward, not by the batch dim)."""
    return _map_by_kind(
        cfg, cache,
        lambda kind: None if kind in REC_KINDS else
        (lambda x: lax.dynamic_slice_in_dim(x, slot, 1, axis=1)))


def write_state(cfg, cache, sub, slot):
    """Write a ``take_state`` view back: contiguous-KV leaves land in the
    slot's row; recurrent leaves come back whole (the forward already
    scattered their rows in place)."""
    out = []
    for si, (unit, _reps) in enumerate(cfg.segments()):
        seg = {}
        for j, kind in enumerate(unit):
            full, s = cache[si][f"l{j}"], sub[si][f"l{j}"]
            if kind in REC_KINDS:
                seg[f"l{j}"] = s
            else:
                seg[f"l{j}"] = jax.tree.map(
                    lambda x, y: lax.dynamic_update_slice_in_dim(
                        x, y.astype(x.dtype), slot, axis=1), full, s)
        out.append(seg)
    return out


def reset_slot_state(cfg, cache, slot=None, rec_row=None):
    """Zero a contiguous-KV slot row (``slot``) and/or a pooled recurrent
    state row (``rec_row``); pass None to leave that backend untouched
    (paged-KV leaves are always untouched — block freshness is the
    allocator's job)."""
    def fn(kind):
        if kind in REC_KINDS:
            if rec_row is None:
                return None
            return lambda x: x.at[:, rec_row].set(jnp.zeros((), x.dtype))
        if slot is None:
            return None
        return lambda x: x.at[:, slot].set(jnp.zeros((), x.dtype))
    return _map_by_kind(cfg, cache, fn)


def copy_block(cache, src, dst):
    """Copy one physical block's payload in every paged-cache leaf
    (leaf [reps, num_blocks, block_size, ...], axis 1 = block).  The
    device half of copy-on-write: the allocator hands out a private block
    id and this clones the shared content into it before any write.
    Traced src/dst (jit-stable)."""
    def cp(x):
        blk = lax.dynamic_slice_in_dim(x, src, 1, axis=1)
        return lax.dynamic_update_slice_in_dim(x, blk, dst, axis=1)
    return jax.tree.map(cp, cache)
