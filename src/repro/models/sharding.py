"""Parameter / activation / cache sharding policy (GSPMD PartitionSpecs).

Mesh axes: ``("data","model")`` single-pod, ``("pod","data","model")``
multi-pod.  Policy (MaxText-style FSDP × TP):

  * FSDP axes = ("pod","data"): parameters + optimizer moments sharded on the
    d_model-ish dimension (ZeRO-3; XLA all-gathers per scanned layer).
  * TP axis = "model": attention heads / MoE experts / d_ff / vocab.
  * Guards: a dim only gets an axis if divisible by the axis product AND, for
    head-structured projections, if the head count itself divides the axis —
    otherwise that axis is dropped (e.g. gemma2's 8 heads on a 16-way model
    axis: attention stays fsdp-only; recorded as a roofline hillclimb lever).
  * Decode caches shard batch over FSDP axes and sequence over "model"
    (a 500k-token KV/state must live across the pod).

The policy is data (name-pattern rules), so hillclimb variants can override
single rules without touching model code (see launch/dryrun.py --opt).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return math.prod(mesh.shape[a] for a in axes)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def _path_keys(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(int(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


# name of the projection component (parent of the "w"/"b" leaf)
_COL_PARALLEL = {"wq", "w_gate", "w_up", "up_proj", "in_proj", "q_up",
                 "kv_up", "ffn_up", "w_if"}          # [D_in, D_out·TP]
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "down_proj", "ffn_down",
                 "dt_proj"}                          # [D_in·TP, D_out]
_KV_PROJ = {"wk", "wv"}
_REPLICATED = {"q_norm", "k_norm", "norm", "norm1", "norm2", "post1", "post2",
               "out_norm", "group_norm", "final_norm", "kv_norm",
               "frontend_proj", "proj", "skip", "r_gates"}


def _leaf_spec(cfg: ArchConfig, keys: list, shape: Tuple[int, ...],
               mesh: Mesh, mode: str = "train") -> P:
    """mode="train": FSDP×TP (ZeRO-3: per-layer weight gathers amortize over
    fwd+bwd).  mode="serve": weights must not move per token — dense weights
    TP-only (replicated over the data axes), MoE experts sharded over ALL
    axes (full EP: tokens travel, weights stay)."""
    fsdp = fsdp_axes(mesh) if mode == "train" else ()
    ep_axes = ("model",) + fsdp_axes(mesh) if mode == "serve" else ("model",)
    has_model = "model" in mesh.shape
    stacked = "segments" in keys          # lax.scan leading repeat dim
    nd = len(shape)
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*axes):
        axes = tuple(a if (a and _fits(body[i], mesh, a)) else None
                     for i, a in enumerate(axes))
        return P(*(lead + axes))

    # ---- top-level tensors -------------------------------------------------
    if keys[:1] == ["embed"]:
        return spec("model", fsdp)
    if "head" in keys:
        return spec(fsdp, "model")
    if keys and keys[0] == "mtp" and "block" not in keys:
        return P(*((None,) * nd))

    name = next((k for k in reversed(keys)
                 if isinstance(k, str) and k not in ("w", "b")), "")

    if name in _REPLICATED or not has_model and not fsdp:
        return P(*((None,) * nd))

    # ---- MoE expert tensors [E, D, F] / [E, F, D]: EP over "model"
    # (train) or over every axis (serve: 1-expert-per-chip at 256 chips) ----
    if name in ("w_gate", "w_up", "w_down") and len(body) == 3 \
            and cfg.moe and body[0] == cfg.moe.num_experts:
        if mode == "serve":
            if body[0] % axis_size(mesh, ep_axes) == 0:
                return spec(ep_axes, None, None)   # full EP: 1 expert/chip
            # E doesn't cover every axis (e.g. qwen3's 128e on 256 chips):
            # E over "model" + intra-expert TP over the data axes (weights
            # still pinned; activations move instead)
            ftp = fsdp_axes(mesh)
            if name == "w_down":
                return spec("model", ftp, None)
            return spec("model", None, ftp)
        if name == "w_down":
            return spec("model", None, fsdp)
        return spec("model", fsdp, None)

    leaf = keys[-1] if keys else ""
    if leaf == "b":                      # bias of a projection
        if name in _COL_PARALLEL and _head_ok(cfg, name, mesh):
            return spec("model")
        return P(*((None,) * nd))

    if len(body) == 1:                   # 1-D vectors (A, D, conv_b, scale)
        if name in ("A_log", "D", "conv_b") or leaf in ("D",):
            return spec("model") if len(body) == 1 else P(None)
        return P(*((None,) * nd))

    if name == "conv_w":                 # [K, C]
        return spec(None, "model")
    if name in ("A_log",):               # [d_in, N]
        return spec("model", None)
    if name == "x_proj":                 # [d_in, dt+2N]: row-parallel-ish
        return spec("model", None)
    if name in ("wq", "wk", "wv") and len(body) == 3:
        return P(*((None,) * nd))        # xLSTM headwise cells: replicate

    if name in _COL_PARALLEL:
        model = "model" if _head_ok(cfg, name, mesh) else None
        return spec(fsdp, model)
    if name in _KV_PROJ:
        model = "model" if cfg.num_kv_heads % axis_size(mesh, "model") == 0 \
            else None
        return spec(fsdp, model)
    if name in _ROW_PARALLEL:
        model = "model" if _head_ok(cfg, name, mesh) else None
        return spec(model, fsdp)
    if name in ("router", "q_down", "kv_down"):
        return spec(fsdp, None)
    # default: replicate
    return P(*((None,) * nd))


def _head_ok(cfg: ArchConfig, name: str, mesh: Mesh) -> bool:
    """Head-structured projections need heads % TP == 0 to stay head-aligned."""
    tp = axis_size(mesh, "model")
    if name in ("wq", "wo"):
        return cfg.num_heads % tp == 0
    return True


def param_specs(cfg: ArchConfig, params_shape, mesh: Mesh,
                mode: str = "train"):
    """PartitionSpec pytree mirroring the parameter pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        keys = _path_keys(path)
        specs.append(_leaf_spec(cfg, keys, tuple(leaf.shape), mesh, mode))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache / logits specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh) -> Dict[str, P]:
    dp = fsdp_axes(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None),
            "frontend": P(dp, None, None)}


def cache_specs(cfg: ArchConfig, cache_shape, mesh: Mesh):
    """Decode caches: batch over FSDP, sequence (or largest state dim) over
    "model" when divisible."""
    dp = fsdp_axes(mesh)

    def leaf(path, x):
        keys = _path_keys(path)
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        nd = len(x.shape)
        stacked = 1  # caches are stacked per segment repeat: [R, B, ...]
        base = [None] * nd
        if nd >= 2 and x.shape[1] % axis_size(mesh, dp) == 0:
            base[1] = dp            # batch dim (long_500k has batch 1)
        if name in ("k", "v", "c_kv", "k_rope") and nd >= 3 \
                and x.shape[2] % axis_size(mesh, "model") == 0:
            base[2] = "model"       # sequence dim of KV caches
        elif name in ("h",) and nd >= 3 \
                and x.shape[2] % axis_size(mesh, "model") == 0:
            base[2] = "model"       # mamba state d_in
        elif name == "conv" and nd >= 4 - 0 and \
                x.shape[-1] % axis_size(mesh, "model") == 0:
            base[-1] = "model"
        return P(*base)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(p, x) for p, x in flat])


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
