"""Pure-jnp oracles for the fused paged-attention decode kernels.

Each reference is the gather-then-attend computation the kernel fuses
away: ``paged_gather`` materializes the virtual [B, n*bs, ...] KV view,
then a dense masked-softmax attention runs over it.  Masking is by
virtual position only — valid keys of row b are positions
``< lengths[b]`` — which hides both future positions and the garbage
gathered through sentinel-padded table entries (those always lie at or
after the row's length).  This is the oracle the parity tests pin the
kernel against, and the ``paged_kernel="ref"`` dispatch target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gather(pool, tables):
    """pool [N, bs, ...] + tables [B, n] → virtual view [B, n*bs, ...]."""
    B, n = tables.shape
    bs = pool.shape[1]
    g = jnp.take(pool, tables.reshape(-1), axis=0)
    return g.reshape((B, n * bs) + pool.shape[2:])


def paged_attention_ref(q, k_pool, v_pool, tables, lengths, *, scale: float,
                        window=None, softcap=None):
    """q: [B, Hkv, G, d], pools: [N, bs, Hkv, d(v)], tables: [B, n],
    lengths: [B] → [B, Hkv, G, dv]."""
    k = _gather(k_pool, tables)                       # [B, S, Hkv, d]
    v = _gather(v_pool, tables)                       # [B, S, Hkv, dv]
    S = k.shape[1]
    s = jnp.einsum("bhgd,bshd->bhgs", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S, dtype=jnp.int32)[None, None, None, :]
    length = lengths.astype(jnp.int32)[:, None, None, None]
    mask = pos < length
    if window is not None:
        mask &= (length - 1 - pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v).astype(q.dtype)


def paged_mla_attention_ref(q_eff, q_rope, ckv_pool, kr_pool, tables,
                            lengths, *, scale: float):
    """q_eff: [B, H, r], q_rope: [B, H, dr], ckv_pool: [N, bs, r],
    kr_pool: [N, bs, dr], tables: [B, n], lengths: [B] → [B, H, r]."""
    c_kv = _gather(ckv_pool, tables)                  # [B, S, r]
    k_r = _gather(kr_pool, tables)                    # [B, S, dr]
    S = c_kv.shape[1]
    s = jnp.einsum("bhr,bsr->bhs", q_eff, c_kv).astype(jnp.float32)
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope, k_r).astype(jnp.float32)
    s = s * scale
    pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]
    valid = pos < lengths.astype(jnp.int32)[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bsr->bhr",
                      p.astype(c_kv.dtype), c_kv).astype(q_eff.dtype)
