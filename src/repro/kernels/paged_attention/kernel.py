"""Fused paged-attention decode Pallas kernels: block-table-driven K/V
streaming with online softmax.

The serve engine's decode hot loop previously paid a full HBM round trip
per step: ``paged_gather`` materialized the virtual contiguous KV view
[B, n*bs, ...] from the pool before every attention call.  These kernels
walk the block table directly instead — the table rides the grid as a
scalar-prefetch operand, so each KV grid step's BlockSpec index map reads
``tables[b, j]`` and streams the *physical* block [bs, ...] straight from
the pool into VMEM.  The gathered view is never materialized; the
scattered layout is free (the hardware-offload lesson of the paper's
barrier design applied to data movement).

Two variants, both single-query (T == 1 decode):

* ``paged_attention_pallas``     — GQA: grid (B, Hkv, n), per-(batch, kv
  head) program streams the row's blocks and reduces G grouped query
  heads at once.
* ``paged_mla_attention_pallas`` — MLA absorbed decode: grid (B, n);
  scores are latent-space (q_eff·c_kv + q_rope·k_rope) and the streamed
  c_kv block doubles as the value matrix.

Masking is by *virtual position only*: valid keys of row b are positions
``< lengths[b]`` (= cache offset + 1: the causal set of a query sitting
at the row's last position, including the token scattered this step).
Sentinel-padded table entries map to positions at/after ``lengths[b]``,
so the same mask hides them — exactly the invariant the gather path's
causal mask enforces.  Blocks entirely at/after the length are skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, bs: int, n: int,
                  window, softcap):
    b = pl.program_id(0)
    j = pl.program_id(2)               # kv block step (innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when((j * bs) < length)
    def _step():
        q = q_ref[0, 0]                               # [G, d]
        k = k_ref[0, :, 0, :]                         # [bs, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
        mask = pos < length
        if window is not None:
            # query sits at virtual position length-1
            mask &= (length - 1 - pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, :, 0, :],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, tables, lengths, *,
                           scale: float, window=None, softcap=None,
                           interpret: bool = False):
    """q: [B, Hkv, G, d], pools: [N, bs, Hkv, d(v)], tables: [B, n] int32,
    lengths: [B] int32 → [B, Hkv, G, dv].  ops.py does the GQA reshape."""
    B, Hkv, G, d = q.shape
    N, bs = k_pool.shape[:2]
    dv = v_pool.shape[-1]
    n = tables.shape[1]
    kernel = functools.partial(_paged_kernel, scale=scale, bs=bs, n=n,
                               window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n),
        in_specs=[
            pl.BlockSpec((1, 1, G, d),
                         lambda b, h, j, tables, lengths: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda b, h, j, tables, lengths:
                         (tables[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dv),
                         lambda b, h, j, tables, lengths:
                         (tables[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dv),
                               lambda b, h, j, tables, lengths:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, dv), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dv), q.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q, k_pool, v_pool)


def _paged_mla_kernel(tables_ref, lengths_ref, qe_ref, qr_ref, ckv_ref,
                      kr_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                      bs: int, n: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when((j * bs) < length)
    def _step():
        ckv = ckv_ref[0]                              # [bs, r]
        s = jnp.dot(qe_ref[0], ckv.T,
                    preferred_element_type=jnp.float32)
        s = s + jnp.dot(qr_ref[0], kr_ref[0].T,
                        preferred_element_type=jnp.float32)
        s = s * scale                                 # [H, bs]
        H = s.shape[0]
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (H, bs), 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(ckv.dtype), ckv, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_mla_attention_pallas(q_eff, q_rope, ckv_pool, kr_pool, tables,
                               lengths, *, scale: float,
                               interpret: bool = False):
    """q_eff: [B, H, r], q_rope: [B, H, dr], ckv_pool: [N, bs, r],
    kr_pool: [N, bs, dr], tables: [B, n], lengths: [B] → latent attention
    output [B, H, r] (the c_kv block is both key component and value)."""
    B, H, r = q_eff.shape
    dr = q_rope.shape[-1]
    N, bs = ckv_pool.shape[:2]
    n = tables.shape[1]
    kernel = functools.partial(_paged_mla_kernel, scale=scale, bs=bs, n=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n),
        in_specs=[
            pl.BlockSpec((1, H, r),
                         lambda b, j, tables, lengths: (b, 0, 0)),
            pl.BlockSpec((1, H, dr),
                         lambda b, j, tables, lengths: (b, 0, 0)),
            pl.BlockSpec((1, bs, r),
                         lambda b, j, tables, lengths:
                         (tables[b, j], 0, 0)),
            pl.BlockSpec((1, bs, dr),
                         lambda b, j, tables, lengths:
                         (tables[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, r),
                               lambda b, j, tables, lengths: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),    # m
            pltpu.VMEM((H, 1), jnp.float32),    # l
            pltpu.VMEM((H, r), jnp.float32),    # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_eff.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q_eff, q_rope, ckv_pool, kr_pool)
