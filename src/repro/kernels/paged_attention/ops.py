"""Public fused paged-attention decode ops: GQA grouping + dispatch.

Decode-only (T == 1), forward-only (no grads flow at serve time), so no
custom_vjp is needed — dispatch is a straight three-way switch shared
with the other kernel packages:

  * TPU            → native Pallas kernel (block-table scalar prefetch)
  * elsewhere      → the same kernel in interpret mode
  * Pallas missing → the jnp gather-then-attend reference

``models/layers.py`` routes its paged T==1 decode branch here when the
resolved ``paged_kernel`` knob says "pallas"; the ``paged_gather`` path
stays as the ref/oracle lowering ("ref").
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.compat import import_pallas_kernels, on_tpu

from .ref import paged_attention_ref, paged_mla_attention_ref

(paged_attention_pallas, paged_mla_attention_pallas,
 _PALLAS_OK) = import_pallas_kernels(
    "repro.kernels.paged_attention.kernel",
    "paged_attention_pallas", "paged_mla_attention_pallas")


def _lengths(offset, batch: int):
    """Per-row valid-key counts from the cache offset (scalar or [B]):
    a query at position ``offset`` attends positions [0, offset]."""
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 0:
        off = jnp.broadcast_to(off, (batch,))
    return off + 1


def paged_attention(q, k_pool, v_pool, tables, offset, *, scale=None,
                    window=None, softcap=None,
                    interpret: bool | None = None):
    """Fused GQA decode over a paged KV pool.

    q: [B, 1, Hq, d] (single decode query per row), pools
    [N, bs, Hkv, d(v)], tables [B, n] int32, offset scalar or [B] (tokens
    already cached; the query sits at that position) → [B, 1, Hq, dv],
    never materializing the gathered [B, n*bs, ...] view.
    """
    B, T, Hq, d = q.shape
    if T != 1:
        raise ValueError(f"paged_attention is decode-only (T==1), got T={T}")
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qh = q[:, 0].reshape(B, Hkv, G, d)
    lengths = _lengths(offset, B)
    if not _PALLAS_OK:
        o = paged_attention_ref(qh, k_pool, v_pool, tables, lengths,
                                scale=scale, window=window, softcap=softcap)
    else:
        interpret = (not on_tpu()) if interpret is None else interpret
        o = paged_attention_pallas(qh, k_pool, v_pool, tables, lengths,
                                   scale=scale, window=window,
                                   softcap=softcap, interpret=interpret)
    return o.reshape(B, 1, Hq, v_pool.shape[-1])


def paged_mla_attention(q_eff, q_rope, ckv_pool, kr_pool, tables, offset, *,
                        scale: float, interpret: bool | None = None):
    """Fused MLA absorbed decode over paged latent pools.

    q_eff: [B, 1, H, r] (q_nope·W_uk), q_rope: [B, 1, H, dr], ckv_pool
    [N, bs, r], kr_pool [N, bs, 1, dr] (as cached), tables [B, n], offset
    scalar or [B] → latent attention output [B, 1, H, r] (the caller
    applies W_uv outside — it is a weight, not cache, contraction).
    """
    B, T, H, r = q_eff.shape
    if T != 1:
        raise ValueError(
            f"paged_mla_attention is decode-only (T==1), got T={T}")
    qe = q_eff[:, 0]
    qr = q_rope[:, 0]
    kr = kr_pool[:, :, 0, :] if kr_pool.ndim == 4 else kr_pool
    lengths = _lengths(offset, B)
    if not _PALLAS_OK:
        o = paged_mla_attention_ref(qe, qr, ckv_pool, kr, tables, lengths,
                                    scale=scale)
    else:
        interpret = (not on_tpu()) if interpret is None else interpret
        o = paged_mla_attention_pallas(qe, qr, ckv_pool, kr, tables,
                                       lengths, scale=scale,
                                       interpret=interpret)
    return o[:, None]


__all__ = ["paged_attention", "paged_mla_attention",
           "paged_attention_ref", "paged_mla_attention_ref"]
