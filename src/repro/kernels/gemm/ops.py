"""jit'd public wrapper for the GEMM kernel: padding + dtype policy +
interpret fallback on non-TPU backends."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import import_pallas_kernels, on_tpu as _on_tpu

from .ref import gemm_ref

gemm_pallas, _PALLAS_OK = import_pallas_kernels(
    "repro.kernels.gemm.kernel", "gemm_pallas")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def gemm(x: jax.Array, y: jax.Array, *, block_m: int = 128,
         block_n: int = 128, block_k: int = 128,
         interpret: bool | None = None) -> jax.Array:
    """Padded blocked GEMM. interpret=None → auto (interpret off-TPU).
    Falls back to the jnp reference when the installed Pallas lacks the API
    the kernel needs (guarded import above)."""
    if not _PALLAS_OK:
        return gemm_ref(x, y)
    interpret = (not _on_tpu()) if interpret is None else interpret
    M, K = x.shape
    _, N = y.shape
    pm = (-M) % block_m
    pk = (-K) % block_k
    pn = (-N) % block_n
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    yp = jnp.pad(y, ((0, pk), (0, pn))) if (pk or pn) else y
    out = gemm_pallas(xp, yp, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)
    return out[:M, :N]


__all__ = ["gemm", "gemm_ref"]
