"""Blocked GEMM Pallas kernel — the RedMulE analogue on TPU.

MAGIA's tile offloads MatMuls to RedMulE, a 24×8 semi-systolic FP array fed
from 32 TCDM banks (paper §2.1).  The TPU-native re-think (DESIGN.md §2):
the MXU is a 128×128 systolic array fed from VMEM, so the tiling becomes
128-aligned VMEM blocks with an f32 accumulator scratch that lives across the
K-loop — grid (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics), f32
accumulation regardless of input dtype (RedMulE likewise accumulates wider
than its inputs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(x: jax.Array, y: jax.Array, *, block_m: int = 128,
                block_n: int = 128, block_k: int = 128,
                out_dtype=None, interpret: bool = False) -> jax.Array:
    """x: [M,K] @ y: [K,N] → [M,N]; dims must divide by the block sizes
    (ops.py pads). MXU alignment: blocks should be multiples of 128."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, (x.shape, y.shape)
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(f"dims {(M, K, N)} not divisible by blocks "
                         f"{(block_m, block_k, block_n)}")
    out_dtype = out_dtype or x.dtype
    k_steps = K // block_k
    kernel = functools.partial(_gemm_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, y)
