"""Pure-jnp oracle for the blocked GEMM kernel."""

import jax.numpy as jnp


def gemm_ref(x, y, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)
