"""Public tree-reduce ops: padding + interpret fallback + fused codecs.

Besides the plain ``tree_reduce``, this module owns the *codec-fused*
variants that collapse the wire-codec dequantize into the reduction /
accumulate launch:

  * ``encode_rows``       — per-row wire encoding of an [N, D] stack.
  * ``coded_tree_reduce`` — H-tree sum of N wire-encoded rows without a
    separate dequant pass (int8 dequants in VMEM; bf16 rides the f32
    accumulator of the plain kernel).
  * ``decode_add``        — ``keep + decode(wire)`` in one launch: the
    receive side of every fractal halving exchange
    (``core/collectives._codec_exchange_add``).

Fusing drops one kernel launch per codec use, which is exactly the
per-step α overhead ``core/autotune.CODEC_STEP_ALPHAS_FUSED`` prices —
the calibrated bucket tuner picks the cheaper codecs up automatically.

Off-TPU, ``decode_add`` is EXACTLY the jnp expression
``keep + codec.decode(wire)`` so collective token/bit-identity tests are
unaffected; ``interpret=True`` forces the kernel for parity tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import import_pallas_kernels, on_tpu as _on_tpu

from .ref import tree_reduce_ref

(tree_reduce_pallas, int8_tree_reduce_pallas, decode_add_bf16_pallas,
 decode_add_int8_pallas, _PALLAS_OK) = import_pallas_kernels(
    "repro.kernels.tree_reduce.kernel",
    "tree_reduce_pallas", "int8_tree_reduce_pallas",
    "decode_add_bf16_pallas", "decode_add_int8_pallas")


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tree_reduce(x: jax.Array, *, block: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """[N, D] → [D] deterministic pairwise-tree sum. N padded up to a power
    of two with zeros; D padded to the block size.  The reference fallback
    keeps the same H-tree reduction order (bitwise determinism holds)."""
    if not _PALLAS_OK:
        return tree_reduce_ref(x)
    interpret = (not _on_tpu()) if interpret is None else interpret
    N, D = x.shape
    n2 = 1 << max(1, (N - 1).bit_length())
    block = min(block, 1 << (D - 1).bit_length() if D else block)
    pd = (-D) % block
    xp = jnp.pad(x, ((0, n2 - N), (0, pd)))
    out = tree_reduce_pallas(xp, block=block, interpret=interpret)
    return out[:D]


# ---------------------------------------------------------------------------
# fused wire codecs
# ---------------------------------------------------------------------------

_CODEC_BLOCK = 128          # int8 codec group == one TPU lane row


def encode_rows(x: jax.Array, codec: str):
    """Per-row wire encoding of an [N, D] stack of reduction operands.

    Unlike ``optim.compression.Int8Codec.encode`` (which groups along the
    leading axis of a flat payload), rows here are independent wire
    messages, so int8 groups run along D: q [N, D/128, 128] int8 +
    scale [N, D/128, 1] f32.  D must be a multiple of 128 for int8.
    """
    if codec == "none":
        return {"x": x}
    if codec == "bf16":
        return {"x": x.astype(jnp.bfloat16)}
    if codec == "int8":
        N, D = x.shape
        if D % _CODEC_BLOCK:
            raise ValueError(f"D={D} not divisible by {_CODEC_BLOCK}")
        xb = x.reshape(N, D // _CODEC_BLOCK, _CODEC_BLOCK)
        scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
        safe = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(xb / safe), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    raise ValueError(f"unknown codec {codec!r}")


def _decode_rows(wire, codec: str, dtype):
    if codec in ("none", "bf16"):
        return wire["x"].astype(dtype)
    q, scale = wire["q"], wire["scale"]
    x = q.astype(dtype) * scale.astype(dtype)
    return x.reshape(q.shape[0], -1)


@functools.partial(jax.jit,
                   static_argnames=("codec", "block", "interpret"))
def coded_tree_reduce(wire, codec: str, *, block: int = 512,
                      interpret: bool | None = None) -> jax.Array:
    """H-tree sum of N wire-encoded rows → [D] f32, dequant fused into the
    reduction launch.  ``wire`` is ``encode_rows`` output; bf16 rows feed
    the plain kernel's f32 accumulator directly, int8 rows dequant in VMEM.
    The pairwise H-tree order is preserved (deterministic in N); int8 may
    differ from decode-then-``tree_reduce`` by an ulp where the dequant
    multiply fuses into the first add.
    """
    if not _PALLAS_OK:
        return tree_reduce_ref(_decode_rows(wire, codec, jnp.float32))
    interpret = (not _on_tpu()) if interpret is None else interpret
    if codec == "int8":
        q, scale = wire["q"], wire["scale"]
        N = q.shape[0]
        n2 = 1 << max(1, (N - 1).bit_length())
        qp = jnp.pad(q, ((0, n2 - N), (0, 0), (0, 0)))
        sp = jnp.pad(scale, ((0, n2 - N), (0, 0), (0, 0)))
        return int8_tree_reduce_pallas(qp, sp, out_dtype=jnp.float32,
                                       interpret=interpret)
    x = wire["x"]
    N, D = x.shape
    n2 = 1 << max(1, (N - 1).bit_length())
    block = min(block, 1 << (D - 1).bit_length() if D else block)
    pd = (-D) % block
    xp = jnp.pad(x, ((0, n2 - N), (0, pd)))
    out = tree_reduce_pallas(xp, block=block, interpret=interpret,
                             out_dtype=jnp.float32)
    return out[:D]


def decode_add(keep: jax.Array, wire, codec, *,
               interpret: bool | None = None) -> jax.Array:
    """``keep + codec.decode(wire)`` as ONE launch when the Pallas path is
    live — the fused receive+accumulate of a fractal halving exchange.

    ``codec`` is an ``optim.compression.Codec`` instance (its ``name``
    selects the kernel; its ``decode`` is the fallback).  Off-TPU with
    ``interpret=None`` this is EXACTLY ``keep + codec.decode(wire)`` —
    bit-stable for the collective identity tests.  Flat f32/[M] payloads
    only on the fused path; anything else falls back.
    """
    fused = _PALLAS_OK and (interpret if interpret is not None
                            else _on_tpu())
    if fused and keep.ndim == 1:
        interpret = (not _on_tpu()) if interpret is None else interpret
        M = keep.shape[0]
        if codec.name == "bf16" and wire["x"].shape == (M,):
            block = min(512, 1 << max(1, (M - 1).bit_length()))
            if M % block == 0:
                return decode_add_bf16_pallas(keep, wire["x"], block=block,
                                              interpret=interpret)
        if codec.name == "int8" and wire["q"].ndim == 2 \
                and wire["q"].shape[0] * wire["q"].shape[1] == M:
            return decode_add_int8_pallas(keep, wire["q"],
                                          wire["scale"].reshape(-1, 1),
                                          interpret=interpret)
    return keep + codec.decode(wire, keep.shape, keep.dtype)


__all__ = ["tree_reduce", "tree_reduce_ref", "encode_rows",
           "coded_tree_reduce", "decode_add"]
