"""Public tree-reduce op: padding + interpret fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import pallas_supported

from .ref import tree_reduce_ref

try:
    from .kernel import tree_reduce_pallas
    _PALLAS_OK = pallas_supported()
except Exception:  # pragma: no cover - exercised only on broken installs
    tree_reduce_pallas = None
    _PALLAS_OK = False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tree_reduce(x: jax.Array, *, block: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """[N, D] → [D] deterministic pairwise-tree sum. N padded up to a power
    of two with zeros; D padded to the block size.  The reference fallback
    keeps the same H-tree reduction order (bitwise determinism holds)."""
    if not _PALLAS_OK:
        return tree_reduce_ref(x)
    interpret = (not _on_tpu()) if interpret is None else interpret
    N, D = x.shape
    n2 = 1 << max(1, (N - 1).bit_length())
    block = min(block, 1 << (D - 1).bit_length() if D else block)
    pd = (-D) % block
    xp = jnp.pad(x, ((0, n2 - N), (0, pd)))
    out = tree_reduce_pallas(xp, block=block, interpret=interpret)
    return out[:D]


__all__ = ["tree_reduce", "tree_reduce_ref"]
