"""Pure-jnp oracle for the tree-reduce kernel (same pairwise order)."""

import math

import jax.numpy as jnp


def tree_reduce_ref(x):
    """[N, D] → [D]: pairwise halving in f32 (bitwise == kernel)."""
    acc = x.astype(jnp.float32)
    n = acc.shape[0]
    for _ in range(int(math.log2(n))):
        half = n // 2
        acc = acc[:half] + acc[half:n]
        n = half
    return acc[0].astype(x.dtype)


def linear_reduce_ref(x):
    """Accumulation-order baseline (sum left-to-right) for determinism tests."""
    acc = x[0].astype(jnp.float32)
    for i in range(1, x.shape[0]):
        acc = acc + x[i].astype(jnp.float32)
    return acc.astype(x.dtype)
