"""FractalSync-shaped tree reduction Pallas kernel.

On-chip analogue of the paper's H-tree: reduce N partial gradient rows to
one by **pairwise halving in log2(N) levels** — the same recursive-pairwise
order as the synchronization tree, which makes the reduction **bitwise
deterministic and independent of how partials arrived** (a linear
accumulation order changes with worker count; the tree order does not).
Used for micro-batch gradient-accumulation reduction inside a BSP rank
before the inter-chip fractal schedule takes over.

Grid: one program per 128-lane column block; the [N, block] tile reduces in
VMEM through log2(N) halvings (f32 accumulate).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _tree_reduce_kernel(x_ref, o_ref, *, levels: int):
    acc = x_ref[...].astype(jnp.float32)      # [N, block]
    n = acc.shape[0]
    for _ in range(levels):                   # pairwise halving: H-tree order
        half = n // 2
        acc = acc[:half] + acc[half:n]
        n = half
    o_ref[...] = acc[:1].astype(o_ref.dtype)


def tree_reduce_pallas(x: jax.Array, *, block: int = 512,
                       interpret: bool = False) -> jax.Array:
    """x: [N, D] → [D] pairwise-tree sum; N must be a power of two and
    D % block == 0 (ops.py pads)."""
    N, D = x.shape
    levels = int(math.log2(N))
    if 1 << levels != N:
        raise ValueError(f"N={N} not a power of two")
    if D % block:
        raise ValueError(f"D={D} not divisible by block={block}")
    kernel = functools.partial(_tree_reduce_kernel, levels=levels)
    out = pl.pallas_call(
        kernel,
        grid=(D // block,),
        in_specs=[pl.BlockSpec((N, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, D), x.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return out[0]
