"""FractalSync-shaped tree reduction Pallas kernel.

On-chip analogue of the paper's H-tree: reduce N partial gradient rows to
one by **pairwise halving in log2(N) levels** — the same recursive-pairwise
order as the synchronization tree, which makes the reduction **bitwise
deterministic and independent of how partials arrived** (a linear
accumulation order changes with worker count; the tree order does not).
Used for micro-batch gradient-accumulation reduction inside a BSP rank
before the inter-chip fractal schedule takes over.

Grid: one program per 128-lane column block; the [N, block] tile reduces in
VMEM through log2(N) halvings (f32 accumulate).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _tree_reduce_kernel(x_ref, o_ref, *, levels: int):
    acc = x_ref[...].astype(jnp.float32)      # [N, block]
    n = acc.shape[0]
    for _ in range(levels):                   # pairwise halving: H-tree order
        half = n // 2
        acc = acc[:half] + acc[half:n]
        n = half
    o_ref[...] = acc[:1].astype(o_ref.dtype)


def tree_reduce_pallas(x: jax.Array, *, block: int = 512,
                       interpret: bool = False, out_dtype=None) -> jax.Array:
    """x: [N, D] → [D] pairwise-tree sum; N must be a power of two and
    D % block == 0 (ops.py pads).  ``out_dtype`` decouples the result
    dtype from the input — a bf16 *wire* payload accumulates in f32 and
    lands in the caller's accumulation dtype without a second launch
    (the fused-codec path of ``ops.coded_tree_reduce``)."""
    N, D = x.shape
    levels = int(math.log2(N))
    if 1 << levels != N:
        raise ValueError(f"N={N} not a power of two")
    if D % block:
        raise ValueError(f"D={D} not divisible by block={block}")
    kernel = functools.partial(_tree_reduce_kernel, levels=levels)
    out = pl.pallas_call(
        kernel,
        grid=(D // block,),
        in_specs=[pl.BlockSpec((N, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, D), out_dtype or x.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return out[0]


# ---------------------------------------------------------------------------
# fused wire-codec variants: dequantize in VMEM, reduce in the same launch
# ---------------------------------------------------------------------------


def _int8_tree_reduce_kernel(q_ref, s_ref, o_ref, *, levels: int):
    """One 128-lane codec block: dequant q·scale in VMEM, then the same
    pairwise halving as ``_tree_reduce_kernel``.  H-tree order is
    preserved; only the dequant multiply may fuse into the first add
    (FMA), so fused vs dequant-then-reduce agree to an ulp, and the
    reduction stays deterministic in worker count."""
    acc = q_ref[:, 0, :].astype(jnp.float32) * s_ref[:, 0, :]   # [N, 128]
    n = acc.shape[0]
    for _ in range(levels):
        half = n // 2
        acc = acc[:half] + acc[half:n]
        n = half
    o_ref[...] = acc[:1].astype(o_ref.dtype)


def int8_tree_reduce_pallas(q: jax.Array, scale: jax.Array, *,
                            out_dtype=jnp.float32,
                            interpret: bool = False) -> jax.Array:
    """q: [N, nb, 128] int8 + scale: [N, nb, 1] f32 (per-row, per-128-lane
    codec blocks) → [nb*128] tree sum of the dequantized rows, one launch.
    N must be a power of two (ops.py pads with zero wire rows)."""
    N, nb, C = q.shape
    levels = int(math.log2(N))
    if 1 << levels != N:
        raise ValueError(f"N={N} not a power of two")
    kernel = functools.partial(_int8_tree_reduce_kernel, levels=levels)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((N, 1, C), lambda j: (0, j, 0)),
                  pl.BlockSpec((N, 1, 1), lambda j: (0, j, 0))],
        out_specs=pl.BlockSpec((1, C), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nb * C), out_dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scale)
    return out[0]


def _decode_add_bf16_kernel(k_ref, w_ref, o_ref):
    o_ref[...] = k_ref[...] + w_ref[...].astype(o_ref.dtype)


def _decode_add_int8_kernel(k_ref, q_ref, s_ref, o_ref):
    o_ref[...] = k_ref[...] + (q_ref[...].astype(jnp.float32)
                               * s_ref[...]).astype(o_ref.dtype)


def decode_add_bf16_pallas(keep: jax.Array, wire: jax.Array, *,
                           block: int = 512,
                           interpret: bool = False) -> jax.Array:
    """keep [M] + bf16 wire [M] → [M]: dequant+accumulate in one launch —
    the collective receive side of every fractal halving exchange.
    M % block == 0 (ops.py pads)."""
    M = keep.shape[0]
    out = pl.pallas_call(
        _decode_add_bf16_kernel,
        grid=(M // block,),
        in_specs=[pl.BlockSpec((1, block), lambda j: (0, j)),
                  pl.BlockSpec((1, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, M), keep.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keep[None], wire[None])
    return out[0]


def decode_add_int8_pallas(keep: jax.Array, q: jax.Array, scale: jax.Array,
                           *, interpret: bool = False) -> jax.Array:
    """keep [M] + int8 wire (q [M/128, 128], scale [M/128, 1]) → [M]:
    per-block dequant fused into the accumulate, one launch."""
    nb, C = q.shape
    out = pl.pallas_call(
        _decode_add_int8_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, C), lambda j: (j, 0)),
                  pl.BlockSpec((1, C), lambda j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((1, C), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, C), keep.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keep.reshape(nb, C), q, scale)
    return out.reshape(-1)
