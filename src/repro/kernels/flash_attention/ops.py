"""Public flash-attention op: GQA grouping, padding, custom-vjp backward.

Forward runs the Pallas kernel (interpret mode off-TPU); backward recomputes
through the jnp oracle under jax.checkpoint semantics (custom_vjp), so the
kernel is trainable without a hand-written bwd kernel — the classic
recompute trade the paper's BSP framing makes cheap (compute is local; only
barriers are global).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.compat import import_pallas_kernels, on_tpu as _on_tpu

from .ref import flash_attention_ref

flash_attention_pallas, _PALLAS_OK = import_pallas_kernels(
    "repro.kernels.flash_attention.kernel", "flash_attention_pallas")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, softcap, interpret):
    return _fwd_impl(q, k, v, causal, window, softcap, interpret)


def _fwd_impl(q, k, v, causal, window, softcap, interpret):
    if not _PALLAS_OK:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    bq = min(128, Tq) if Tq % 128 else 128
    bk = min(128, Tk) if Tk % 128 else 128
    pq = (-Tq) % bq
    pk = (-Tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0))) if pk else v
    # padded kv columns must not contribute: causal masking handles the tail
    # for pos >= Tk only when causal; otherwise mask via -inf keys is needed —
    # we keep causal=True usage in models; non-causal tests use exact shapes.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 softcap=softcap, block_q=bq, block_k=bk,
                                 interpret=interpret)
    return out[:, :Tq]


def _fwd(q, k, v, causal, window, softcap, interpret):
    return _fwd_impl(q, k, v, causal, window, softcap, interpret), (q, k, v)


def _bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap), q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, interpret: bool | None = None):
    """q: [B,Tq,Hq,D], k/v: [B,Tk,Hkv,D] → [B,Tq,Hq,D] (GQA grouped)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Tq, Hq, D = q.shape
    _, Tk, Hkv, Dv = v.shape
    G = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hkv, G, Tq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, 1, Tk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, 1, Tk, Dv)
    kf = jnp.broadcast_to(kf, (B * Hkv, G, Tk, D)).reshape(-1, Tk, D)
    vf = jnp.broadcast_to(vf, (B * Hkv, G, Tk, Dv)).reshape(-1, Tk, Dv)
    qf = qf.reshape(-1, Tq, D)
    out = _flash(qf, kf, vf, causal, window, softcap, interpret)
    return out.reshape(B, Hkv * G, Tq, Dv).transpose(0, 2, 1, 3)


__all__ = ["flash_attention", "flash_attention_ref"]
