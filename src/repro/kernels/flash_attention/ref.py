"""Pure-jnp oracle for the flash-attention kernel."""

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """q: [BH,Tq,D], k/v: [BH,Tk,D(v)] → [BH,Tq,Dv]; dense reference."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Tq)[:, None]
    k_pos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v).astype(q.dtype)
