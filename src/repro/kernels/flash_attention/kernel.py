"""Flash-attention (fwd) Pallas kernel: online softmax in VMEM.

The dominant hot-spot of every assigned transformer at prefill shapes.  TPU
re-think of the classic GPU kernel (DESIGN.md §2): instead of warp-level
softmax reductions, blocks are MXU-aligned VMEM tiles; the (m, l, acc)
running statistics live in VMEM scratch across the KV grid steps (innermost,
"arbitrary"); causal masking is positional via block-offset iota, and
fully-masked KV blocks are skipped by the grid index map (the causal ~2×).

Supports GQA (q heads grouped over kv heads), causal masking, sliding
window, and logit softcap (Gemma-2).  Backward uses the pure-jnp oracle
via jax.custom_vjp recompute (kernels/flash_attention/ops.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, kv_steps: int,
                  causal: bool, window, softcap):
    qi = pl.program_id(1)          # query block
    ki = pl.program_id(2)          # kv block (innermost)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    run = jnp.bool_(True)
    if causal:
        # skip kv blocks entirely above the causal diagonal
        run &= (ki * block_k) <= (qi * block_q + block_q - 1)
    if window is not None:
        # skip kv blocks entirely left of the sliding window
        run &= ((ki + 1) * block_k - 1) > (qi * block_q - window)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                  # [block_q, d]
        k = k_ref[0]                                  # [block_k, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           softcap=None, scale=None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: [BH, Tq, D], k/v: [BH, Tk, D] → [BH, Tq, D].

    Batch and (grouped) heads must be pre-flattened into BH (ops.py does
    GQA grouping + padding).  Tq % block_q == Tk % block_k == 0.
    """
    BH, Tq, D = q.shape
    _, Tk, Dv = v.shape
    if Tq % block_q or Tk % block_k:
        raise ValueError(f"{(Tq, Tk)} not divisible by {(block_q, block_k)}")
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kv_steps = Tk // block_k
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        kv_steps=kv_steps, causal=causal, window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(BH, Tq // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, Dv), jnp.float32),  # acc
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
