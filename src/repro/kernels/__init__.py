# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

def kernels_backend() -> str:
    """Which implementation the public ops dispatch to on this install.

    "pallas"   — the Pallas kernels (native on TPU, interpret elsewhere)
    "reference"— pure-jnp oracles (Pallas API unsupported by installed jax)

    Reads the ops modules' own dispatch flags so this answer can never
    disagree with what the ops actually run (a kernel module import can
    fail independently of the coarse API probe in ``compat``).
    """
    from repro.kernels.flash_attention import ops as _fa
    from repro.kernels.gemm import ops as _gemm
    from repro.kernels.paged_attention import ops as _pa
    from repro.kernels.tree_reduce import ops as _tr
    pallas = (_gemm._PALLAS_OK and _fa._PALLAS_OK and _tr._PALLAS_OK
              and _pa._PALLAS_OK)
    return "pallas" if pallas else "reference"

