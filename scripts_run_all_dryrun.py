"""Run all dry-run cells cheap-first (resumable; skips cached)."""
import subprocess, sys, os, itertools
CHEAP = ["qwen2.5-3b", "phi4-mini-3.8b", "gemma2-2b", "musicgen-medium",
         "paligemma-3b", "xlstm-1.3b", "granite-34b", "jamba-v0.1-52b",
         "qwen3-moe-235b-a22b", "deepseek-v3-671b"]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]
cells = []
for shape in SHAPES:
    for arch in CHEAP:
        for mesh in ("single", "multi"):
            cells.append((arch, shape, mesh))
env = dict(os.environ); env["PYTHONPATH"] = "src"
for arch, shape, mesh in cells:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell",
           f"{arch}:{shape}", "--mesh", mesh]
    r = subprocess.run(cmd, env=env, cwd="/root/repo")
