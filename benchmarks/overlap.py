"""Bucketed superstep overlap sweep — bucket size × schedule vs monolithic.

For each (mesh, model) cell the SuperstepEngine partitions a synthetic
transformer's gradient leaves into reverse-layer buckets and the sweep
reports, per bucket size (including the DP-searched ``bucket_mb="auto"``
boundaries):

  * the per-bucket autotuned schedules (``schedule="auto"``) and codecs
    (``bucket_codec="auto"``),
  * the overlap-aware predicted step time (``cost_model.overlap_step_cost``:
    buckets enter the shared fabric as backward produces them), and
  * the no-overlap baseline (backward, THEN all communication — what the
    monolithic path pays).

The headline claim is asserted: for at least one realistic cell the
overlap-aware predicted step time is strictly below the no-overlap sum,
and the DP-searched boundaries predict ≤ every fixed-size greedy packing.
A second section replays a bucket pipeline on the contended-NoC simulator
(``simulator.pipelined_on_noc``) against the serial sum of per-bucket
replays — the same overlap, with link contention simulated rather than
modeled.

``--measured`` adds the measured mode (≥8 host devices): the link
parameters are CALIBRATED from real jitted collectives
(``core.calibrate.fit_link_params``), the DP + per-bucket-codec engine is
refined with a measured-schedule budget (``SuperstepEngine.refined``), and
the resulting configuration's real jitted sync wall-clock is compared
against the greedy analytic configuration.  The greedy baseline is itself
in the measured candidate set (it is the tuner's upper bound), so the
chosen configuration's wall-clock ≤ greedy+analytic is asserted — measured
autotuning never does worse than its fallback on the very measurements it
selected by.

Results are persisted machine-readably to ``BENCH_overlap.json``
(predicted vs measured seconds, chosen schedules/codecs, speedups) so the
perf trajectory is tracked across PRs.

Standalone: PYTHONPATH=src python -m benchmarks.overlap \
                [--smoke] [--measured] [--devices N] [--out FILE]
Harness:    PYTHONPATH=src python -m benchmarks.run --only overlap
CI runs ``--smoke --measured --devices 8`` so neither path can rot.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import autotune, cost_model as CM, schedule_ir as IR
from repro.core import superstep as SS
from repro.core.bsp import BSPConfig
from repro.core.simulator import pipelined_on_noc, schedule_on_noc

MFU = 0.4           # assumed model-flops utilization for the backward pass


def transformer_leaf_specs(d_model: int, n_layers: int, vocab: int):
    """Leaf sizes of a GPT-ish decoder in forward (layer) order."""
    leaves = [(vocab, d_model)]                       # embedding
    for _ in range(n_layers):
        leaves += [(d_model, 3 * d_model),            # qkv
                   (d_model, d_model),                # attn out
                   (d_model, 4 * d_model),            # mlp up
                   (4 * d_model, d_model),            # mlp down
                   (d_model,), (d_model,)]            # norms
    leaves += [(d_model,), (vocab, d_model)]          # final norm, lm head
    return tuple(SS.LeafSpec(shape=s, dtype="float32") for s in leaves)


def backward_seconds(n_params: int, tokens_per_rank: int,
                     chip: CM.ChipParams = CM.TPU_V5E) -> float:
    """4·P FLOPs/token for backward, at MFU of the chip's peak."""
    return 4.0 * n_params * tokens_per_rank / (MFU * chip.peak_flops)


CELLS = (
    # (mesh shape, d_model, n_layers, vocab, tokens/rank/step)
    ((4, 4), 2048, 24, 32_000, 8_192),     # ~1.4B on a 4×4 v5e slice
    ((8, 8), 4096, 32, 32_000, 4_096),     # ~6.5B on an 8×8 slice
)
BUCKET_MBS = (None, 16.0, 64.0, 256.0, "auto")


def sweep_cell(shape, d_model, n_layers, vocab, tokens,
               bucket_mbs=BUCKET_MBS, rows=None):
    specs = transformer_leaf_specs(d_model, n_layers, vocab)
    n_params = sum(s.size for s in specs)
    bwd_s = backward_seconds(n_params, tokens)
    cell = f"{shape[0]}x{shape[1]}/{n_params / 1e9:.1f}B"
    any_overlap_win = False
    fixed_overlapped, auto_overlapped = [], None
    for mb in bucket_mbs:
        cfg = BSPConfig(schedule="auto", bucket_mb=mb, bucket_codec="auto")
        eng = SS.SuperstepEngine(specs, cfg, shape, backward_s=bwd_s)
        tl = eng.timeline(bwd_s)
        picks = "+".join(
            f"{n}x{c}" for n, c in sorted(
                (s, eng.schedules.count(s)) for s in set(eng.schedules)))
        codecs = "+".join(
            f"{n}x{c}" for n, c in sorted(
                (s, eng.codec_names.count(s))
                for s in set(eng.codec_names)))
        label = "mono" if mb is None else \
            ("auto" if mb == "auto" else f"{mb:g}MB")
        print(f"overlap/{cell},{label},{eng.n_buckets} buckets,{picks},"
              f"{codecs},overlapped={tl.overlapped_s * 1e3:.2f}ms,"
              f"serial={tl.serial_s * 1e3:.2f}ms,"
              f"gain={tl.overlap_gain * 100:.1f}%")
        if rows is not None:
            rows.append({"cell": cell, "bucket_mb": mb,
                         "n_buckets": eng.n_buckets,
                         "schedules": list(eng.schedules),
                         "codecs": list(eng.codec_names),
                         "plan": eng.plan.source if eng.plan else None,
                         "predicted_overlapped_s": tl.overlapped_s,
                         "predicted_serial_s": tl.serial_s,
                         "overlap_gain": tl.overlap_gain})
        if mb is not None and tl.overlapped_s < tl.serial_s:
            any_overlap_win = True
        if mb == "auto":
            auto_overlapped = tl.overlapped_s
        elif mb is not None:
            fixed_overlapped.append((mb, tl.overlapped_s))
    if auto_overlapped is not None and fixed_overlapped:
        # the DP searches the space the fixed sizes sample, so it must not
        # predict (meaningfully) worse than any greedy packing it had as an
        # upper bound.  The DP optimizes the band-quantized policy price
        # while the timeline reprices exactly, so allow the quantization
        # slack (one quarter-octave ≈ 9%); the EXACT optimality claim is
        # locked by the brute-force property test instead.
        best_fixed = min(t for _, t in fixed_overlapped)
        assert auto_overlapped <= best_fixed * 1.10, (
            f"{cell}: DP-searched boundaries predict {auto_overlapped} "
            f"> best fixed bucket size {best_fixed}")
    return any_overlap_win


def noc_replay_section(shape=(4, 4), payload_flits=2048, n_buckets=4,
                       rows=None) -> None:
    """Simulated (contended-NoC) overlap vs serial replay of the buckets."""
    flits = [payload_flits // n_buckets] * n_buckets
    names = [autotune.pick_schedule(shape, f * 4, link=CM.MAGIA)
             for f in flits]
    progs = [IR.build_program(n, shape) for n in names]
    serial = sum(schedule_on_noc(p, payload_flits=f).overhead
                 for p, f in zip(progs, flits))
    # grads drop out of backward at a steady cadence ending at `serial`
    ready = [int(serial * (i + 1) / n_buckets) for i in range(n_buckets)]
    pipe = pipelined_on_noc(progs, payload_flits=flits, ready=ready)
    overlapped = pipe.overhead
    no_overlap = max(ready) + serial    # backward, THEN all buckets
    print(f"overlap/noc_{shape[0]}x{shape[1]},{n_buckets} buckets,"
          f"{'+'.join(names)},sim_overlapped={overlapped},"
          f"sim_serial={no_overlap},program_finish={pipe.program_finish}")
    if rows is not None:
        rows.append({"shape": list(shape), "n_buckets": n_buckets,
                     "schedules": names, "sim_overlapped": int(overlapped),
                     "sim_serial": int(no_overlap)})
    assert overlapped < no_overlap, (
        f"pipelined NoC replay {overlapped} should beat the serial sum "
        f"{no_overlap}")


# ---------------------------------------------------------------------------
# measured mode: calibrated + DP + per-bucket codec vs greedy analytic,
# real jitted wall-clock on ≥8 host devices
# ---------------------------------------------------------------------------

MEASURE_WORLD = 8


def _sync_step_seconds(eng, mesh, axes, leaves, repeats=5):
    """Best-of-``repeats`` wall-clock of the engine's jitted bucketed sync."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat

    spec = [P() for _ in leaves]
    fn = jax.jit(compat.shard_map(
        lambda tree: eng.sync(tree), mesh, (spec,), spec,
        check_vma=False, axis_names=frozenset(axes)))
    out = fn(leaves)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(leaves)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measured_section(smoke: bool, rows=None) -> None:
    """The acceptance claim, measured: DP+calibrated+codec ≤ greedy+analytic.

    The measured tuner's candidate set CONTAINS the greedy analytic config
    (its own fallback/upper bound), so the selected configuration can never
    measure worse than it — the assert locks the selection logic, the
    printed speedup reports how much the search actually bought.
    """
    import jax
    import numpy as np

    from repro import compat
    from repro.core.calibrate import fit_link_params

    if len(jax.devices()) < MEASURE_WORLD:
        print(f"overlap/measured,skip,needs {MEASURE_WORLD} devices,")
        return
    shape = (MEASURE_WORLD,)
    axes = ("data",)
    mesh = compat.make_mesh(shape, axes)

    d_model, n_layers, vocab = (256, 4, 4096) if smoke else (512, 8, 8192)
    specs = transformer_leaf_specs(d_model, n_layers, vocab)
    rng = np.random.default_rng(0)
    leaves = [jax.numpy.asarray(
        rng.normal(size=s.shape).astype(np.float32)) for s in specs]
    n_params = sum(s.size for s in specs)
    bwd_s = backward_seconds(n_params, 1024)

    # 1. calibrate: fit (alpha, hop, beta) from real jitted collectives
    fit = fit_link_params(shape=shape,
                          payload_elems=(1 << 12, 1 << 15, 1 << 17),
                          repeats=2)
    print(f"overlap/calibrated,{fit.link.name},"
          f"alpha={fit.link.alpha_s:.2e},bw={fit.link.bw_Bps:.3g},"
          f"residual={fit.residual:.2f}")

    # 2. the greedy analytic baseline: fixed bucket size, default link
    cfg_greedy = BSPConfig(schedule="auto", bucket_mb=4.0)
    eng_greedy = SS.SuperstepEngine(specs, cfg_greedy, shape,
                                    backward_s=bwd_s)

    # 3. the tuned contender: DP boundaries + calibrated link + per-bucket
    #    codec, schedules refined with a measured budget
    cfg_dp = BSPConfig(schedule="auto", bucket_mb="auto",
                       bucket_codec="auto", link=fit.link)
    eng_dp = SS.SuperstepEngine(specs, cfg_dp, shape, backward_s=bwd_s)

    def measure(schedule: str, payload_bytes: float) -> float:
        from repro.core.calibrate import _measure_collective
        per_rank = max(MEASURE_WORLD,
                       int(payload_bytes / 4) // MEASURE_WORLD
                       * MEASURE_WORLD)
        return _measure_collective(mesh, axes, shape, schedule, per_rank,
                                   repeats=2, inner=3)

    budget = 4 if smoke else 8
    eng_ref = eng_dp.refined(measure, measure_budget=budget)

    # 4. measure the full bucketed sync for every candidate; the tuner
    #    takes the measured argmin (greedy included — it is the fallback)
    candidates = {
        "greedy+analytic": eng_greedy,
        "dp+calibrated": eng_dp,
        "dp+calibrated+refined": eng_ref,
    }
    repeats = 3 if smoke else 5
    timed = {}
    for name, eng in candidates.items():
        timed[name] = _sync_step_seconds(eng, mesh, axes, leaves,
                                         repeats=repeats)
        print(f"overlap/measured_{name},{eng.n_buckets} buckets,"
              f"{'+'.join(eng.schedules)},"
              f"{'+'.join(eng.codec_names)},"
              f"wall={timed[name] * 1e3:.2f}ms")
    chosen = min(timed, key=timed.get)
    greedy_s = timed["greedy+analytic"]
    chosen_s = timed[chosen]
    speedup = greedy_s / max(chosen_s, 1e-12)
    print(f"overlap/measured_chosen,{chosen},"
          f"{chosen_s * 1e3:.2f}ms,speedup_vs_greedy={speedup:.2f}x")
    if rows is not None:
        rows.append({
            "world": MEASURE_WORLD,
            "link": {"alpha_s": fit.link.alpha_s, "hop_s": fit.link.hop,
                     "bw_Bps": fit.link.bw_Bps, "residual": fit.residual},
            "measured_s": timed,
            "chosen": chosen,
            "chosen_schedules": list(candidates[chosen].schedules),
            "chosen_codecs": list(candidates[chosen].codec_names),
            "speedup_vs_greedy": speedup,
        })
    assert chosen_s <= greedy_s, (
        f"measured selection broke: chose {chosen} at {chosen_s}s over "
        f"greedy+analytic at {greedy_s}s")
    print("overlap/measured_claim,ok,DP+calibrated selection wall-clock "
          "<= greedy+analytic")


def run(smoke: bool = False, measured: bool = False,
        out: str = "BENCH_overlap.json") -> None:
    results = {"cells": [], "noc": [], "measured": []}
    print("overlap/cell,buckets,schedules,codecs,predicted,baseline,gain")
    cells = CELLS[:1] if smoke else CELLS
    bucket_mbs = (None, 64.0, "auto") if smoke else BUCKET_MBS
    wins = [sweep_cell(*cell, bucket_mbs=bucket_mbs, rows=results["cells"])
            for cell in cells]
    assert any(wins), (
        "expected ≥1 cell where the overlap-aware predicted step time "
        "is strictly below the no-overlap sum")
    print("overlap/claim,ok,overlap-aware predicted step time < "
          "no-overlap sum")
    noc_replay_section(payload_flits=512 if smoke else 2048,
                       rows=results["noc"])
    if measured:
        measured_section(smoke, rows=results["measured"])
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"overlap/json,written,{out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell sweep for CI")
    ap.add_argument("--measured", action="store_true",
                    help="calibrate + measure real jitted configs "
                         "(needs ≥8 devices)")
    ap.add_argument("--devices", type=int, default=0,
                    help="host-device override (set before jax init)")
    ap.add_argument("--out", default="BENCH_overlap.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)
    if args.devices:
        import os
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    run(smoke=args.smoke, measured=args.measured, out=args.out)


if __name__ == "__main__":
    main()
