"""Bucketed superstep overlap sweep — bucket size × schedule vs monolithic.

For each (mesh, model) cell the SuperstepEngine partitions a synthetic
transformer's gradient leaves into reverse-layer buckets and the sweep
reports, per bucket size:

  * the per-bucket autotuned schedules (``schedule="auto"``),
  * the overlap-aware predicted step time (``cost_model.overlap_step_cost``:
    buckets enter the shared fabric as backward produces them), and
  * the no-overlap baseline (backward, THEN all communication — what the
    monolithic path pays).

The headline claim is asserted: for at least one realistic cell the
overlap-aware predicted step time is strictly below the no-overlap sum.
A second section replays a bucket pipeline on the contended-NoC simulator
(``simulator.pipelined_on_noc``) against the serial sum of per-bucket
replays — the same overlap, with link contention simulated rather than
modeled.

Standalone: PYTHONPATH=src python -m benchmarks.overlap [--smoke]
Harness:    PYTHONPATH=src python -m benchmarks.run --only overlap
CI runs ``--smoke`` (one cell per section) so this sweep cannot rot.
"""

from __future__ import annotations

import argparse

from repro.core import autotune, cost_model as CM, schedule_ir as IR
from repro.core import superstep as SS
from repro.core.bsp import BSPConfig
from repro.core.simulator import pipelined_on_noc, schedule_on_noc

MFU = 0.4           # assumed model-flops utilization for the backward pass


def transformer_leaf_specs(d_model: int, n_layers: int, vocab: int):
    """Leaf sizes of a GPT-ish decoder in forward (layer) order."""
    leaves = [(vocab, d_model)]                       # embedding
    for _ in range(n_layers):
        leaves += [(d_model, 3 * d_model),            # qkv
                   (d_model, d_model),                # attn out
                   (d_model, 4 * d_model),            # mlp up
                   (4 * d_model, d_model),            # mlp down
                   (d_model,), (d_model,)]            # norms
    leaves += [(d_model,), (vocab, d_model)]          # final norm, lm head
    return tuple(SS.LeafSpec(shape=s, dtype="float32") for s in leaves)


def backward_seconds(n_params: int, tokens_per_rank: int,
                     chip: CM.ChipParams = CM.TPU_V5E) -> float:
    """4·P FLOPs/token for backward, at MFU of the chip's peak."""
    return 4.0 * n_params * tokens_per_rank / (MFU * chip.peak_flops)


CELLS = (
    # (mesh shape, d_model, n_layers, vocab, tokens/rank/step)
    ((4, 4), 2048, 24, 32_000, 8_192),     # ~1.4B on a 4×4 v5e slice
    ((8, 8), 4096, 32, 32_000, 4_096),     # ~6.5B on an 8×8 slice
)
BUCKET_MBS = (None, 16.0, 64.0, 256.0)


def sweep_cell(shape, d_model, n_layers, vocab, tokens,
               bucket_mbs=BUCKET_MBS) -> bool:
    specs = transformer_leaf_specs(d_model, n_layers, vocab)
    n_params = sum(s.size for s in specs)
    bwd_s = backward_seconds(n_params, tokens)
    cell = f"{shape[0]}x{shape[1]}/{n_params / 1e9:.1f}B"
    any_overlap_win = False
    for mb in bucket_mbs:
        cfg = BSPConfig(schedule="auto", bucket_mb=mb)
        eng = SS.SuperstepEngine(specs, cfg, shape)
        tl = eng.timeline(bwd_s)
        picks = "+".join(
            f"{n}x{c}" for n, c in sorted(
                (s, eng.schedules.count(s)) for s in set(eng.schedules)))
        label = "mono" if mb is None else f"{mb:g}MB"
        print(f"overlap/{cell},{label},{eng.n_buckets} buckets,{picks},"
              f"overlapped={tl.overlapped_s * 1e3:.2f}ms,"
              f"serial={tl.serial_s * 1e3:.2f}ms,"
              f"gain={tl.overlap_gain * 100:.1f}%")
        if mb is not None and tl.overlapped_s < tl.serial_s:
            any_overlap_win = True
    return any_overlap_win


def noc_replay_section(shape=(4, 4), payload_flits=2048, n_buckets=4) -> None:
    """Simulated (contended-NoC) overlap vs serial replay of the buckets."""
    flits = [payload_flits // n_buckets] * n_buckets
    names = [autotune.pick_schedule(shape, f * 4, link=CM.MAGIA)
             for f in flits]
    progs = [IR.build_program(n, shape) for n in names]
    serial = sum(schedule_on_noc(p, payload_flits=f).overhead
                 for p, f in zip(progs, flits))
    # grads drop out of backward at a steady cadence ending at `serial`
    ready = [int(serial * (i + 1) / n_buckets) for i in range(n_buckets)]
    pipe = pipelined_on_noc(progs, payload_flits=flits, ready=ready)
    overlapped = pipe.overhead
    no_overlap = max(ready) + serial    # backward, THEN all buckets
    print(f"overlap/noc_{shape[0]}x{shape[1]},{n_buckets} buckets,"
          f"{'+'.join(names)},sim_overlapped={overlapped},"
          f"sim_serial={no_overlap},program_finish={pipe.program_finish}")
    assert overlapped < no_overlap, (
        f"pipelined NoC replay {overlapped} should beat the serial sum "
        f"{no_overlap}")


def run(smoke: bool = False) -> None:
    print("overlap/cell,buckets,schedules,predicted,baseline,gain")
    cells = CELLS[:1] if smoke else CELLS
    bucket_mbs = (None, 64.0) if smoke else BUCKET_MBS
    wins = [sweep_cell(*cell, bucket_mbs=bucket_mbs) for cell in cells]
    assert any(wins), (
        "expected ≥1 cell where the overlap-aware predicted step time "
        "is strictly below the no-overlap sum")
    print("overlap/claim,ok,overlap-aware predicted step time < "
          "no-overlap sum")
    noc_replay_section(payload_flits=512 if smoke else 2048)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell sweep for CI")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
