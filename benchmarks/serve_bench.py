"""Serving A/B benchmarks: scheduling and KV-memory wins, both asserted.

**Continuous vs wave** (PR 3): the wave baseline admits ``max_slots``
requests, decodes until the WHOLE wave drains, and only then admits again —
on ragged output lengths every wave burns slot-steps padding out its
straggler.  The continuous engine refills a slot the moment EOS (or the
budget) frees it, paying only the interleaved admission-prefill ticks.

**Paged vs contiguous** (this sweep): both engines get the SAME KV HBM
budget (``contig_slots * max_len`` cache positions per layer — the paged
pool is exactly that many positions, plus one sentinel block of
bookkeeping).  The contiguous backend must reserve a full ``max_len`` row
per slot, so the budget caps it at ``contig_slots`` concurrent requests
even though a ragged long-context mix mostly uses a fraction of each row.
The paged backend allocates blocks as sequences actually grow, so the same
budget sustains strictly more live slots — higher tokens/step, fewer decode
steps — while emitting token-identical outputs (same fold-in sampling, same
chunk grid, bit-identical gathered attention).

All runners share one RNG discipline, so per-request outputs are
token-identical across every mode — the comparisons isolate *scheduling*
and *memory*, not sampling noise.  Metrics asserted:

  * decode-step slot occupancy and peak live slots (concurrency),
  * tokens per decode step — the deterministic tok/s proxy: the decode step
    is one fixed-shape compiled call, so per-step cost is constant and
    tok/s ∝ tokens/step (measured wall tok/s is printed, never asserted).

Standalone: PYTHONPATH=src python -m benchmarks.serve_bench \
                [--smoke] [--kv-mode all|contiguous|paged] [--devices N]
Harness:    PYTHONPATH=src python -m benchmarks.run --only serve_bench
CI runs ``--smoke`` and ``--smoke --kv-mode paged --devices 8`` so neither
claim can rot.
"""

from __future__ import annotations

import argparse
import os

import numpy as np

# (max_slots, n_requests, prompt_len, gen_lo, gen_hi)
CELLS = (
    (4, 16, 8, 4, 32),      # ragged budgets: the wave pathology
    (8, 24, 8, 2, 24),      # wider pool, heavier churn
)
SMOKE_CELLS = ((4, 12, 8, 4, 24),)

# (max_len, block_size, contig_slots, paged_slots,
#  (n_short, p_lo, p_hi, g_lo, g_hi), (n_long, p_long, g_long))
# Equal HBM budget: contig_slots * max_len positions; the paged pool gets
# exactly that many (kv_blocks = budget/bs + 1 sentinel).  paged_slots is
# a host-side cap only — free blocks gate admission.
PAGED_CELLS = (
    (192, 16, 4, 12, (20, 8, 24, 4, 16), (4, 80, 40)),
)
PAGED_SMOKE_CELLS = (
    (64, 8, 8, 16, (40, 6, 12, 4, 10), (4, 24, 16)),
)


def make_requests(cfg, n, prompt_len, gen_lo, gen_hi, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [
        Request(req_id=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=(prompt_len,)).tolist(),
                max_new_tokens=int(rng.integers(gen_lo, gen_hi + 1)))
        for i in range(n)
    ]


def make_ragged_mix(cfg, short, long, seed=0):
    """A ragged long-context mix: mostly short chats, a few long documents
    — the workload where per-slot max_len reservations waste the most HBM.
    Prompt lengths are drawn so block_size rarely divides them (chunk
    boundaries straddle block edges)."""
    from repro.serve import Request
    n_short, p_lo, p_hi, g_lo, g_hi = short
    n_long, p_long, g_long = long
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_short + n_long):
        if n_long and i % (max(1, (n_short + n_long) // n_long)) == 0 \
                and sum(1 for r in reqs if len(r.prompt) == p_long) < n_long:
            plen, gen = p_long, g_long
        else:
            plen = int(rng.integers(p_lo, p_hi + 1))
            gen = int(rng.integers(g_lo, g_hi + 1))
        reqs.append(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(plen,)).tolist(),
            max_new_tokens=gen))
    return reqs


def _mesh_for(devices, max_slots):
    if not devices:
        return None
    if max_slots % devices:
        print(f"serve/note,unsharded,{max_slots} slots do not divide "
              f"{devices} devices — this engine runs without a mesh")
        return None
    from repro.launch.mesh import make_mesh
    return make_mesh((devices,), ("data",))


def bench_cell(cfg, params, max_slots, n, prompt_len, gen_lo, gen_hi,
               clock="wall"):
    from repro.serve import EngineConfig, ServeEngine, serve_waves

    ecfg = EngineConfig(max_slots=max_slots,
                        max_len=prompt_len + gen_hi + 1,
                        prefill_chunk=prompt_len,
                        chunks_per_step=2,
                        clock=clock)    # wall: measured tok/s; step (smoke):
                                        # deterministic TTFT columns in CI
    requests = make_requests(cfg, n, prompt_len, gen_lo, gen_hi)

    engine = ServeEngine(cfg, params, ecfg)
    cont_out = engine.run(make_requests(cfg, n, prompt_len, gen_lo, gen_hi))
    cont = engine.metrics.summary()

    wave_out, wave_metrics = serve_waves(cfg, params, ecfg, requests)
    wave = wave_metrics.summary()

    assert cont_out == wave_out, (
        "fold-in sampling must make scheduling invisible to outputs")

    cell = f"{max_slots}slots/{n}req/gen{gen_lo}-{gen_hi}"
    for label, m in (("continuous", cont), ("wave", wave)):
        print(f"serve/{cell},{label},steps={m['decode_steps']:.0f},"
              f"occupancy={m['occupancy']:.3f},"
              f"tok_per_step={m['tokens_per_step']:.2f},"
              f"ttft_p50={m['ttft_p50_s'] * 1e3:.0f}ms,"
              f"wall_tok_s={m['tokens_per_s']:.0f}")
    assert cont["occupancy"] > wave["occupancy"], (
        f"{cell}: continuous occupancy {cont['occupancy']:.3f} must beat "
        f"wave {wave['occupancy']:.3f}")
    assert cont["tokens_per_step"] > wave["tokens_per_step"], (
        f"{cell}: continuous tokens/step {cont['tokens_per_step']:.2f} must "
        f"beat wave {wave['tokens_per_step']:.2f}")
    assert cont["decode_steps"] < wave["decode_steps"], (
        f"{cell}: continuous must finish in fewer decode steps")
    return cont, wave


def bench_paged_cell(cfg, params, cell, devices=0, clock="wall"):
    from repro.serve import EngineConfig, ServeEngine

    max_len, bs, contig_slots, paged_slots, short, long = cell
    budget = contig_slots * max_len          # positions per layer leaf
    usable = budget // bs
    assert usable * bs == budget, "budget must be block-aligned"
    chunk = min(16, max_len)

    contig_cfg = EngineConfig(
        max_slots=contig_slots, max_len=max_len, prefill_chunk=chunk,
        chunks_per_step=2, clock=clock)
    paged_cfg = EngineConfig(
        max_slots=paged_slots, max_len=max_len, prefill_chunk=chunk,
        chunks_per_step=2, kv_mode="paged", block_size=bs,
        kv_blocks=usable + 1,                # +1: the sentinel block
        clock=clock)

    cont = ServeEngine(cfg, params, contig_cfg,
                       mesh=_mesh_for(devices, contig_slots))
    cont_out = cont.run(make_ragged_mix(cfg, short, long))
    cm = cont.metrics.summary()

    paged = ServeEngine(cfg, params, paged_cfg,
                        mesh=_mesh_for(devices, paged_slots))
    paged_out = paged.run(make_ragged_mix(cfg, short, long))
    pm = paged.metrics.summary()

    assert paged_out == cont_out, (
        "paged backend must emit token-identical outputs to contiguous")
    assert pm["blocks_peak"] <= usable, (
        f"paged used {pm['blocks_peak']} blocks, budget is {usable}")

    n_req = short[0] + long[0]
    cell_name = (f"{budget}pos/{n_req}req/"
                 f"c{contig_slots}-p{paged_slots}slots/bs{bs}")
    for label, m in (("contiguous", cm), ("paged", pm)):
        print(f"serve/{cell_name},{label},steps={m['decode_steps']:.0f},"
              f"peak_active={m['peak_active']:.0f},"
              f"occupancy={m['occupancy']:.3f},"
              f"tok_per_step={m['tokens_per_step']:.2f},"
              f"hit_rate={m['prefix_hit_rate']:.2f},"
              f"blocks_peak={m['blocks_peak']:.0f},"
              f"preempt={m['preemptions']:.0f}")
    assert pm["peak_active"] > cm["peak_active"], (
        f"{cell_name}: paged peak concurrency {pm['peak_active']} must "
        f"beat contiguous {cm['peak_active']} under the same HBM budget")
    assert pm["tokens_per_step"] > cm["tokens_per_step"], (
        f"{cell_name}: paged tokens/step {pm['tokens_per_step']:.2f} must "
        f"beat contiguous {cm['tokens_per_step']:.2f}")
    assert pm["decode_steps"] < cm["decode_steps"], (
        f"{cell_name}: paged must finish in fewer decode steps")
    return cm, pm


def run(smoke: bool = False, kv_mode: str = "all", devices: int = 0) -> None:
    import jax

    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = get_config("gemma2-2b-smoke")
    params = T.init_params(cfg, jax.random.key(0))
    # smoke (CI) runs on the virtual step clock — deterministic timing
    # columns; full runs measure real wall seconds
    clock = "step" if smoke else "wall"
    if kv_mode in ("all", "contiguous"):
        cells = SMOKE_CELLS if smoke else CELLS
        print("serve/cell,mode,steps,occupancy,tok_per_step,ttft_p50,"
              "wall_tok_s")
        for cell in cells:
            bench_cell(cfg, params, *cell, clock=clock)
        print("serve/claim,ok,continuous admission beats wave baseline on "
              "occupancy AND tokens/step (outputs token-identical)")
    if kv_mode in ("all", "paged"):
        cells = PAGED_SMOKE_CELLS if smoke else PAGED_CELLS
        print("serve/cell,mode,steps,peak_active,occupancy,tok_per_step,"
              "hit_rate,blocks_peak,preempt")
        for cell in cells:
            bench_paged_cell(cfg, params, cell, devices=devices,
                             clock=clock)
        print("serve/claim,ok,paged KV serves the ragged mix at strictly "
              "higher concurrency than contiguous under an equal HBM "
              "budget (outputs token-identical)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell sweep for CI")
    ap.add_argument("--kv-mode", choices=("all", "contiguous", "paged"),
                    default="all",
                    help="which sweep: continuous-vs-wave (contiguous), "
                         "paged-vs-contiguous (paged), or both")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the slot batch over N host devices "
                         "(engines whose slot count N divides)")
    args = ap.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    run(smoke=args.smoke, kv_mode=args.kv_mode, devices=args.devices)


if __name__ == "__main__":
    main()
