"""Continuous admission vs wave-at-a-time serving on ragged output lengths.

The wave baseline (PR 2's serve loop) admits ``max_slots`` requests, decodes
until the WHOLE wave drains, and only then admits again — on ragged output
lengths every wave burns slot-steps padding out its straggler.  The
continuous engine refills a slot the moment EOS (or the budget) frees it,
paying only the interleaved admission-prefill ticks.

Both runners sample with the same fold-in RNG discipline, so per-request
outputs are token-identical — the comparison isolates *scheduling*:

  * decode-step slot occupancy (live slot-steps / total slot-steps), and
  * tokens per decode step — the deterministic tok/s proxy: the decode step
    is one fixed-shape compiled call, so per-step cost is constant and
    tok/s ∝ tokens/step (measured wall tok/s is printed, never asserted).

The headline claim is asserted: on every swept cell, continuous admission
strictly beats the wave baseline on BOTH metrics.

Standalone: PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
Harness:    PYTHONPATH=src python -m benchmarks.run --only serve_bench
CI runs ``--smoke`` (one cell) so the claim cannot rot.
"""

from __future__ import annotations

import argparse

import numpy as np

# (max_slots, n_requests, prompt_len, gen_lo, gen_hi)
CELLS = (
    (4, 16, 8, 4, 32),      # ragged budgets: the wave pathology
    (8, 24, 8, 2, 24),      # wider pool, heavier churn
)
SMOKE_CELLS = ((4, 12, 8, 4, 24),)


def make_requests(cfg, n, prompt_len, gen_lo, gen_hi, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [
        Request(req_id=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=(prompt_len,)).tolist(),
                max_new_tokens=int(rng.integers(gen_lo, gen_hi + 1)))
        for i in range(n)
    ]


def bench_cell(cfg, params, max_slots, n, prompt_len, gen_lo, gen_hi):
    from repro.serve import EngineConfig, ServeEngine, serve_waves

    ecfg = EngineConfig(max_slots=max_slots,
                        max_len=prompt_len + gen_hi + 1,
                        prefill_chunk=prompt_len,
                        chunks_per_step=2)
    requests = make_requests(cfg, n, prompt_len, gen_lo, gen_hi)

    engine = ServeEngine(cfg, params, ecfg)
    cont_out = engine.run(make_requests(cfg, n, prompt_len, gen_lo, gen_hi))
    cont = engine.metrics.summary()

    wave_out, wave_metrics = serve_waves(cfg, params, ecfg, requests)
    wave = wave_metrics.summary()

    assert cont_out == wave_out, (
        "fold-in sampling must make scheduling invisible to outputs")

    cell = f"{max_slots}slots/{n}req/gen{gen_lo}-{gen_hi}"
    for label, m in (("continuous", cont), ("wave", wave)):
        print(f"serve/{cell},{label},steps={m['decode_steps']:.0f},"
              f"occupancy={m['occupancy']:.3f},"
              f"tok_per_step={m['tokens_per_step']:.2f},"
              f"ttft_p50={m['ttft_p50_s'] * 1e3:.0f}ms,"
              f"wall_tok_s={m['tokens_per_s']:.0f}")
    assert cont["occupancy"] > wave["occupancy"], (
        f"{cell}: continuous occupancy {cont['occupancy']:.3f} must beat "
        f"wave {wave['occupancy']:.3f}")
    assert cont["tokens_per_step"] > wave["tokens_per_step"], (
        f"{cell}: continuous tokens/step {cont['tokens_per_step']:.2f} must "
        f"beat wave {wave['tokens_per_step']:.2f}")
    assert cont["decode_steps"] < wave["decode_steps"], (
        f"{cell}: continuous must finish in fewer decode steps")
    return cont, wave


def run(smoke: bool = False) -> None:
    import jax

    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = get_config("gemma2-2b-smoke")
    params = T.init_params(cfg, jax.random.key(0))
    cells = SMOKE_CELLS if smoke else CELLS
    print("serve/cell,mode,steps,occupancy,tok_per_step,ttft_p50,wall_tok_s")
    for cell in cells:
        bench_cell(cfg, params, *cell)
    print("serve/claim,ok,continuous admission beats wave baseline on "
          "occupancy AND tokens/step (outputs token-identical)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell sweep for CI")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
