"""Benchmark harness — one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows.

  table1     paper Table 1: sync overhead, 4 schemes × 5 meshes (+ vs-paper)
  area       paper §4.2: tile/system area, NoC + FS shares
  scaling    beyond-paper: schedule scaling 2×2 → 64×64 (+ TPU projection)
  schedules  measured wall-time of the JAX collective schedules (16 host dev)
  schedule_matrix  Schedule-IR autotuning sweep: cost ranking × NoC replay ×
             measured lowering; asserts the butterfly↔ring payload crossover
  overlap    bucketed-superstep sweep: bucket size × per-bucket schedule vs
             monolithic; asserts overlap-aware predicted time < serial sum
  serve_bench  continuous-batching engine vs wave baseline on ragged output
             lengths; asserts the occupancy + tokens/step win
  probes     XLA cost_analysis while-loop probe (motivates hlo_analysis)
  roofline   per-(arch×shape×mesh) roofline table from results/dryrun/*.json

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

import argparse
import os
import sys

# `schedules` executes real collectives: give this process 16 host devices
# BEFORE jax initializes (benchmarks only — tests/examples see 1 device).
if "XLA_FLAGS" not in os.environ or "device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                               + os.environ.get("XLA_FLAGS", ""))

BENCHES = ("table1", "area", "scaling", "schedules", "schedule_matrix",
           "overlap", "serve_bench", "probes", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES, default=None)
    args = ap.parse_args(argv)
    selected = [args.only] if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},error,{type(e).__name__}:{str(e)[:120]}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
