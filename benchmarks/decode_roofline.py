"""Decode-step roofline: bytes moved per paged decode step, gather vs fused.

The paged ref lowering materializes the virtual KV view every decode step:
``paged_gather`` writes a [B, n*bs, Hkv, Dh] copy of each pool (k and v)
and dense attention reads it back.  The fused Pallas kernel walks the
block table directly — each live pool block is DMA'd into VMEM exactly
once per grid step and the gathered view never exists.

Per cell this benchmark:

  * MEASURES the ref attention op's bytes (XLA ``cost_analysis()`` of the
    jitted gather-then-attend graph — the exact graph the
    ``paged_kernel="ref"`` engine lowering runs);
  * ACCOUNTS the fused kernel's bytes from its BlockSpecs (q in + output
    + one streamed read of every table-addressed k/v block + the scalar
    prefetch operands).  The kernel side is analytic because interpret
    mode lowers to the Pallas interpreter's grid loop, whose XLA byte
    count models the interpreter, not the TPU DMA schedule;
  * asserts the fused path moves at least one gathered-view copy (k+v)
    FEWER bytes per attention layer — the pool-sized copy is eliminated;
  * asserts fused and ref decode_step lowerings emit identical argmax
    tokens (interpret mode off-TPU), so the byte saving is not bought
    with drift.

Results land in ``BENCH_decode.json`` (committed; CI re-runs ``--smoke``).
"""

import argparse
import json

CELLS = [
    # (arch, batch, max_len, block_size)
    ("gemma2-2b-smoke", 4, 32, 4),
    ("gemma2-2b-smoke", 8, 128, 16),
    ("qwen2.5-3b-smoke", 8, 128, 16),
]
SMOKE_CELLS = CELLS[:1]
ATTN = ("attn", "local", "global")


def _bytes_accessed(compiled):
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return float(ca["bytes accessed"])


def measure_cell(arch: str, B: int, max_len: int, bs: int, decode_steps: int,
                 rows: list):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import transformer as T
    from repro.models.layers import gqa_attention, paged_gather
    from repro.models.registry import get_config

    cfg = get_config(arch)
    n = max_len // bs
    N = 1 + B * n
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, Dh)),
                    jnp.dtype(cfg.param_dtype))
    kp = jnp.asarray(rng.normal(size=(N, bs, Hkv, Dh)), q.dtype)
    vp = jnp.asarray(rng.normal(size=(N, bs, Hkv, Dh)), q.dtype)
    tables = jnp.asarray(1 + np.arange(B * n).reshape(B, n), jnp.int32)
    offs = jnp.asarray(rng.integers(1, max_len - decode_steps, size=(B,)),
                       jnp.int32)

    # -- measured: the ref lowering's per-layer attention op ----------------
    def ref_attn(q, kp, vp, t, off):
        k_all = paged_gather(kp, t)
        v_all = paged_gather(vp, t)
        pos_k = jnp.arange(k_all.shape[1], dtype=jnp.int32)[None, :]
        return gqa_attention(q, k_all, v_all, pos_q=off[:, None],
                             pos_k=pos_k, causal=True,
                             attn_cap=cfg.attn_softcap)

    ref_bytes = _bytes_accessed(
        jax.jit(ref_attn).lower(q, kp, vp, tables, offs).compile())

    # -- accounted: the fused kernel's DMA traffic from its BlockSpecs ------
    view = B * n * bs * Hkv * Dh * itemsize      # one gathered tensor copy
    fused_bytes = (2 * B * Hq * Dh * itemsize    # q in + o out
                   + 2 * view                    # k+v blocks streamed once
                   + tables.size * 4 + B * 4)    # scalar-prefetch operands
    n_attn = sum(reps for unit, reps in cfg.segments()
                 for kind in unit if kind in ATTN)
    saved = ref_bytes - fused_bytes
    gathered = 2 * view                          # the k+v copy that vanishes
    print(f"decode/cell,{arch},B={B},S={max_len},bs={bs},"
          f"ref_B={ref_bytes:.0f},fused_B={fused_bytes},"
          f"saved_B={saved:.0f},view_B={gathered},"
          f"saved_over_view={saved / gathered:.2f}")
    assert saved >= gathered, (
        f"{arch} B={B} S={max_len}: fused path must move at least the "
        f"gathered k+v copy ({gathered}B) fewer bytes, saved {saved:.0f}B")

    # -- token identity between the two decode_step lowerings ---------------
    params = T.init_params(cfg, jax.random.key(0))
    cache = T.init_paged_cache(cfg, N, bs)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 1)),
                      jnp.int32)
    fns = {pk: jax.jit(lambda p, t, c, o, bt, pk=pk: T.decode_step(
        p, cfg, t, c, o, block_tables=bt, paged_kernel=pk))
        for pk in ("ref", "pallas")}
    state = {pk: (tok, cache, offs) for pk in fns}
    for _ in range(decode_steps):
        nxt = {}
        for pk, fn in fns.items():
            t, c, o = state[pk]
            logits, c = fn(params, t, c, o, tables)
            nxt[pk] = (logits[:, 0].argmax(-1).astype(jnp.int32)[:, None],
                       c, o + 1)
        assert np.array_equal(np.asarray(nxt["ref"][0]),
                              np.asarray(nxt["pallas"][0])), (
            f"{arch}: fused decode diverged from ref lowering")
        state = nxt
    print(f"decode/identity,ok,{arch},steps={decode_steps}")

    rows.append({
        "arch": arch, "batch": B, "max_len": max_len, "block_size": bs,
        "attn_layers": n_attn,
        "ref_attn_bytes_measured": ref_bytes,
        "fused_attn_bytes_accounted": fused_bytes,
        "saved_bytes_per_layer": saved,
        "gathered_view_bytes": gathered,
        "saved_bytes_per_decode_step": saved * n_attn,
        "identity_steps": decode_steps,
    })


def run(smoke: bool = False, out: str = "BENCH_decode.json") -> None:
    results = {"cells": []}
    print("decode/cell,arch,batch,seq,block,ref,fused,saved,view,ratio")
    for cell in (SMOKE_CELLS if smoke else CELLS):
        measure_cell(*cell, decode_steps=3 if smoke else 5,
                     rows=results["cells"])
    print("decode/claim,ok,fused paged decode eliminates the gathered "
          "KV copy every attention layer")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"decode/json,written,{out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-cell sweep for CI")
    ap.add_argument("--devices", type=int, default=0,
                    help="host-device override (set before jax init)")
    ap.add_argument("--out", default="BENCH_decode.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)
    if args.devices:
        import os
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
