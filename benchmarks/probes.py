"""XLA measurement probes that justify the hlo_analysis corrections."""

import time

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch import hlo_analysis as H


def run() -> None:
    def f(x, w):
        def body(h, wi):
            return h @ wi, ()
        h, _ = lax.scan(body, x, w)
        return jnp.sum(h)

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    flops = {}
    for L in (1, 8):
        w = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        flops[L] = c.cost_analysis()["flops"]
        st = H.analyze_hlo(c.as_text())
        print(f"probes/cost_analysis_scan{L},1,"
              f"xla_flops={flops[L]:.3e};corrected={st.flops:.3e};"
              f"true={2*256**3*L:.3e}")
    ratio = flops[8] / flops[1]
    print(f"probes/while_trip_count_ignored,1,"
          f"xla_ratio_8v1={ratio:.2f};expected_if_correct=8.0")
