"""Schedule × payload autotuning matrix — the Schedule IR end to end.

For each (mesh shape, payload) cell: rank every IR schedule with the
cost-model backend, replay the winner's IR on the NoC simulator, and (when
enough host devices exist) measure the jitted JAX lowering — the three
backends of the same IR program side by side.  The sweep demonstrates the
expected crossover: the latency-optimal butterfly wins small payloads, the
bandwidth-optimal ring wins large ones, and ``BSPConfig(schedule="auto")``
picks accordingly.

Results are persisted machine-readably to ``BENCH_schedules.json``
(predicted rankings, NoC replay cycles, measured refinements, speedup of
the auto pick vs the serial Naïve baseline) so the perf trajectory is
tracked across PRs.

Standalone: PYTHONPATH=src python -m benchmarks.schedule_matrix [--out F]
Harness:    PYTHONPATH=src python -m benchmarks.run --only schedule_matrix
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import autotune, cost_model as CM, schedule_ir as IR
from repro.core.simulator import schedule_on_noc

SHAPES = ((2, 2), (4, 4), (8, 8), (16, 16))
PAYLOADS_B = (256, 4e5, 4e7)   # near-pure-control, 100K and 10M f32 grads
CROSSOVER_SHAPES = SHAPES[1:]  # on 2×2 ring≡butterfly (all links adjacent)
MEASURE_SHAPE = (4, 4)                 # 16 host devices when available


def _measure_fn(mesh, axes, sizes, n_bytes):
    """measure(schedule) → seconds for the jitted IR lowering (host devs)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import collectives as C

    world = int(np.prod(sizes))
    # per-device shard's leading dim must divide by the chunk count (world)
    unit = world * world * 16
    elems = max(unit, int(n_bytes) // 4 // unit * unit)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(elems // 16, 16)).astype(np.float32))
    spec = P(axes)

    def measure(schedule: str) -> float:
        fn = jax.jit(compat.shard_map(
            lambda v: C.all_reduce(v, schedule, axes, sizes),
            mesh, spec, spec, check_vma=False, axis_names=frozenset(axes)))
        fn(x).block_until_ready()
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            out = fn(x)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    return measure


def run(out: str = "BENCH_schedules.json") -> None:
    link = CM.MAGIA
    flit_bytes = 4  # 32-bit NoC flits
    print("schedule_matrix/mesh,payload_B,auto_pick,cost_ranking,"
          "noc_cycles_winner")
    crossover = {}
    results = {"cells": [], "measured": []}
    for shape in SHAPES:
        for vol in PAYLOADS_B:
            result = autotune.autotune(shape, vol, link=link)
            ranking = " ".join(f"{n}:{c * 1e6:.2f}us"
                               for n, c in result.ranking[:3])
            prog = IR.build_program(result.schedule, shape)
            flits = max(1, int(vol / flit_bytes))
            replay = schedule_on_noc(prog, payload_flits=min(flits, 4096))
            print(f"schedule_matrix/{shape[0]}x{shape[1]},{vol:.0e},"
                  f"{result.schedule},{ranking},{replay.overhead}")
            crossover[(shape, vol)] = result.schedule
            costs = dict(result.ranking)
            results["cells"].append({
                "shape": list(shape), "payload_B": vol,
                "chosen": result.schedule,
                "predicted_s": dict(result.ranking),
                "noc_cycles_chosen": int(replay.overhead),
                "speedup_vs_naive": (costs["naive"] / costs[result.schedule]
                                     if costs.get(result.schedule)
                                     else None),
            })

    # the sweep's headline claim, asserted so regressions are loud
    small = [crossover[(s, PAYLOADS_B[0])] for s in CROSSOVER_SHAPES]
    large = [crossover[(s, PAYLOADS_B[-1])] for s in CROSSOVER_SHAPES]
    assert all(p == "fractal" for p in small), \
        f"latency regime should pick the butterfly, got {small}"
    assert all(p == "ring" for p in large), \
        f"bandwidth regime should pick the ring, got {large}"
    print("schedule_matrix/crossover,ok,"
          "small→fractal large→ring as predicted")

    # measured refinement on real host devices (skipped when too few)
    try:
        import jax
        if len(jax.devices()) >= int(np.prod(MEASURE_SHAPE)):
            mesh = jax.make_mesh(MEASURE_SHAPE, ("a", "b"))
            measure = _measure_fn(mesh, ("a", "b"), MEASURE_SHAPE, 4e5)
            tuned = autotune.autotune(MEASURE_SHAPE, 4e5, link=link,
                                      measure=measure, measure_top_k=3)
            rows = " ".join(f"{n}:{t * 1e6:.0f}us" for n, t in tuned.measured)
            print(f"schedule_matrix/measured_{MEASURE_SHAPE[0]}x"
                  f"{MEASURE_SHAPE[1]},4e5,{tuned.schedule},{rows},")
            results["measured"].append({
                "shape": list(MEASURE_SHAPE), "payload_B": 4e5,
                "chosen": tuned.schedule,
                "predicted_s": dict(tuned.ranking),
                "measured_s": dict(tuned.measured),
            })
        else:
            print("schedule_matrix/measured,skip,"
                  f"needs {np.prod(MEASURE_SHAPE)} devices,")
    except Exception as e:  # measurement is optional refinement, not gating
        print(f"schedule_matrix/measured,error,{type(e).__name__},")

    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"schedule_matrix/json,written,{out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_schedules.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)
    run(out=args.out)


if __name__ == "__main__":
    import os
    if "device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=16 "
            + os.environ.get("XLA_FLAGS", ""))
    main()
