"""Paper Table 1: synchronization overhead of FSync / FSync+P / Naïve / XY.

Emits one row per (mesh × scheme) with the simulated cycle count, the paper's
number, and the ratio; plus the headline speedup rows (FSync+P vs best AMO).
"""

import time

from repro.core.simulator import PAPER_TABLE1, table1


def run() -> None:
    t0 = time.perf_counter()
    results = table1()
    elapsed_us = (time.perf_counter() - t0) * 1e6

    for name, row in results.items():
        fsync, fsync_p, naive, xy, speedup = PAPER_TABLE1[name]
        paper = {"fsync": fsync, "fsync_p": fsync_p, "naive": naive, "xy": xy}
        for scheme in ("fsync", "fsync_p", "naive", "xy"):
            got = row[scheme]
            print(f"table1/{name}/{scheme},{elapsed_us/20:.0f},"
                  f"cycles={got:.0f};paper={paper[scheme]};"
                  f"ratio={got/paper[scheme]:.2f}")
        print(f"table1/{name}/speedup,{elapsed_us/20:.0f},"
              f"sim={row['speedup']:.1f}x;paper={speedup}x")
