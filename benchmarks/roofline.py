"""Roofline table from the dry-run artifacts (results/dryrun/*/*.json).

One row per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, per-device memory, roofline fraction.
Also emits the EXPERIMENTS.md §Roofline markdown via --write-md (used by the
docs pipeline; the CSV rows here feed bench_output.txt).
"""

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_records():
    recs = []
    if not RESULTS.exists():
        return recs
    for mesh_dir in sorted(RESULTS.iterdir()):
        if not mesh_dir.is_dir():
            continue
        for p in sorted(mesh_dir.glob("*.json")):
            if "__opt" in p.stem or p.stem.count("__") > 1:
                continue   # hillclimb variants live in §Perf, not here
            recs.append(json.loads(p.read_text()))
    return recs


def run() -> None:
    recs = load_records()
    if not recs:
        print("roofline,skip,no dry-run artifacts (run repro.launch.dryrun)")
        return
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            print(f"roofline/{cell},0,skipped:{r.get('reason', '')[:60]}")
            continue
        if r.get("status") != "ok":
            print(f"roofline/{cell},0,error:{r.get('error', '')[:80]}")
            continue
        rf = r.get("roofline", {})
        mem = r.get("memory", {}).get("total_per_device_gib", float("nan"))
        print(
            f"roofline/{cell},{r.get('compile_s', 0) * 1e6:.0f},"
            f"compute={rf.get('compute_s', 0):.3f}s;"
            f"memory={rf.get('memory_s', 0):.3f}s;"
            f"collective={rf.get('collective_s', 0):.3f}s;"
            f"dom={rf.get('dominant', '?')};"
            f"useful={r.get('useful_flops_ratio', 0)};"
            f"frac={r.get('roofline_fraction', 0)};"
            f"mem={mem}GiB")


def markdown_table(records=None) -> str:
    records = records or load_records()
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s "
        "(ici/dcn) | dominant | useful FLOPs ratio | roofline frac | "
        "GiB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| — | — | — | skipped: {r.get('reason', '')} |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| — | — | — | ERROR: {r.get('error', '')[:80]} |")
            continue
        rf = r.get("roofline", {})
        mem = r.get("memory", {}).get("total_per_device_gib", "n/a")
        ici = rf.get("collective_ici_s", 0)
        dcn = rf.get("collective_dcn_s", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf.get('compute_s', 0):.3f} | {rf.get('memory_s', 0):.3f} "
            f"| {rf.get('collective_s', 0):.3f} ({ici:.3f}/{dcn:.3f}) "
            f"| {rf.get('dominant', '?').replace('_s', '')} "
            f"| {r.get('useful_flops_ratio', '—')} "
            f"| {r.get('roofline_fraction', '—')} | {mem} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if "--write-md" in sys.argv:
        print(markdown_table())
    else:
        run()
