"""Beyond-paper scaling study: 2×2 → 64×64 meshes, and the TPU projection.

Part 1 — MAGIA constants (cycle-accurate sim + analytic FSync): extends the
paper's Table 1 beyond 16×16; the AMO baselines are simulated up to 16×16
and the FractalSync columns are exact at every size.

Part 2 — TPU constants (α-β cost model): the same four schedules pricing a
pure barrier and a 1 GiB gradient all-reduce on a v5e pod and on 2 pods —
the regime our framework actually targets (EXPERIMENTS.md §Schedules).
"""

import math
import time

from repro.core import cost_model as cm
from repro.core.simulator import scaling_sweep


def run() -> None:
    t0 = time.perf_counter()
    sweep = scaling_sweep(ks=(2, 4, 8, 16, 32, 64))
    us = (time.perf_counter() - t0) * 1e6
    for name, row in sweep.items():
        extra = ""
        if "naive" in row:
            extra = (f";naive={row['naive']:.0f};xy={row['xy']:.0f};"
                     f"speedup={row['speedup']:.0f}x")
        print(f"scaling/magia/{name},{us/6:.0f},"
              f"fsync={row['fsync']:.0f};fsync_p={row['fsync_p']:.0f}{extra}")

    # ---- TPU projection ----
    for n, label in ((256, "pod"), (512, "2pods")):
        link = cm.TPU_V5E_ICI
        for sched in ("fractal", "xy", "ring", "naive"):
            b = cm.barrier_cost(n, link, sched)
            print(f"scaling/tpu_barrier/{label}/{sched},1,"
                  f"{b*1e6:.1f}us")
        vol = 2**30
        for sched in ("fractal", "xy", "ring", "naive"):
            c = cm.schedule_cost(sched, n, vol, link,
                                 mesh_xy=(int(math.sqrt(n)),
                                          n // int(math.sqrt(n))))
            print(f"scaling/tpu_allreduce_1GiB/{label}/{sched},1,"
                  f"{c*1e3:.2f}ms")
        h = cm.hierarchical_all_reduce(256, n // 256, vol, cm.TPU_V5E_ICI,
                                       cm.TPU_DCN)
        print(f"scaling/tpu_allreduce_1GiB/{label}/hierarchical,1,"
              f"{h*1e3:.2f}ms")
