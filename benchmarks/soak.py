"""Soak + SLO harness CLI: fault-injected serving and training soaks.

Serve mode drives the continuous-batching engine for thousands of
virtual-clock steps under open-loop arrivals (Poisson or bursty) with a
``FaultPlan`` injected — admission stalls, KV block-pool pressure — and
asserts p99 TTFT RECOVERS to the pre-fault baseline band within a
bounded number of steps after the fault window closes.  Train mode runs
``runtime.soak.run_train_soak``: a slow rank triggers an actuated
micro-batch rebalance, a killed rank triggers heartbeat-timeout
detection, re-mesh onto the surviving fsync domain, checkpoint-restore,
and loss-trajectory continuity.  Everything runs on the virtual step
clock, so every number below is deterministic per seed.

Standalone:
  PYTHONPATH=src python -m benchmarks.soak --smoke --devices 8
  PYTHONPATH=src python -m benchmarks.soak --mode serve \
      --soak-steps 4000 --arrival burst:40,0.5 \
      --fault-plan 'stall:steps=700..760;blocks:frac=0.5,steps=1000..1200' \
      --slo-p99-ms 200 --devices 8

``--smoke`` (CI) runs the 2000-step serve soak (one stall + one
block-pressure window) AND the training soak (one slow rank + one killed
rank) on 8 host devices, then writes the committed BENCH_serve.json.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import tempfile

import numpy as np

SMOKE_STEPS = 2000
SMOKE_ARRIVAL = "burst:40,0.5"
SMOKE_PLAN = "stall:steps=700..760;blocks:frac=0.5,steps=1000..1200"


def _round(x, nd=4):
    if isinstance(x, float):
        return round(x, nd) if math.isfinite(x) else None
    return x


def run_serve_soak(steps: int, arrival: str, fault_plan: str,
                   slo_p99_ms: float | None, devices: int, seed: int,
                   arch: str = "gemma2-2b-smoke"):
    import jax

    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.runtime.chaos import FaultPlan
    from repro.serve import (EngineConfig, Request, ServeEngine, SoakConfig,
                             parse_arrival_spec, run_soak)
    from benchmarks.serve_bench import _mesh_for

    cfg = get_config(arch)
    params = T.init_params(cfg, jax.random.key(0))
    max_slots = 8
    ecfg = EngineConfig(max_slots=max_slots, max_len=32, prefill_chunk=8,
                        chunks_per_step=2, kv_mode="paged", block_size=8,
                        kv_blocks=4 * max_slots + 1, clock="step")
    engine = ServeEngine(cfg, params, ecfg,
                         mesh=_mesh_for(devices, max_slots))

    # size the request stream to the arrival process over the soak horizon
    rate = 40.0 if ":" not in arrival else float(
        arrival.split(":", 1)[1].split(",")[0])
    n = max(1, int(rate * steps * ecfg.step_s))
    arrivals = parse_arrival_spec(arrival, n, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = [Request(req_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(8,)).tolist(),
                    max_new_tokens=int(rng.integers(4, 13)),
                    arrival_s=arrivals[i])
            for i in range(n)]

    plan = FaultPlan.parse(fault_plan)
    scfg = SoakConfig(steps=steps, window=max(10, steps // 40),
                      warmup_steps=max(50, steps // 10),
                      recovery_band=1.5, recovery_slack_s=0.01,
                      recovery_steps=max(200, steps // 4),
                      slo_p99_s=slo_p99_ms / 1e3 if slo_p99_ms else None)
    res = run_soak(engine, reqs, plan, scfg)

    print(f"soak/serve,steps={steps},requests={n},arrival={arrival}")
    print(f"soak/serve,faults={plan.spec()!r}")
    print(f"soak/serve,baseline_p99={res.baseline_p99_s * 1e3:.1f}ms,"
          f"stream_p99={res.summary['ttft_p99_stream_s'] * 1e3:.1f}ms,"
          f"queue_peak={res.summary['queue_peak']:.0f},"
          f"preempt={res.summary['preemptions']:.0f}")
    spike = max((r["ttft_p99_s"] for r in res.trend
                 if r["first_tokens"] and plan.first_fault_start() is not None
                 and r["step"] > plan.first_fault_start()), default=float("nan"))
    print(f"soak/serve,fault_end={res.fault_end_step},"
          f"worst_p99={spike * 1e3:.1f}ms,"
          f"recovered_step={res.recovered_step},"
          f"recovery_steps={res.recovery_steps_taken}")
    assert res.ok, res.failures
    print(f"soak/claim,ok,p99 TTFT returned to {scfg.recovery_band}x "
          f"baseline within {res.recovery_steps_taken} steps of fault end")
    return {
        "steps": steps, "requests": n, "arrival": arrival,
        "fault_plan": plan.spec(),
        "baseline_p99_ms": _round(res.baseline_p99_s * 1e3, 2),
        "worst_window_p99_ms": _round(spike * 1e3, 2),
        "fault_end_step": res.fault_end_step,
        "recovered_step": res.recovered_step,
        "recovery_steps": res.recovery_steps_taken,
        "recovery_band": scfg.recovery_band,
        "summary": {k: _round(v) for k, v in res.summary.items()},
        "trend": [{k: _round(v) for k, v in row.items()}
                  for row in res.trend],
    }


def run_train_soak_bench():
    from repro.runtime.soak import (TrainSoakConfig, check_train_soak,
                                    run_train_soak)

    scfg = TrainSoakConfig()
    with tempfile.TemporaryDirectory() as d:
        res = check_train_soak(run_train_soak(scfg, d), scfg)
    rec = res.recovery or {}
    print(f"soak/train,steps={scfg.total_steps},faults={scfg.fault_spec!r}")
    print(f"soak/train,actuated_shares={res.actuated_shares},"
          f"recovery={rec.get('old_world')}->{rec.get('new_world')}ranks,"
          f"level={rec.get('level')},restore_step={rec.get('restore_step')}")
    assert res.ok, res.failures
    print("soak/claim,ok,straggler rebalance actuated + killed rank "
          "re-meshed onto surviving fsync domain with continuous loss")
    return {
        "total_steps": scfg.total_steps, "fault_plan": scfg.fault_spec,
        "actuated_shares": res.actuated_shares,
        "rebalance_events": len(res.rebalance),
        "recovery": {k: (list(map(list, v)) if k == "tiles" else v)
                     for k, v in rec.items()},
        "replay_pairs": [[_round(a, 6), _round(b, 6)]
                         for a, b in res.replay_pairs],
        "first_losses": [_round(r["loss"]) for r in res.history[:3]],
        "last_losses": [_round(r["loss"]) for r in res.history[-3:]],
    }


def run(mode: str, steps: int, arrival: str, fault_plan: str,
        slo_p99_ms: float | None, devices: int, seed: int,
        out: str | None) -> None:
    report = {}
    if mode in ("serve", "both"):
        report["serve"] = run_serve_soak(steps, arrival, fault_plan,
                                         slo_p99_ms, devices, seed)
    if mode in ("train", "both"):
        report["train"] = run_train_soak_bench()
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"soak/report,{out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: 2000-step serve soak + train "
                         "soak on 8 host devices, write BENCH_serve.json")
    ap.add_argument("--mode", choices=("serve", "train", "both"),
                    default="both")
    ap.add_argument("--soak-steps", type=int, default=SMOKE_STEPS,
                    help="virtual-clock engine steps for the serve soak")
    ap.add_argument("--arrival", default=SMOKE_ARRIVAL,
                    help="arrival spec: poisson:RATE | burst:RATE,DUTY"
                         "[,PERIOD] | trace:SPEC")
    ap.add_argument("--fault-plan", default=SMOKE_PLAN,
                    help="';'-separated fault events "
                         "(see repro.runtime.chaos)")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="absolute steady-state p99 TTFT SLO to assert "
                         "(virtual ms); default: band-recovery only")
    ap.add_argument("--devices", type=int, default=0,
                    help="host devices (the train soak needs 8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the JSON report here "
                         "(--smoke default: BENCH_serve.json)")
    args = ap.parse_args(argv)
    if args.smoke and args.devices == 0:
        args.devices = 8
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    out = args.out
    if args.smoke and out is None:
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    run(args.mode, args.soak_steps, args.arrival, args.fault_plan,
        args.slo_p99_ms, args.devices, args.seed, out)


if __name__ == "__main__":
    main()
