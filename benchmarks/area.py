"""Paper §4.2 + Fig. 4: area model."""

import time

from repro.core.area import (TILE_BREAKDOWN, fs_tile_overhead, system_area)


def run() -> None:
    t0 = time.perf_counter()
    print(f"area/tile_fs_overhead,1,delta={fs_tile_overhead()*100:.4f}%"
          f";paper=<0.01%")
    for k in (4, 8, 16, 32, 64):
        a = system_area(k)
        print(f"area/system_{k}x{k},1,total={a.total_mm2:.1f}mm2;"
              f"noc={a.noc_share*100:.2f}%;fs={a.fs_share*100:.4f}%")
    top = sorted(TILE_BREAKDOWN.items(), key=lambda kv: -kv[1])[:4]
    comp = ";".join(f"{k}={v*100:.1f}%" for k, v in top)
    print(f"area/tile_breakdown,1,{comp}")
    _ = (time.perf_counter() - t0)
