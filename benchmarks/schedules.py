"""Measured wall-time of the JAX collective schedules (16 host devices).

The container's empirical analogue of Table 1: the same payload all-reduced
through fractal / ring / xy / naive / xla schedules, timed.  Host-device
collectives go through shared memory, so ratios are indicative (latency
structure), not ICI-accurate — the ICI numbers come from the dry-run +
cost model.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives as C


def _bench(fn, x, iters=20):
    fn(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    n_dev = len(jax.devices())
    if n_dev < 16:
        print(f"schedules,skip,needs 16 devices (have {n_dev})")
        return
    mesh = compat.make_mesh((4, 4), ("a", "b"))
    axes, sizes = ("a", "b"), (4, 4)
    world = 16

    for elems in (2**14, 2**20):
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(world * elems // 16, 16)).astype(np.float32))
        spec = P(("a", "b"))

        def make(schedule):
            def f(v):
                return C.all_reduce(v, schedule, axes, sizes)
            return jax.jit(compat.shard_map(
                f, mesh, spec, spec,
                check_vma=False, axis_names=frozenset(axes)))

        base = None
        for sched in ("xla", "fractal", "ring", "xy", "naive"):
            us = _bench(make(sched), x)
            if sched == "fractal":
                base = us
            ratio = f";vs_fractal={us/base:.2f}x" if base else ""
            print(f"schedules/allreduce_{elems*4//1024}KiB/{sched},"
                  f"{us:.0f},{ratio[1:] if ratio else ''}")

    # pure barrier (the paper's regime: payload → 0)
    tok = jnp.ones((16, 16), jnp.float32)

    def barrier(schedule):
        def f(v):
            if schedule == "fractal":
                t = C.fractal_barrier(axes, sizes).astype(jnp.float32)
            else:
                tok = jnp.ones((world, 1), jnp.float32)  # world-divisible
                t = C.all_reduce(tok, schedule, axes, sizes)[0, 0]
            return v + t * 0
        return jax.jit(compat.shard_map(
            f, mesh, P(("a", "b")), P(("a", "b")),
            check_vma=False, axis_names=frozenset(axes)))

    for sched in ("fractal", "ring", "naive", "xla"):
        us = _bench(barrier(sched), tok, iters=50)
        print(f"schedules/barrier/{sched},{us:.0f},")
