"""Generate EXPERIMENTS.md from results/ artifacts + narrative sections.

    PYTHONPATH=src python scripts_gen_experiments.py

Safe to re-run as dry-run cells land; hillclimb variants (tagged JSONs) are
collected into §Perf.
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.core.simulator import PAPER_TABLE1, table1, DEFAULT_PARAMS  # noqa: E402
from repro.core.area import fs_tile_overhead, system_area  # noqa: E402
from repro.core import cost_model as cm  # noqa: E402

ROOT = Path(__file__).resolve().parent
RESULTS = ROOT / "results" / "dryrun"

ARCH_ORDER = ["deepseek-v3-671b", "qwen3-moe-235b-a22b", "qwen2.5-3b",
              "granite-34b", "phi4-mini-3.8b", "gemma2-2b", "paligemma-3b",
              "musicgen-medium", "xlstm-1.3b", "jamba-v0.1-52b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

MOVE_DOWN = {
    "compute_s": "fuse/skip masked attention blocks and raise MXU occupancy "
                 "(Pallas flash kernel replaces the blocked-HLO path on TPU)",
    "memory_s": "keep scores/softmax in VMEM (flash kernel) and cut "
                "rematerialized HBM round-trips (remat policy)",
    "collective_s": "reshard to cut resharding all-gathers; hierarchical "
                    "(fractal) two-level schedule on the slow axis; compress "
                    "gradient payloads (bf16/int8+EF)",
}


def load(mesh):
    recs = {}
    d = RESULTS / mesh
    if not d.exists():
        return recs
    for p in sorted(d.glob("*.json")):
        stem = p.stem
        parts = stem.split("__")
        arch, shape = parts[0], parts[1]
        tag = parts[2] if len(parts) > 2 else ""
        recs[(arch, shape, tag)] = json.loads(p.read_text())
    return recs


def sec_table1():
    res = table1()
    out = ["## §Table-1 — paper reproduction (cycle-accurate simulator)",
           "",
           "FractalSync columns are **parameter-free** (pure topology: "
           "`2+2L`, pipeline regs `max(0,sep/2−1)`) and match the paper "
           "exactly. The Naïve/XY software-AMO baselines use the calibrated "
           "event-driven NoC+AMO model "
           f"(`{DEFAULT_PARAMS}`, fitted by `repro.core.calibrate`, mean "
           "squared log-ratio 0.029).", "",
           "| mesh | FSync sim/paper | FSync+P sim/paper | Naïve sim/paper "
           "(ratio) | XY sim/paper (ratio) | speedup sim/paper |",
           "|---|---|---|---|---|---|"]
    for name, row in res.items():
        f, fp, nv, xy, sp = PAPER_TABLE1[name]
        out.append(
            f"| {name} | {row['fsync']:.0f}/{f} | {row['fsync_p']:.0f}/{fp} "
            f"| {row['naive']:.0f}/{nv} ({row['naive']/nv:.2f}) "
            f"| {row['xy']:.0f}/{xy} ({row['xy']/xy:.2f}) "
            f"| {row['speedup']:.0f}×/{sp}× |")
    out += ["",
            "All paper claims hold in the reproduction: FSync latencies "
            "exact; speedup ≥19× everywhere and **growing with mesh size** "
            "(50× vs paper's 43× at 16×16 — our XY baseline is 15% "
            "pessimistic); Naïve beats XY at 2×2 and loses from 4×4 up. "
            "Largest residual: Naïve@16×16 at 0.67× — the real system's "
            "poll-storm congestion is super-linear beyond what the "
            "single-queue AMO model captures; trend and ranking are "
            "preserved (see tests/test_simulator.py)."]
    return "\n".join(out)


def sec_area():
    out = ["## §Area — paper §4.2",
           "",
           f"- FractalSync tile overhead: {fs_tile_overhead()*100:+.4f}% "
           "(paper: <0.01%, slightly negative = synthesis noise) ✓",
           "",
           "| k | total mm² | NoC share | FS share |",
           "|---|---|---|---|"]
    for k in (4, 8, 16, 32, 64):
        a = system_area(k)
        out.append(f"| {k}×{k} | {a.total_mm2:.1f} | {a.noc_share*100:.2f}% "
                   f"| {a.fs_share*100:.4f}% |")
    out += ["",
            "Reproduces the paper's 1.7% / 0.007% at k=16 and shows the "
            "scalability property: the sync-network share is bounded "
            "(k²−1 FS modules vs k² tiles)."]
    return "\n".join(out)


def sec_schedules():
    rows = []
    for n, label in ((256, "1 pod"), (512, "2 pods")):
        for sched in ("fractal", "xy", "ring", "naive"):
            b = cm.barrier_cost(n, cm.TPU_V5E_ICI, sched) * 1e6
            rows.append((label, sched, f"{b:.0f} µs"))
    out = ["## §Schedules — TPU projection (α-β model) + measured host ratios",
           "",
           "Pure barrier (paper's regime, payload→0) on v5e ICI "
           "(α≈1 µs/step):", "",
           "| world | fractal (2·log₂N) | xy (4(√N−1)) | ring (2(N−1)) | "
           "naive (2(N−1)) |", "|---|---|---|---|---|"]
    for label in ("1 pod", "2 pods"):
        vals = {s: v for l, s, v in rows if l == label}
        out.append(f"| {label} | {vals['fractal']} | {vals['xy']} "
                   f"| {vals['ring']} | {vals['naive']} |")
    out += ["",
            "1 GiB gradient all-reduce, 2 pods (ICI 50 GB/s, DCN 25 GB/s): "
            f"fractal {cm.fractal_all_reduce(512, 2**30, cm.TPU_V5E_ICI)*1e3:.1f} ms flat vs "
            f"hierarchical {cm.hierarchical_all_reduce(256, 2, 2**30, cm.TPU_V5E_ICI, cm.TPU_DCN)*1e3:.1f} ms "
            "(intra-pod RS → inter-pod AR on 1/256 of the bytes → intra-pod "
            "AG) — the H-tree idea applied at pod granularity is what makes "
            "the 2-pod mesh viable.",
            "",
            "Measured host-device schedule ratios: `python -m benchmarks.run "
            "--only schedules` (see bench_output.txt); numerical equivalence "
            "of all schedules vs `psum`: tests/collective_checks.py (16 "
            "checks)."]
    return "\n".join(out)


def _fmt_mem(r):
    m = r.get("memory", {}).get("total_per_device_gib")
    return f"{m:.1f}" if isinstance(m, (int, float)) else "n/a"


def sec_dryrun(single, multi):
    out = ["## §Dry-run — lower + compile every (arch × shape × mesh)",
           "",
           "`jax.jit(step).lower(...).compile()` with production shardings "
           "at 256 devices (16×16 `(\"data\",\"model\")`) and 512 devices "
           "(2×16×16 `(\"pod\",\"data\",\"model\")`), XLA CPU backend, "
           "`ShapeDtypeStruct` inputs (no allocation). train shapes lower "
           "`train_step` (fwd+bwd+AdamW, FSDP×TP, layer-scan + block-remat); "
           "decode/long shapes lower `serve_step` (1 token against a "
           "seq_len KV/state cache); optimizer moments are bf16 above 30 B "
           "params (deepseek, qwen3-moe, granite, jamba), f32 otherwise.",
           "",
           "| arch | shape | single-pod | compile s | GiB/dev | multi-pod | "
           "compile s | GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = n_err = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = single.get((arch, shape, ""))
            m = multi.get((arch, shape, ""))
            cells = []
            for r in (s, m):
                if r is None:
                    cells += ["pending", "—", "—"]
                elif r.get("status") == "skipped":
                    cells += ["skipped¹", "—", "—"]
                    n_skip += 0.5
                elif r.get("status") == "ok":
                    cells += ["ok", f"{r.get('compile_s', 0):.0f}",
                              _fmt_mem(r)]
                    n_ok += 0.5
                else:
                    cells += ["ERROR", "—", "—"]
                    n_err += 0.5
            out.append(f"| {arch} | {shape} | " + " | ".join(cells) + " |")
    out += ["",
            "¹ long_500k is assigned to sub-quadratic archs only "
            "(xlstm, jamba); the 8 full-attention archs skip it "
            "(DESIGN.md §5).",
            "",
            "**Memory fits**: per-device totals ≤16 GiB (v5e HBM) for all "
            "serving cells except deepseek decode_32k (204 GiB — the "
            "recomputed-from-latent K/V + 129k-vocab logits; §Perf "
            "iteration 3 attacks it). Training the two MoE giants does NOT "
            "fit one pod (deepseek train 3.1 TiB/dev at the baseline): "
            "they need the multi-pod mesh plus the §Perf memory fixes — "
            "exactly the motivation for hierarchical BSP sync at scale.",]
    return "\n".join(out)


def sec_roofline(single, multi):
    sys.path.insert(0, str(ROOT / "benchmarks"))
    import importlib
    roofline = importlib.import_module("benchmarks.roofline")
    out = ["## §Roofline — per (arch × shape × mesh), from the compiled HLO",
           "",
           "Terms per device per step (v5e: 197 bf16 TFLOP/s, 819 GB/s HBM, "
           "50 GB/s/link ICI, 25 GB/s DCN inter-pod): compute = "
           "HLO_FLOPs/peak; memory = HLO bytes/HBM-bw; collective = parsed "
           "wire bytes/link-bw, split ici/dcn by replica-group pod "
           "membership. **HLO_FLOPs/bytes are trip-count corrected** — "
           "XLA's cost_analysis counts While bodies once "
           "(benchmarks/probes.py), so a scanned 61-layer model "
           "under-reports ~61×; `launch/hlo_analysis.py` rebuilds the "
           "multipliers from `known_trip_count`. `useful FLOPs ratio` = "
           "MODEL_FLOPS/HLO_FLOPs with MODEL_FLOPS = 6·N_active·tokens "
           "(train) / 2·N_active·tokens (serve); `roofline frac` = "
           "(MODEL_FLOPS/peak)/max-term — the MFU-style score.",
           "",
           roofline.markdown_table(), "",
           "### Reading the table",
           ""]
    doms = {}
    for recs in (single, multi):
        for (arch, shape, tag), r in recs.items():
            if tag or r.get("status") != "ok":
                continue
            d = r.get("roofline", {}).get("dominant", "?")
            doms.setdefault(d, []).append((arch, shape, r["mesh"]))
    for d, cells in sorted(doms.items()):
        out.append(f"- **{d.replace('_s','')}-bound** ({len(cells)} cells): "
                   f"move it down by: {MOVE_DOWN.get(d, '—')}.")
    out += ["",
            "Decode cells are memory/collective-bound (every step reads "
            "params + cache: arithmetic intensity ≈ 1-2 flops/byte ⇒ "
            "roofline fraction is inherently ~bandwidth-limited at "
            "batch≤128); train cells are memory-bound in this baseline "
            "because the blocked-attention HLO round-trips scores through "
            "HBM — the §Perf log drives exactly that term down."]
    return "\n".join(out)


def sec_perf(single, multi):
    out = ["## §Perf — hypothesis → change → measure → validate",
           "",
           "Three hillclimbed cells: gemma2-2b:train_4k (worst train "
           "roofline fraction), deepseek-v3-671b:train_4k (paper-technique "
           "representative: biggest BSP sync volume + EP), "
           "deepseek-v3-671b:decode_32k (most collective-bound). Baselines "
           "(paper-faithful GSPMD tier) recorded above; variants are tagged "
           "dry-runs (`--opt k=v --tag h*`).", ""]
    # collect tagged variants
    variants = {}
    for recs in (single, multi):
        for (arch, shape, tag), r in recs.items():
            if tag:
                label = tag + ("" if r.get("mesh") == "single"
                               else f" [{r.get('mesh')}]")
                variants.setdefault((arch, shape), []).append((label, r))
    for (arch, shape), vs in sorted(variants.items()):
        base = single.get((arch, shape, "")) or multi.get((arch, shape, ""))
        out.append(f"### {arch} : {shape}")
        out.append("")
        out.append("| variant | opts | compute s | memory s | collective s "
                   "| GiB/dev | roofline frac | Δ dominant vs base |")
        out.append("|---|---|---|---|---|---|---|---|")

        def row(name, r):
            rf = r.get("roofline", {})
            if r.get("status") != "ok":
                return (f"| {name} | {r.get('opts', {})} | ERROR "
                        f"{r.get('error', '')[:40]} | | | | | |")
            dom_base = (base or {}).get("roofline", {}).get("dominant")
            delta = ""
            if base and base.get("status") == "ok" and dom_base:
                b = base["roofline"][dom_base]
                v = rf.get(dom_base, 0)
                delta = f"{(v - b) / b * 100:+.0f}%"
            return (f"| {name} | {r.get('opts', {})} "
                    f"| {rf.get('compute_s', 0):.2f} "
                    f"| {rf.get('memory_s', 0):.2f} "
                    f"| {rf.get('collective_s', 0):.2f} | {_fmt_mem(r)} "
                    f"| {r.get('roofline_fraction', '—')} | {delta} |")

        if base:
            out.append(row("baseline", base))
        for tag, r in sorted(vs, key=lambda t: t[0]):
            out.append(row(tag, r))
        out.append("")
    out.append("(Hypotheses, napkin math and confirm/refute notes per "
               "iteration are in §Perf-log below.)")
    return "\n".join(out)


def main():
    single, multi = load("single"), load("multi")
    doc = ["# EXPERIMENTS — FractalSync-JAX",
           "",
           "Container: 1× CPU core, 35 GB RAM, jax 0.8.2 (CPU backend). "
           "TPU v5e is the compile/roofline TARGET; Pallas kernels validate "
           "in interpret mode; collective schedules validate numerically on "
           "host devices. All numbers below are reproducible with the "
           "commands in DESIGN.md §8.",
           "",
           sec_table1(), "", sec_area(), "", sec_schedules(), "",
           sec_dryrun(single, multi), "", sec_roofline(single, multi), "",
           sec_perf(single, multi), ""]
    extra = ROOT / "EXPERIMENTS_extra.md"
    if extra.exists():
        doc.append(extra.read_text())
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(doc))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
