"""Quickstart: build a model, take BSP train steps, then serve from it.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end on one CPU device in under a minute:
config → init → loss/grad → AdamW → prefill → decode.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.optim import adamw


def main():
    # 1. pick an architecture (any of the ten assigned ids, or its -smoke cut)
    cfg = get_config("gemma2-2b-smoke")
    print(f"arch={cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"pattern={cfg.layer_pattern}")

    # 2. init params + optimizer
    params = T.init_params(cfg, jax.random.key(0))
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    opt = adamw.init(params, acfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n:,}")

    # 3. a few train steps on synthetic data
    data = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=64))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, cfg, batch)
        params, opt, m = adamw.apply_updates(params, grads, opt, acfg)
        return params, opt, loss

    for s in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt, loss = step(params, opt, batch)
        if s % 3 == 0:
            print(f"step {s}: loss {float(loss):.4f}")

    # 4. serve: prefill a prompt, decode greedily
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 12),
                                          dtype=np.int32))
    cache = T.init_cache(cfg, 1, 40)
    logits, cache, offset = T.prefill(params, cfg, prompt, cache)
    out = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(8):
        out.append(int(tok[0, 0]))
        logits, cache = T.decode_step(params, cfg, tok, cache, offset + i)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    print("generated:", out)


if __name__ == "__main__":
    main()
